"""Legacy per-client round loop — the REFERENCE engine.

This is the seed's execution model: a Python loop over the K selected
clients with one jitted local-update call and a blocking ``float(...)``
host sync per client.  It computes the same algorithm as the batched
round program in ``fed/engine.py`` (same key derivations, same
aggregation), and exists for exactly two purposes:

  1. parity tests — the batched engine must reproduce its accuracy
     trajectory at a fixed seed;
  2. the looped-vs-batched engine benchmark (``benchmarks`` entry
     ``engine/*``), which quantifies the rounds/sec win.

The SERVER side goes through the family's typed uplink codec exactly
like the fused engines: per-client payloads are encoded into a stacked
:class:`~repro.fed.codecs.WireMsg` and aggregated with
``codec.aggregate`` — so ``uplink_bits_round`` here is the same MEASURED
quantity (summed encoded buffer sizes per round) every engine reports,
not a precomputed ``[K * estimate] * R`` constant list.

Production callers should use the Experiment API (scan engine) instead.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (NoiseConfig, client_local_update, gen_noise,
                    make_compressor, mix_add, sgd_local_update,
                    tree_num_params)
from .algorithms import _CODEC_COMPRESSORS, fedpm_posterior
from .codecs import WireMsg
from .engine import (FLConfig, fedpm_local, fedsparsify_local,
                     get_algorithm, make_client_schedule,
                     stack_client_batches, uplink_bits)

Pytree = Any


def run_federated_looped(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    init_params: Pytree,
    client_batch_fn: Callable[[int, int], Any],
    eval_fn: Callable[[Pytree], float],
    cfg: FLConfig,
    *,
    eval_every: int = 1,
    client_weights: Optional[List[float]] = None,
    schedule: Optional[np.ndarray] = None,
    valid: Optional[np.ndarray] = None,
) -> Dict[str, Any]:
    from ..core.compressors import REGISTRY as COMPRESSOR_REGISTRY
    builtin = ({"fedmrn", "fedmrns", "fedpm", "fedsparsify", "fedavg"}
               | set(COMPRESSOR_REGISTRY))
    if cfg.algorithm not in builtin:
        raise ValueError(
            f"engine='looped' is the seed-era reference loop and only "
            f"supports the built-in families; run registered plugin "
            f"algorithm {cfg.algorithm!r} on engine='scan' or 'batched'")
    if cfg.int_mask_agg and client_weights is not None:
        # same guard as the scan chunk body: the integer count aggregate
        # folds ONE weight scalar — per-client weights need the f32 path
        raise ValueError(
            "int_mask_agg requires uniform client weights "
            "(client_weights=None)")
    if cfg.privacy is not None and client_weights is not None:
        raise ValueError(
            "privacy= requires uniform client weights "
            "(client_weights=None): the clipped-count sensitivity bound "
            "assumes every client contributes one unweighted mask")
    # the same precomputed seed-stable (R, K) selection every engine uses
    if schedule is None:
        schedule = make_client_schedule(cfg)
    w = init_params
    mrn_cfg = cfg.fedmrn_config()
    codec = get_algorithm(cfg.algorithm).codec(cfg, init_params)
    history: Dict[str, Any] = {
        "algorithm": cfg.algorithm, "engine": "looped",
        "acc": [], "round": [],
        "local_loss": [], "uplink_bits_per_client": uplink_bits(cfg, w),
        "uplink_bits_round": [],
        "params": tree_num_params(w), "schedule": schedule,
    }
    if client_weights is None:
        client_weights = [1.0] * cfg.num_clients
    # one jitted server step per family: stacked WireMsg → update
    # (encode is unused by fedmrn, whose clients ship packed masks already;
    # its decode + Eq.(5) update is one codec.aggregate_apply program —
    # fused words→counts→model on the pallas backend)
    aggregate = jax.jit(codec.aggregate)
    encode = jax.jit(codec.encode_stacked)
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        aggregate_apply = jax.jit(codec.aggregate_apply)

    # jitted workers (compiled once, reused by every client/round)
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        local = jax.jit(partial(client_local_update, loss_fn, cfg=mrn_cfg,
                                base_seed=cfg.seed))
    elif cfg.algorithm == "fedpm":
        local_pm = jax.jit(partial(fedpm_local, loss_fn, lr=cfg.lr))
        noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)
        w_frozen = gen_noise(jax.random.key(cfg.seed), w, noise_cfg)
        scores_global = jax.tree_util.tree_map(jnp.zeros_like, w)
    elif cfg.algorithm == "fedsparsify":
        local_sp = jax.jit(partial(fedsparsify_local, loss_fn, lr=cfg.lr,
                                   frac=cfg.sparsify_frac))
    else:
        local_sgd = jax.jit(partial(sgd_local_update, loss_fn, lr=cfg.lr))
        # signsgd/topk: the CODEC is the compression (encode quantizes) —
        # same as the fused engines; stochastic quantizers still
        # roundtrip per client before the DenseCodec transport
        compressor = (None if cfg.algorithm in ("fedavg",)
                      + _CODEC_COMPRESSORS else
                      make_compressor(cfg.algorithm,
                                      topk_frac=cfg.topk_frac,
                                      qsgd_bits=cfg.qsgd_bits,
                                      noise=mrn_cfg.noise))
        if compressor is not None:
            comp_fn = jax.jit(compressor.roundtrip)

    if valid is not None:
        valid = np.asarray(valid)
        if valid.shape != tuple(schedule.shape):
            raise ValueError(
                f"valid mask shape {valid.shape} does not match schedule "
                f"shape {tuple(schedule.shape)}")
    history["participation_round"] = []
    residuals: Dict[int, Pytree] = {}
    t0 = time.time()
    for rnd in range(cfg.rounds):
        # the reference loop GENUINELY excludes dropped clients — no
        # masked zero-weight rows — which is what the masked fused
        # engines are parity-tested against
        if valid is None:
            picked = schedule[rnd]
        else:
            picked = [int(c) for k, c in enumerate(schedule[rnd])
                      if valid[rnd][k]]
            if not picked:
                raise ValueError(
                    f"round {rnd} has zero surviving clients — lower "
                    "dropout or enable avail_resample")
        history["participation_round"].append(len(picked))
        weights = [client_weights[c] for c in picked]
        weights_dev = jnp.asarray(weights, jnp.float32)
        losses = []

        if cfg.algorithm in ("fedmrn", "fedmrns"):
            results = []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                noise_id = 0 if cfg.shared_noise else int(cid)
                res = local(w, batches, round_idx=rnd, client_id=noise_id,
                            train_key=jax.random.fold_in(
                                jax.random.key(cfg.seed + 1),
                                rnd * 1000 + int(cid)),
                            init_residual=residuals.get(int(cid)))
                if cfg.error_feedback:
                    residuals[int(cid)] = res.residual
                results.append(res)
                losses.append(float(res.losses[-1]))
            # clients already ship the wire format: stack it directly
            msg = WireMsg(codec.name, {
                "words": jnp.stack([r.packed_mask for r in results]),
                "seed": jnp.stack([jax.random.key_data(r.seed_key)
                                   for r in results])})
            w = aggregate_apply(msg, weights_dev, w,
                                round_idx=jnp.int32(rnd))

        elif cfg.algorithm == "fedpm":
            masks_all = []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                masks, ls = local_pm(
                    w_frozen, scores_global, batches,
                    key=jax.random.fold_in(jax.random.key(cfg.seed + 2),
                                           rnd * 1000 + int(cid)))
                masks_all.append(masks)
                losses.append(float(ls[-1]))
            K = len(masks_all)
            msg = encode({"mask": stack_client_batches(masks_all)})
            # vote counts, client_weights ignored — see _fedpm_body
            m_sum = aggregate(msg, jnp.ones((K,), jnp.float32),
                              round_idx=jnp.int32(rnd))
            # Beta(1,1)-posterior estimate — see algorithms._fedpm_body
            # (clamped under privacy: noisy counts can leave [0, K])
            probs, scores_global = fedpm_posterior(
                m_sum, float(K), clamp=cfg.privacy is not None)
            w = jax.tree_util.tree_map(
                lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)

        elif cfg.algorithm == "fedsparsify":
            ws = []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                w_local, ls = local_sp(w, batches)
                ws.append(w_local)
                losses.append(float(ls[-1]))
            msg = encode({"value": stack_client_batches(ws)})
            agg = aggregate(msg, weights_dev)
            w = jax.tree_util.tree_map(lambda p, a: a.astype(p.dtype),
                                       w, agg)

        else:  # fedavg + post-training compressors
            updates, ckeys = [], []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                u, ls = local_sgd(w, batches)
                ckey = jax.random.fold_in(jax.random.key(cfg.seed + 3),
                                          rnd * 1000 + int(cid))
                if compressor is not None:
                    u = comp_fn(u, ckey)
                updates.append(u)
                ckeys.append(ckey)
                losses.append(float(ls[-1]))
            payload = {"value": stack_client_batches(updates)}
            if codec.needs_key:
                # quantization happens inside encode (same keys the
                # in-body roundtrip would have used)
                payload["key"] = jax.random.wrap_key_data(jnp.stack(
                    [jax.random.key_data(k) for k in ckeys]))
            msg = encode(payload)
            w = jax.tree_util.tree_map(mix_add, w,
                                       aggregate(msg, weights_dev))

        history["local_loss"].append(float(np.mean(losses)))
        # measured per-round wire bits: what the stacked message occupies
        history["uplink_bits_round"].append(codec.round_bits(msg))
        if rnd % eval_every == 0 or rnd == cfg.rounds - 1:
            history["acc"].append(float(eval_fn(w)))
            history["round"].append(rnd)
    # one jitted local-update dispatch per (round, surviving client) —
    # the engine overhead the batched/scan drivers collapse
    history["num_dispatches"] = int(sum(history["participation_round"]))
    history["wall_s"] = time.time() - t0
    history["final_acc"] = history["acc"][-1]
    from .api import dp_epsilon_schedule          # lazy, one-way (like shim)
    eps, delta = dp_epsilon_schedule(cfg, history["participation_round"],
                                     history["params"])
    history["dp_epsilon"] = list(eps)
    history["dp_delta"] = delta
    return history
