"""Registry-driven pod rounds — any FL algorithm as a single pjit program
on the production mesh (the paper's protocol mapped onto pod hardware,
DESIGN.md §3).

Clients = slices of one mesh axis ('pod' when multi-pod — cross-silo FL
between pods over the slow inter-pod links — else 'data').  The round is
the SAME pure body every simulation engine runs — whatever
:class:`~repro.fed.algorithms.Algorithm` is registered under the chosen
name builds it — lowered with the stacked client axis partitioned over
the client mesh axis:

  1. the body vmaps the K selected clients over the stacked axis; XLA
     partitions the vmapped dim over the client mesh axis, so clients
     train in parallel, tensor/ZeRO-parallel *within* their slice;
  2. each family's own uplink CODEC lowers under the mesh:
     :class:`~repro.fed.codecs.MaskCodec` families (fedmrn/fedmrns,
     fedpm) aggregate mask bits — when the codec is count-aggregatable
     (fedpm, or fedmrn with ``shared_noise``, the pod default) and the
     round weights are uniform, ``make_pod_round`` switches the config
     to ``int_mask_agg``: the server sum Σ_k m_k is reduced in the
     minimal integer dtype holding ``⌈log2(K+1)⌉`` bits
     (``codecs.min_count_dtype``), so the cross-client all-reduce moves
     int8/int16 mask counts instead of f32 — a ≥4× collective-byte cut
     at simulation K, verified against the compiled HLO in
     ``tests/test_sharded_engine.py``; dense-codec families (fedavg +
     compressors, fedsparsify) all-reduce f32 updates;
  3. cross-round state (EF residuals, fedpm scores) flows through the
     ``state`` pytree exactly as on the scan engine.

Because the pod program and the simulation engines share one round body,
pod trajectories are ≡ the scan engine's at fixed seed/schedule/batches
(``tests/test_sharded_engine.py`` asserts it to 1e-6 on 8 fake CPU
devices) — there is no pod-only algorithm fork left to drift.

``PodRoundSpec(rounds=R)`` lowers an R-round ``lax.scan`` over the round
body — the pod-path mirror of the simulation engine's multi-round
experiment program — reusing one batch stream across rounds (dry-run
semantics, for probing multi-round HLO and collective totals).  All
hyper-parameters come from the spec's :class:`FLConfig` — the same
config object every other engine consumes — so pod train/noise keys are
derived by the registered algorithm itself, never duplicated here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..sharding.rules import param_shardings
from .algorithms import (ALGORITHMS, Algorithm, FLConfig, get_algorithm,
                         register_algorithm)
from .codecs import MaskCodec
from .engine import normalize_round_outputs

Pytree = Any

# the dry-run probe trains S=2 local steps (linear in FLOPs, enough to
# exercise the scan) — everything else keeps the FLConfig defaults
POD_PROBE_CONFIG = FLConfig(local_steps=2)


@dataclasses.dataclass(frozen=True)
class PodRoundSpec:
    """What the pod program runs: an :class:`FLConfig` + fusion depth.

    ``config`` is the SAME config type every simulation engine takes —
    local steps, lr, noise, seed, backend, shared_noise all live there
    and are interpreted by the registered algorithm (no pod-side
    duplicate defaults).  ``rounds > 1`` fuses a multi-round ``lax.scan``
    over the round body into one dispatch (same fusion as the scan
    engine), with per-round keys; the batch stream is reused across
    rounds (dry-run semantics).
    """

    config: FLConfig = POD_PROBE_CONFIG
    rounds: int = 1

    def resolved(self, algorithm: Union[str, Algorithm, None]) -> FLConfig:
        """The config with the ``make_pod_round`` algorithm applied."""
        if algorithm is None:
            return self.config
        name = (algorithm.name if isinstance(algorithm, Algorithm)
                else algorithm)
        return dataclasses.replace(self.config, algorithm=name)


def client_axis_of(mesh) -> str:
    return "pod" if "pod" in mesh.shape else "data"


def pod_param_shardings(p_specs: Pytree, mesh, *, num_layers: int,
                        encoder_layers: int = 0) -> Pytree:
    """Param shardings for the pod round: ZeRO minus the client axis.

    Params must NOT be zero-sharded over the client axis (each client
    needs the full model in its slice), so ZeRO uses the remaining data
    axes only.
    """
    client_axis = client_axis_of(mesh)
    fsdp = tuple(a for a in ("pod", "data")
                 if a in mesh.shape and a != client_axis)
    return param_shardings(p_specs, mesh, num_layers=num_layers,
                           encoder_layers=encoder_layers, zero=bool(fsdp),
                           fsdp_axes=fsdp)


def pod_batch_specs(batch_specs: Dict[str, Any], num_clients: int,
                    local_steps: int) -> Dict[str, Any]:
    """Split a global-batch spec into per-client local streams.

    ``(B, ...)`` → ``(K, S, b_local, ...)`` with ``b_local = B // (K·S)``
    (floor, min 1) — the round bodies' input contract: a stacked client
    axis of S-step local batch stacks.
    """
    def split(s):
        B = s.shape[0]
        b_local = max(1, B // (num_clients * local_steps))
        return jax.ShapeDtypeStruct(
            (num_clients, local_steps, b_local) + s.shape[1:], s.dtype)

    return {k: split(v) for k, v in batch_specs.items()}


def _replicated(mesh, tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


def _state_shardings(mesh, state_specs: Pytree, cfg: FLConfig,
                     client_axis: str) -> Pytree:
    """Client-stacked state leaves shard over the client axis; the rest
    (fedpm scores, any global pytree) replicate.

    A hint, not a contract: leaves whose leading dim is the client count
    (EF residual stacks) are the only ones that grow with clients.
    """
    D = mesh.shape[client_axis]

    def shard_one(s):
        shape = jnp.shape(s)
        if len(shape) >= 1 and shape[0] == cfg.num_clients \
                and shape[0] % D == 0:
            return NamedSharding(mesh, P(client_axis))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(shard_one, state_specs)


def make_pod_round(
    algorithm: Union[str, Algorithm, None],
    mesh,
    spec: PodRoundSpec = PodRoundSpec(),
    *,
    loss_fn: Callable[[Pytree, Any], jax.Array],
    p_specs: Pytree,
    p_shard: Optional[Pytree] = None,
    batch_specs: Pytree,
    client_weights: Optional[Any] = None,
    int_mask_agg: Optional[bool] = None,
) -> Tuple[Callable, Tuple, Tuple]:
    """Lower any registered algorithm's round body as a pod program.

    Returns ``(step, arg_specs, in_shardings)`` for ``jit`` + ``lower``:

      step(w, state, batches, picked, round_idx)
          -> (new_w, new_state, losses)

    ``batches`` is the stacked-client pytree the round bodies consume —
    ``(K, S, B, ...)`` leaves with ``K = cfg.clients_per_round`` — and is
    sharded over the client mesh axis (which must divide K).  ``picked``
    is the ``(K,)`` int32 client-id vector (``arange(K)`` for the probe,
    a schedule row for trajectory runs), ``round_idx`` a scalar int32.
    With ``spec.rounds > 1`` the round body is scanned ``rounds`` times
    starting at ``round_idx`` (losses gain a leading round axis) and the
    same ``batches`` feed every round — a cost/sharding probe, not
    training.

    ``p_shard`` defaults to fully-replicated params (fine for tests /
    small models); pass :func:`pod_param_shardings` for the production
    ZeRO layout.  ``client_weights`` (one float per ``cfg.num_clients``)
    reproduces the simulation engines' weighted aggregation — the round
    weights are gathered as ``weights_all[picked]``, exactly like the
    scan engine's chunk body; None means uniform.  State specs are
    derived from the algorithm's own ``init_state`` via ``eval_shape`` —
    nothing is materialised here.

    ``int_mask_agg`` controls the mask-count wire format on the server
    side: ``None`` (default) auto-enables the ``⌈log2(K+1)⌉``-bit
    integer aggregate whenever the algorithm's codec is a
    count-aggregatable :class:`~repro.fed.codecs.MaskCodec` and the
    weights are uniform; ``False`` forces the f32 reference aggregation
    (the benchmark baseline); ``True`` requires a count-aggregatable
    family and uniform weights (raises otherwise).

    Like :class:`~repro.fed.api.ExperimentSpec`, an unregistered
    :class:`Algorithm` instance auto-registers; an instance whose name is
    taken by a DIFFERENT plugin raises instead of silently running the
    registered one.
    """
    if isinstance(algorithm, Algorithm):
        existing = ALGORITHMS.get(algorithm.name)
        if existing is None:
            register_algorithm(algorithm)
        elif existing is not algorithm:
            raise ValueError(
                f"algorithm name {algorithm.name!r} is already registered "
                "by a different plugin")
    cfg = spec.resolved(algorithm)
    algo = get_algorithm(cfg.algorithm)
    if (cfg.int_mask_agg or int_mask_agg) and client_weights is not None:
        raise ValueError(
            "int_mask_agg requires uniform client weights "
            "(client_weights=None)")
    if cfg.privacy is not None:
        # the DP release is defined over the five simulation engines'
        # partial/finalize chain; the pod lowering has no parity oracle
        # for the noisy count wire yet, so refuse rather than emit an
        # unaudited release
        raise ValueError(
            "privacy= is not supported by make_pod_round — run DP "
            "experiments on engine='scan', 'batched', 'looped', "
            "'cohort' or 'service'")
    codec = algo.codec(cfg, p_specs)
    count_ok = (isinstance(codec, MaskCodec) and codec.count_aggregatable)
    if int_mask_agg is None:
        # pod default: mask families whose server sum is a pure count
        # (fedpm, fedmrn with shared noise) aggregate in the minimal
        # integer dtype holding ⌈log2(K+1)⌉ bits — the cross-client
        # all-reduce then moves int8/int16 mask counts instead of f32;
        # an explicit cfg.int_mask_agg is honoured (and validated below)
        int_mask_agg = (cfg.int_mask_agg
                        or (client_weights is None and count_ok))
    if int_mask_agg and not count_ok:
        # must fail loudly: a dense codec never reads the flag, so the
        # caller would silently measure the ordinary f32 all-reduce
        raise ValueError(
            f"int_mask_agg=True but {cfg.algorithm!r}'s codec "
            f"({type(codec).__name__}) is not a count-aggregatable "
            "MaskCodec (needs mask uplink, and shared_noise for fedmrn)")
    if bool(int_mask_agg) != cfg.int_mask_agg:
        cfg = dataclasses.replace(cfg, int_mask_agg=bool(int_mask_agg))
    cfg.validate()
    algo.validate(cfg)

    client_axis = client_axis_of(mesh)
    D = mesh.shape[client_axis]
    K = cfg.clients_per_round
    if K % D:
        raise ValueError(
            f"clients_per_round={K} must be divisible by the client mesh "
            f"axis {client_axis!r} (size {D})")
    for k, leaf in jax.tree_util.tree_leaves_with_path(batch_specs):
        if jnp.shape(leaf)[0] != K:
            raise ValueError(
                f"batch leaf {k} has leading dim {jnp.shape(leaf)[0]}, "
                f"expected the stacked client axis K={K} "
                "(see pod_batch_specs)")

    round_body = algo.make_round_body(loss_fn, cfg, p_specs)
    state_specs = jax.eval_shape(lambda p: algo.init_state(cfg, p), p_specs)
    seed = jnp.int32(cfg.seed)
    if client_weights is None:
        weights_all = jnp.ones((cfg.num_clients,), jnp.float32)
    else:
        cw = [float(x) for x in client_weights]
        if len(cw) != cfg.num_clients:
            # must fail here: weights_all[picked] inside jit would
            # silently CLAMP out-of-range client ids instead of raising
            raise ValueError(
                f"client_weights has {len(cw)} entries, cfg expects "
                f"{cfg.num_clients}")
        weights_all = jnp.asarray(cw, jnp.float32)

    def step(w, state, batches, picked, round_idx):
        weights = weights_all[picked]
        if spec.rounds == 1:
            w, state, losses, _ = normalize_round_outputs(
                round_body(seed, w, state, batches, picked, round_idx,
                           weights), 0.0)
            return w, state, losses

        def body(carry, r):
            w_c, state_c = carry
            w_c, state_c, losses, _ = normalize_round_outputs(
                round_body(seed, w_c, state_c, batches, picked, r,
                           weights), 0.0)
            return (w_c, state_c), losses

        rs = round_idx + jnp.arange(spec.rounds, dtype=jnp.int32)
        (w, state), losses = jax.lax.scan(body, (w, state), rs)
        return w, state, losses            # losses: (rounds, K, S)

    if p_shard is None:
        p_shard = _replicated(mesh, p_specs)
    b_shard = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P(client_axis)), batch_specs)
    s_shard = _state_shardings(mesh, state_specs, cfg, client_axis)

    arg_specs = (p_specs, state_specs, batch_specs,
                 jax.ShapeDtypeStruct((K,), jnp.int32),
                 jax.ShapeDtypeStruct((), jnp.int32))
    in_shardings = (p_shard, s_shard, b_shard,
                    NamedSharding(mesh, P(client_axis)),
                    NamedSharding(mesh, P()))
    return step, arg_specs, in_shardings
