"""FedMRN as a single pjit program on the production mesh — the paper's
protocol mapped onto pod hardware (DESIGN.md §3).

Clients = slices of one mesh axis ('pod' when multi-pod — cross-silo FL
between pods over the slow inter-pod links — else 'data').  One round:

  1. every client runs S local SGD steps on its update copy ``u`` with PSM
     masking in the forward pass (vmap over the client axis; XLA partitions
     the vmapped dim over the client mesh axis, so clients train in
     parallel, tensor/ZeRO-parallel *within* their slice);
  2. clients sample final masks and bit-pack them along each leaf's last
     dim (sharding-preserving) — the packed uint32 payload IS the uplink;
  3. the payload is all-gathered along the client axis (1 bit/param on the
     wire — vs 32 for FedAvg's float all-reduce, directly visible in the
     HLO collective bytes);
  4. every shard regenerates each client's noise for the slice it owns
     (seed → noise is deterministic, Eq. 5) and accumulates
     w += mean_c G(s_c) ⊙ m_c.

The per-client local computation is the SAME round-program code the
simulation engine vmaps (``core.fedmrn.psm_local_train`` /
``sample_final_mask``), parameterised by :class:`PodRoundSpec` instead of
hardcoded hyper-parameters; only the collective choreography (last-dim
packing, client-axis all-gather, per-shard noise regen) is pod-specific.

``mode='fedavg'`` lowers the float-aggregation baseline for the roofline
comparison.  ``PodRoundSpec(rounds=R)`` lowers an R-round ``lax.scan``
over the round body — the pod-path mirror of the simulation engine's
multi-round experiment program — with per-round seed/noise keys, for
probing multi-round HLO and collective totals.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.fedmrn import (FedMRNConfig, final_mask_key, mix_add,
                           psm_local_train, sample_final_mask)
from ..core.noise import NoiseConfig, client_round_key, gen_noise
from ..core.packing import pack_lastdim, unpack_lastdim
from ..sharding.rules import param_shardings

Pytree = Any

LOCAL_STEPS = 2          # S for the dry-run round (linear in FLOPs)
NOISE = NoiseConfig(dist="uniform", alpha=1e-2)


@dataclasses.dataclass(frozen=True)
class PodRoundSpec:
    """Round hyper-parameters for the pod program (was hardcoded)."""

    local_steps: int = LOCAL_STEPS
    lr: float = 0.1
    noise: NoiseConfig = NOISE
    mask_mode: str = "binary"
    base_seed: int = 0
    backend: str | None = None     # masking/packing kernel backend
    # rounds fused per dispatch: >1 lowers a multi-round ``lax.scan`` over
    # the round body (same fusion the simulation scan engine uses), with
    # per-round seed/noise keys — for probing multi-round HLO/collectives;
    # the batch stream is reused across rounds (dry-run semantics)
    rounds: int = 1

    def fedmrn_config(self) -> FedMRNConfig:
        return FedMRNConfig(mask_mode=self.mask_mode, noise=self.noise,
                            lr=self.lr, backend=self.backend)


def client_axis_of(mesh) -> str:
    return "pod" if "pod" in mesh.shape else "data"


def _shift_spec(ns: NamedSharding, client_axis: str, mesh) -> NamedSharding:
    """Prepend the client axis to a param sharding (for u/masks/noise)."""
    spec = list(ns.spec) if ns.spec else []
    # params in fedmrn mode are zero-sharded over remaining data axes only;
    # drop any use of the client axis inside the param dims
    spec = [None if s == client_axis
            else (tuple(x for x in s if x != client_axis) or None
                  if isinstance(s, tuple) else s)
            for s in spec]
    return NamedSharding(mesh, P(client_axis, *spec))


def make_fedmrn_pod_step(model, mesh, p_specs, p_shard, batch_specs,
                         b_shard, *, mode: str = "fedmrn",
                         spec: PodRoundSpec = PodRoundSpec()):
    """Returns (step_fn, arg_specs, in_shardings) for jit+lower."""
    cfg = model.cfg
    client_axis = client_axis_of(mesh)
    C = mesh.shape[client_axis]
    mrn = spec.fedmrn_config()
    S = spec.local_steps

    # params must NOT be zero-sharded over the client axis (each client
    # needs the full model in its slice) — reshard with fsdp minus client
    fsdp = tuple(a for a in ("pod", "data")
                 if a in mesh.shape and a != client_axis)
    p_shard = param_shardings(
        p_specs, mesh, num_layers=cfg.num_layers,
        encoder_layers=cfg.encoder_layers, zero=bool(fsdp), fsdp_axes=fsdp)

    u_specs = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((C,) + s.shape, jnp.float32)
        if jnp.issubdtype(s.dtype, jnp.floating) else
        jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), p_specs)
    u_shard = jax.tree_util.tree_map(
        lambda ns: _shift_spec(ns, client_axis, mesh), p_shard)

    # split the global batch into (C, S_local, b_local, ...) local streams
    def split_batch_spec(s):
        B = s.shape[0]
        b_local = max(1, B // (C * S))
        return jax.ShapeDtypeStruct((C, S, b_local) + s.shape[1:], s.dtype)

    fb_specs = {k: split_batch_spec(v) for k, v in batch_specs.items()
                if k != "positions3"}
    fb_shard = {k: NamedSharding(mesh, P(client_axis, None, None))
                for k in fb_specs}

    def one_client_update(u_c, batch_c, client_id, w, round_idx):
        """S local steps of SGD on u with PSM — the shared Alg. 1 body."""
        seed_key = client_round_key(spec.base_seed, round_idx, client_id)
        noise = gen_noise(seed_key, w, mrn.noise)
        train_key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(spec.base_seed + 1),
                               round_idx), client_id)

        if mode == "fedmrn":
            u_c, losses = psm_local_train(model.loss_fn, w, batch_c, noise,
                                          train_key, cfg=mrn, u0=u_c)
            m = sample_final_mask(u_c, noise, final_mask_key(train_key, S),
                                  cfg=mrn)
            return m, losses.mean(), noise

        # fedavg baseline: same scan shape, no masking
        def local_step(u, batch):
            def fwd(u_):
                wc = jax.tree_util.tree_map(mix_add, w, u_)
                return model.loss_fn(wc, batch)

            loss, g = jax.value_and_grad(fwd)(u)
            u = jax.tree_util.tree_map(
                lambda a, gi: a - spec.lr * gi, u, g)
            return u, loss

        u_c, losses = jax.lax.scan(local_step, u_c, batch_c)
        return u_c, losses.mean(), noise

    def one_round(w, u, batch, round_idx):
        client_ids = jnp.arange(C)
        out, losses, _ = jax.vmap(
            lambda u_c, b_c, cid: one_client_update(u_c, b_c, cid, w,
                                                    round_idx)
        )(u, batch, client_ids)

        if mode == "fedmrn":
            # ---- uplink: bit-packed masks, all-gathered over clients -------
            payload = jax.tree_util.tree_map(
                lambda m: pack_lastdim(m > 0), out)
            payload = jax.tree_util.tree_map(
                lambda words, ns: jax.lax.with_sharding_constraint(
                    words, NamedSharding(mesh, P(None, *ns.spec))),
                payload, p_shard)   # replicate client axis == all-gather

            # ---- server: regen noise per client, Eq. (5) --------------------
            def srv_body(acc, cid):
                key = client_round_key(spec.base_seed, round_idx, cid)
                noise_c = gen_noise(key, w, mrn.noise)
                u_hat = jax.tree_util.tree_map(
                    lambda words, wl, nl: nl * unpack_lastdim(
                        words[cid], wl.shape[-1]).astype(nl.dtype),
                    payload, w, noise_c)
                acc = jax.tree_util.tree_map(jnp.add, acc, u_hat)
                return acc, None

            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), w)
            agg, _ = jax.lax.scan(srv_body, acc0, jnp.arange(C))
        else:
            # FedAvg: float updates cross the wire (mean over client axis
            # → XLA all-reduce of f32) — the 32 bpp baseline
            agg = jax.tree_util.tree_map(
                lambda uc: jnp.sum(uc.astype(jnp.float32), axis=0), out)

        new_w = jax.tree_util.tree_map(
            lambda p, a: mix_add(p, a / C), w, agg)
        return new_w, losses.mean()

    def step(w, u, batch):
        if spec.rounds == 1:
            return one_round(w, u, batch, jnp.int32(0))

        # multi-round program: scan the round body, fresh u (=input copy,
        # normally zeros) and per-round keys each round; the same batch
        # stream feeds every round (cost/sharding probe, not training)
        def body(w_c, round_idx):
            w_c, loss = one_round(w_c, u, batch, round_idx)
            return w_c, loss

        w_final, losses = jax.lax.scan(
            body, w, jnp.arange(spec.rounds, dtype=jnp.int32))
        return w_final, losses.mean()

    args = (p_specs, u_specs, fb_specs)
    in_shardings = (p_shard, u_shard, fb_shard)
    return step, args, in_shardings
