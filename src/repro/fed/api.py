"""Declarative Experiment API — specs in, typed results out.

The user-facing surface of the federated engine stack:

  :class:`ExperimentSpec`   what to run — algorithm (registry name or
                            :class:`~repro.fed.algorithms.Algorithm`
                            plugin), :class:`FLConfig`, a device-resident
                            :class:`~repro.data.FederatedDataset`, and
                            model refs (``loss_fn`` + optional
                            ``eval_apply`` from which the on-device eval
                            program is auto-wired over the test split).
  :class:`Experiment`       the facade: ``run()`` executes the spec on
                            any engine (scan by default — one jitted
                            program per chunk) and returns a frozen
                            :class:`RunResult`; ``sweep()`` runs a
                            multi-seed axis as ONE vmapped program
                            (S seeds resident per dispatch, one compile)
                            with a host-loop fallback, optionally crossed
                            with a config ``grid``, returning a
                            :class:`SweepResult`.
  :class:`RunResult`        typed per-run trajectories (acc / loss /
                            uplink bits / schedule / wall time) with an
                            engine-independent ``to_history()`` dict whose
                            key schema (:data:`HISTORY_KEYS`) is identical
                            across scan / batched / looped.

Example::

    spec = ExperimentSpec(loss_fn=cnn_loss, params=params, data=ds,
                          config=FLConfig(algorithm="fedmrn", rounds=30),
                          eval_apply=cnn_apply, eval_every=5)
    exp = Experiment(spec)
    result = exp.run()                        # RunResult, scan engine
    sweep = exp.sweep(seeds=8)                # one vmapped program
    mean, std = sweep.point.mean_std()

Compiled scan/sweep programs are cached on the :class:`Experiment`
(keyed by config with the seed normalised out — the seed is a *traced*
argument), so repeated scan ``run()``/``sweep()`` calls and host-loop
sweep fallbacks never pay a second compile.  The batched/looped
reference engines rebuild their per-round programs each ``run()`` call
(they exist for parity and benchmarks, not repeated driving).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from functools import partial
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tree_num_params
from ..core.comm import CommRecord
from ..core.evaluation import make_eval_program
from ..data.federated import CohortedDataset, FederatedDataset
from .algorithms import (ALGORITHMS, Algorithm, FLConfig, algorithm_codec,
                         get_algorithm, register_algorithm, uplink_bits)
from .availability import (AvailabilityTrace, check_engine_support,
                           make_availability, require_survivors)
from .codecs import UplinkCodec
from .engine import (eval_round_indices, make_client_schedule,
                     make_cohort_engine, make_seeded_experiment_program,
                     make_sharded_sweep_program, make_sweep_program,
                     sweep_device_count)

Pytree = Any

ENGINES = ("scan", "cohort", "service", "batched", "looped")

# engine="cohort" shards the population into cohorts of this many clients
# when the caller passes neither a CohortedDataset nor cohort_size=
DEFAULT_COHORT_SIZE = 256

# The engine-independent history schema: every engine's to_history() dict
# has EXACTLY these keys (golden-tested in tests/test_experiment_api.py).
HISTORY_KEYS = frozenset({
    "algorithm", "engine", "acc", "round", "local_loss",
    "uplink_bits_per_client", "uplink_bits_round", "params",
    "participation_round", "schedule", "num_dispatches", "wall_s",
    "final_acc", "dp_epsilon", "dp_delta",
})


# ---------------------------------------------------------------------------
# typed results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunResult:
    """One experiment's trajectories — frozen, engine-independent.

    ``num_dispatches`` counts the jitted round/chunk programs the driver
    dispatched: ⌈R/chunk⌉ for scan, R for batched, R·K for looped.
    """

    algorithm: str
    engine: str
    config: FLConfig
    seed: int
    eval_rounds: Tuple[int, ...]
    acc: Tuple[float, ...]                 # one entry per eval round
    local_loss: Tuple[float, ...]          # one entry per round
    uplink_bits_round: Tuple[float, ...]   # measured K-client wire bits
    #   per round: summed encoded WireMsg buffer sizes, same on every
    #   engine (NOT a precomputed estimate)
    uplink_bits_per_client: int
    num_params: int
    schedule: np.ndarray                   # (R, K) int32 client selection
    num_dispatches: int
    wall_s: float
    participation_round: Tuple[int, ...] = ()   # surviving clients per
    #   round; K everywhere unless an availability trace / fault plan
    #   degraded a round
    dp_epsilon: Tuple[float, ...] = ()     # cumulative (ε, δ)-DP spend
    #   after each round, accounted at the TRUE recorded participation;
    #   all-inf when cfg.privacy is None
    dp_delta: float = 0.0                  # the δ the ε column is at

    @property
    def final_acc(self) -> float:
        return self.acc[-1]

    @property
    def total_uplink_bits(self) -> float:
        return float(sum(self.uplink_bits_round))

    def to_history(self) -> Dict[str, Any]:
        """The legacy ``run_federated`` history dict (unified schema)."""
        return {
            "algorithm": self.algorithm,
            "engine": self.engine,
            "acc": list(self.acc),
            "round": list(self.eval_rounds),
            "local_loss": list(self.local_loss),
            "uplink_bits_per_client": self.uplink_bits_per_client,
            "uplink_bits_round": list(self.uplink_bits_round),
            "params": self.num_params,
            "participation_round": [int(p)
                                    for p in self.participation_round],
            "schedule": self.schedule,
            "num_dispatches": self.num_dispatches,
            "wall_s": self.wall_s,
            "final_acc": self.final_acc,
            "dp_epsilon": [float(e) for e in self.dp_epsilon],
            "dp_delta": float(self.dp_delta),
        }

    @classmethod
    def from_history(cls, cfg: FLConfig, engine: str,
                     hist: Mapping[str, Any]) -> "RunResult":
        return cls(
            algorithm=hist["algorithm"], engine=engine, config=cfg,
            seed=cfg.seed,
            eval_rounds=tuple(int(r) for r in hist["round"]),
            acc=tuple(float(a) for a in hist["acc"]),
            local_loss=tuple(float(x) for x in hist["local_loss"]),
            uplink_bits_round=tuple(float(b)
                                    for b in hist["uplink_bits_round"]),
            uplink_bits_per_client=int(hist["uplink_bits_per_client"]),
            num_params=int(hist["params"]),
            schedule=np.asarray(hist["schedule"]),
            num_dispatches=int(hist["num_dispatches"]),
            wall_s=float(hist["wall_s"]),
            participation_round=tuple(
                int(p) for p in hist.get(
                    "participation_round",
                    [cfg.clients_per_round] * cfg.rounds)),
            dp_epsilon=tuple(float(e) for e in hist.get("dp_epsilon", ())),
            dp_delta=float(hist.get("dp_delta", 0.0)))


def dp_epsilon_schedule(cfg: FLConfig, participation: Sequence[int],
                        num_params: int) -> Tuple[
                            Tuple[float, ...], float]:
    """Cumulative (ε, δ) spend per round at the TRUE participation.

    ``num_params`` is the dimension of the released count vector (the
    model's parameter count) — the accountant normalizes by the L2
    sensitivity at ``cfg.privacy.adjacency`` (Δ·√num_params for the
    default client adjacency).  Accounts every round at the
    participation actually recorded — availability dropouts and
    quorum-degraded service rounds spend LESS budget (smaller sampling
    fraction q = survivors / num_clients).  NOTE conditioning on
    realized dropouts assumes availability is independent of client
    data (true for the built-in traces/fault plans); pass the scheduled
    ``[clients_per_round] * rounds`` for the conditioning-free bound.
    Returns ``((inf,)*R, 0.0)`` when ``cfg.privacy`` is None.
    """
    if cfg.privacy is None:
        return (math.inf,) * len(tuple(participation)), 0.0
    from .privacy import dp_mask_mode, round_epsilons
    eps = round_epsilons(cfg.privacy, participation, cfg.num_clients,
                         dp_mask_mode(cfg.algorithm), num_params)
    return tuple(float(e) for e in eps), cfg.privacy.delta


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """All seeds of one grid point: per-seed runs + aggregate views."""

    overrides: Tuple[Tuple[str, Any], ...]   # config fields this point sets
    seeds: Tuple[int, ...]
    runs: Tuple[RunResult, ...]              # one per seed, same order

    @property
    def eval_rounds(self) -> Tuple[int, ...]:
        return self.runs[0].eval_rounds

    @property
    def acc(self) -> np.ndarray:             # (S, n_eval)
        return np.stack([np.asarray(r.acc) for r in self.runs])

    @property
    def local_loss(self) -> np.ndarray:      # (S, R)
        return np.stack([np.asarray(r.local_loss) for r in self.runs])

    @property
    def final_acc(self) -> np.ndarray:       # (S,)
        return np.asarray([r.final_acc for r in self.runs])

    def mean_std(self) -> Tuple[float, float]:
        fa = self.final_acc
        return float(fa.mean()), float(fa.std())

    def summary_row(self) -> Dict[str, Any]:
        mean, std = self.mean_std()
        return {**dict(self.overrides), "seeds": len(self.seeds),
                "final_acc_mean": round(mean, 4),
                "final_acc_std": round(std, 4)}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """A (grid ×) multi-seed sweep: per-seed trajectories + mean±std."""

    points: Tuple[SweepPoint, ...]
    seeds: Tuple[int, ...]
    vmapped: bool          # True: seeds ran as ONE vmapped program/point
    wall_s: float
    devices: int = 1       # >1: seed axis shard_map'd over this many devices

    def summary(self) -> List[Dict[str, Any]]:
        return [p.summary_row() for p in self.points]

    # ---- single-point conveniences (the seeds-only sweep) -------------

    @property
    def point(self) -> SweepPoint:
        if len(self.points) != 1:
            raise ValueError(
                f"sweep has {len(self.points)} grid points; index "
                ".points explicitly")
        return self.points[0]

    @property
    def runs(self) -> Tuple[RunResult, ...]:
        return self.point.runs

    @property
    def acc(self) -> np.ndarray:
        return self.point.acc

    @property
    def final_acc(self) -> np.ndarray:
        return self.point.final_acc


# ---------------------------------------------------------------------------
# the declarative spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Everything an experiment needs, declared up front.

    ``algorithm`` defaults to ``config.algorithm``; pass a registry name
    to override it, or an :class:`Algorithm` instance to run a plugin
    (auto-registered if its name is free).  Eval wiring, in precedence
    order: an explicit pure ``eval_program`` (params -> scalar metric);
    else ``eval_apply`` (params, x) -> logits, auto-wired into a batched
    on-device eval program over the dataset's test split; else — for the
    host-loop engines only — a Python ``eval_fn``.

    ``data`` is a device-resident :class:`FederatedDataset` (every
    engine) or a host-resident :class:`CohortedDataset` (the streaming
    ``engine="cohort"`` only — the other engines need the whole
    population device-resident).
    """

    loss_fn: Callable[[Pytree, Any], jax.Array]
    params: Pytree
    data: Union[FederatedDataset, CohortedDataset]
    config: FLConfig
    algorithm: Optional[Union[str, Algorithm]] = None
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None
    eval_apply: Optional[Callable[[Pytree, jax.Array], jax.Array]] = None
    eval_fn: Optional[Callable[[Pytree], float]] = None
    eval_batch_size: int = 256
    eval_every: int = 1
    client_weights: Optional[Tuple[float, ...]] = None
    # explicit availability trace; None derives one from the config's
    # availability/dropout/churn knobs (still None for "always")
    availability: Optional[AvailabilityTrace] = None

    def __post_init__(self):
        if self.client_weights is not None:
            object.__setattr__(self, "client_weights",
                               tuple(float(w) for w in self.client_weights))

    def resolved_config(self) -> FLConfig:
        """The config with any spec-level algorithm override applied."""
        cfg = self.config
        if self.algorithm is None:
            return cfg
        name = (self.algorithm.name if isinstance(self.algorithm, Algorithm)
                else self.algorithm)
        return dataclasses.replace(cfg, algorithm=name)


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

class Experiment:
    """Run / sweep an :class:`ExperimentSpec` on any engine."""

    def __init__(self, spec: ExperimentSpec):
        if not isinstance(spec.data, (FederatedDataset, CohortedDataset)):
            raise ValueError(
                "ExperimentSpec.data must be a device-resident "
                "FederatedDataset (see repro.data.make_federated_dataset) "
                "or a host-resident CohortedDataset for engine='cohort'; "
                "legacy host batch callbacks only work through the "
                "deprecated run_federated shim")
        self.spec = spec
        self.cfg = spec.resolved_config()
        if isinstance(spec.algorithm, Algorithm):
            existing = ALGORITHMS.get(spec.algorithm.name)
            if existing is None:
                register_algorithm(spec.algorithm)
            elif existing is not spec.algorithm:
                raise ValueError(
                    f"algorithm name {spec.algorithm.name!r} is already "
                    "registered by a different plugin")
        self.algorithm = get_algorithm(self.cfg.algorithm)
        self.cfg.validate()
        self.algorithm.validate(self.cfg)
        if spec.data.num_clients != self.cfg.num_clients:
            raise ValueError(
                f"dataset has {spec.data.num_clients} clients, cfg expects "
                f"{self.cfg.num_clients}")
        if (spec.client_weights is not None
                and len(spec.client_weights) != self.cfg.num_clients):
            raise ValueError(
                f"client_weights has {len(spec.client_weights)} entries, "
                f"cfg expects {self.cfg.num_clients}")
        self._programs: Dict[Any, Tuple[Callable, Pytree, Pytree]] = {}
        self._eval_prog: Optional[Callable] = None
        self._runners: Dict[Any, Any] = {}   # cohort/service engine cache
        self._cohorted: Dict[int, CohortedDataset] = {}   # per cohort size
        self.service_report = None   # last engine="service" wire report

    # ---- the wire format ----------------------------------------------

    def codec(self) -> UplinkCodec:
        """The algorithm's typed uplink codec for this spec's model —
        the same object the round bodies route payloads through."""
        return algorithm_codec(self.cfg, self.spec.params)

    def comm_record(self) -> CommRecord:
        """The codec's cost report: measured uplink bits (summed encoded
        ``WireMsg`` buffer sizes), the paper-style figure, and the f32
        downlink.  With ``config.privacy`` set, carries the PLANNED
        (ε, δ) after ``cfg.rounds`` full-participation rounds (a run's
        actual spend — at true participation — lives on the RunResult).
        """
        rec = self.codec().wire_bits(self.spec.params)
        if self.cfg.privacy is None:
            return rec
        eps, delta = dp_epsilon_schedule(
            self.cfg, [self.cfg.clients_per_round] * self.cfg.rounds,
            tree_num_params(self.spec.params))
        return dataclasses.replace(rec, dp_epsilon=eps[-1], dp_delta=delta)

    # ---- eval wiring --------------------------------------------------

    def eval_program(self) -> Optional[Callable[[Pytree], jax.Array]]:
        """The pure on-device eval program (auto-wired from the dataset).

        Built once and cached — auto-wiring wrap-pads a device copy of
        the whole test split, which should not be paid per run/grid point.
        """
        spec = self.spec
        if spec.eval_program is not None:
            return spec.eval_program
        if spec.eval_apply is not None:
            if self._eval_prog is None:
                if spec.data.x_test is None:
                    raise ValueError(
                        "eval_apply given but the dataset has no test "
                        "split; pass x_test/y_test to "
                        "make_federated_dataset or an explicit "
                        "eval_program")
                self._eval_prog = make_eval_program(
                    spec.eval_apply, spec.data.x_test, spec.data.y_test,
                    batch_size=spec.eval_batch_size)
            return self._eval_prog
        return None

    def _host_eval_fn(self) -> Callable[[Pytree], float]:
        if self.spec.eval_fn is not None:
            return self.spec.eval_fn
        prog = self.eval_program()
        if prog is None:
            raise ValueError("need eval_fn or eval_program")
        jitted = jax.jit(prog)
        return lambda p: float(jitted(p))

    # ---- availability --------------------------------------------------

    def _availability(self, cfg: FLConfig,
                      seed: Optional[int] = None
                      ) -> Optional[AvailabilityTrace]:
        """The run's availability trace: the spec's explicit trace, else
        one derived from the config knobs (None when always-available)."""
        if self.spec.availability is not None:
            return self.spec.availability
        return make_availability(cfg, seed)

    def _degrade_schedule(self, cfg: FLConfig, engine: str,
                          schedule: np.ndarray,
                          trace: Optional[AvailabilityTrace]):
        """Apply a trace to a schedule: optional dynamic resampling, the
        ``(R, K)`` valid mask, per-round participation.  Returns
        ``(schedule, valid, participation)`` — ``(schedule, None, None)``
        when no trace applies (the bitwise-invariant path)."""
        if trace is None:
            return schedule, None, None
        check_engine_support(cfg, trace, engine)
        if cfg.avail_resample:
            schedule = trace.resample_schedule(schedule, cfg.seed)
        valid = trace.valid_for(schedule)
        require_survivors(valid, resample_hint=cfg.avail_resample)
        participation = valid.sum(axis=1).astype(np.int64)
        return schedule, valid, participation

    # ---- program cache ------------------------------------------------

    def _program(self, kind: str, cfg: FLConfig, devices: int = 1):
        """Build-or-fetch the (seed-polymorphic) chunk/sweep program.

        The cache key normalises the seed out: seeds are traced arguments,
        so one compiled program serves every seed of a sweep AND every
        ``run(seed=...)`` override.  ``devices`` keys the sharded sweep
        variants (the mesh shape is baked into the program).
        """
        key = (kind, devices, dataclasses.replace(cfg, seed=0),
               self.spec.eval_every, self.spec.client_weights)
        if key not in self._programs:
            if kind == "sweep_sharded":
                maker = partial(make_sharded_sweep_program, devices=devices)
            elif kind == "sweep":
                maker = make_sweep_program
            else:
                maker = make_seeded_experiment_program
            prog = self.eval_program()
            if prog is None:
                raise ValueError(
                    "engine='scan' folds eval into the program and needs a "
                    "pure eval_program (params -> metric); pass "
                    "eval_program or eval_apply to ExperimentSpec (build "
                    "one with repro.core.make_eval_program)")
            self._programs[key] = maker(
                self.spec.loss_fn, cfg, self.spec.params, self.spec.data,
                eval_program=prog, eval_every=self.spec.eval_every,
                client_weights=self.spec.client_weights)
        return self._programs[key]

    # ---- run ----------------------------------------------------------

    def run(self, *, engine: str = "scan", seed: Optional[int] = None,
            chunk: Optional[int] = None,
            cohort_size: Optional[int] = None,
            prefetch: bool = True,
            service: Optional[Any] = None) -> RunResult:
        """Execute the spec once; returns a frozen :class:`RunResult`.

        ``engine="scan"`` (default) fuses the whole experiment into
        ⌈R/chunk⌉ jitted dispatches; ``"cohort"`` streams a
        larger-than-HBM population through the device cohort by cohort
        (``cohort_size`` clients staged at a time, default
        min(num_clients, 256); ``prefetch=False`` disables the
        double-buffered host→device overlap); ``"service"`` spawns a
        loopback HTTP coordinator plus K client threads that exchange
        framed ``WireMsg`` bytes (``service=`` takes a
        :class:`repro.fed.service.ServiceConfig` — sync barrier or async
        staleness-weighted rounds; the measured wire accounting lands on
        ``Experiment.service_report``); ``"batched"`` dispatches one
        program per round; ``"looped"`` is the per-client reference
        loop.  ``seed`` overrides ``config.seed`` without rebuilding
        programs.
        """
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (one of {ENGINES})")
        if service is not None and engine != "service":
            raise ValueError(
                f"service= only applies to engine='service', not "
                f"{engine!r}")
        if engine == "cohort":
            cfg = self.cfg if seed is None else dataclasses.replace(
                self.cfg, seed=int(seed))
            return self._run_cohort(cfg, cohort_size, prefetch)
        if cohort_size is not None:
            raise ValueError(
                f"cohort_size= only applies to engine='cohort', not "
                f"{engine!r}")
        if engine == "service":
            cfg = self.cfg if seed is None else dataclasses.replace(
                self.cfg, seed=int(seed))
            return self._run_service(cfg, service)
        if isinstance(self.spec.data, CohortedDataset):
            raise ValueError(
                f"engine={engine!r} needs the whole population "
                "device-resident (a FederatedDataset); a CohortedDataset "
                "only runs on engine='cohort'")
        cfg = self.cfg if seed is None else dataclasses.replace(
            self.cfg, seed=int(seed))
        if engine == "scan":
            return self._run_scan(cfg, chunk)
        return self._run_host_loop(cfg, engine)

    def _run_scan(self, cfg: FLConfig, chunk: Optional[int]) -> RunResult:
        run_chunk, state0, metrics0 = self._program("seeded", cfg)
        chunk = cfg.rounds if chunk is None else max(1, int(chunk))
        chunk = min(chunk, cfg.rounds)
        schedule = make_client_schedule(cfg)
        schedule, valid, participation = self._degrade_schedule(
            cfg, "scan", schedule, self._availability(cfg))
        sched_dev = jnp.asarray(schedule, jnp.int32)
        valid_dev = None if valid is None else jnp.asarray(valid,
                                                           jnp.float32)
        seed_dev = jnp.int32(cfg.seed)
        w, state, metrics = self.spec.params, state0, metrics0
        t0 = time.time()
        dispatches = 0
        for r0 in range(0, cfg.rounds, chunk):
            n = min(chunk, cfg.rounds - r0)
            if valid_dev is None:
                w, state, metrics = run_chunk(
                    seed_dev, w, state, metrics, jnp.int32(r0),
                    sched_dev[r0:r0 + n], n_rounds=n)
            else:
                w, state, metrics = run_chunk(
                    seed_dev, w, state, metrics, jnp.int32(r0),
                    sched_dev[r0:r0 + n], valid_dev[r0:r0 + n],
                    n_rounds=n)
            dispatches += 1
        # the ONLY device→host reads of the whole experiment
        result = self._result_from_metrics(
            cfg, "scan", metrics, schedule, dispatches, time.time() - t0,
            participation=participation)
        return result

    def _cohorted_data(self, cohort_size: Optional[int]) -> CohortedDataset:
        """The spec's data as a CohortedDataset (converted + cached)."""
        if isinstance(self.spec.data, CohortedDataset):
            if cohort_size is not None:
                raise ValueError(
                    "cohort_size= conflicts with a pre-built "
                    "CohortedDataset — the shard layout is fixed at "
                    "construction (make_cohorted_dataset / .cohorted)")
            return self.spec.data
        size = (min(self.spec.data.num_clients, DEFAULT_COHORT_SIZE)
                if cohort_size is None else int(cohort_size))
        if size not in self._cohorted:
            self._cohorted[size] = self.spec.data.cohorted(size)
        return self._cohorted[size]

    def _run_cohort(self, cfg: FLConfig, cohort_size: Optional[int],
                    prefetch: bool) -> RunResult:
        """The streaming cohort engine, through the same RunResult path.

        The runner cache is keyed like :meth:`_program` (seed normalised
        out — ``CohortRunner.run`` takes the seed at call time), plus the
        cohort layout; ``prefetch`` is a run-time toggle, not a cache key.
        """
        data = self._cohorted_data(cohort_size)
        prog = self.eval_program()
        if prog is None:
            raise ValueError(
                "engine='cohort' folds eval into its jitted dispatch "
                "sequence and needs a pure eval_program (params -> "
                "metric); pass eval_program or eval_apply to "
                "ExperimentSpec")
        key = ("cohort", id(data), dataclasses.replace(cfg, seed=0),
               self.spec.eval_every, self.spec.client_weights)
        if key not in self._runners:
            self._runners[key] = make_cohort_engine(
                self.spec.loss_fn, cfg, self.spec.params, data,
                eval_program=prog, eval_every=self.spec.eval_every,
                client_weights=self.spec.client_weights)
        runner = self._runners[key]
        t0 = time.time()
        trace = self._availability(cfg)
        if trace is None:
            metrics, schedule, dispatches = runner.run(seed=cfg.seed,
                                                       prefetch=prefetch)
            participation = None
        else:
            schedule, valid, participation = self._degrade_schedule(
                cfg, "cohort", make_client_schedule(cfg), trace)
            metrics, schedule, dispatches = runner.run(
                seed=cfg.seed, schedule=schedule, prefetch=prefetch,
                valid=valid)
        return self._result_from_metrics(
            cfg, "cohort", metrics, schedule, dispatches, time.time() - t0,
            participation=participation)

    def _run_service(self, cfg: FLConfig, service) -> RunResult:
        """The wire-true coordinator engine (loopback HTTP, ISSUE 8).

        The runner (jitted client step + server aggregation programs)
        is cached like the cohort runner; ``service`` — a
        :class:`repro.fed.service.ServiceConfig` — is a run-time knob
        (transport + sync/async round semantics), never a cache key.
        The run's measured wire accounting (:class:`ServiceReport`,
        incl. the MEASURED downlink ``CommRecord``) lands on
        ``self.service_report``.
        """
        from .service import make_service_engine
        prog = self.eval_program()
        if prog is None:
            raise ValueError(
                "engine='service' evaluates on the coordinator and "
                "needs a pure eval_program (params -> metric); pass "
                "eval_program or eval_apply to ExperimentSpec")
        key = ("service", dataclasses.replace(cfg, seed=0),
               self.spec.eval_every, self.spec.client_weights)
        if key not in self._runners:
            self._runners[key] = make_service_engine(
                self.spec.loss_fn, cfg, self.spec.params, self.spec.data,
                eval_program=prog, eval_every=self.spec.eval_every,
                client_weights=self.spec.client_weights)
        runner = self._runners[key]
        t0 = time.time()
        trace = self._availability(cfg)
        if trace is None:
            metrics, schedule, dispatches = runner.run(seed=cfg.seed,
                                                       service=service)
        else:
            schedule, valid, _ = self._degrade_schedule(
                cfg, "service", make_client_schedule(cfg), trace)
            metrics, schedule, dispatches = runner.run(
                seed=cfg.seed, service=service, schedule=schedule,
                valid=valid, local_steps=trace.local_steps)
        self.service_report = runner.report
        # the coordinator's measured per-round uplink counts — faults
        # and quorum-degraded rounds show up here, not just trace drops
        participation = (list(self.service_report.participation)
                         if self.service_report.participation else None)
        return self._result_from_metrics(
            cfg, "service", metrics, schedule, dispatches,
            time.time() - t0, participation=participation)

    def _result_from_metrics(self, cfg, engine, metrics, schedule,
                             dispatches, wall_s,
                             participation=None) -> RunResult:
        loss = np.asarray(metrics["loss"])
        acc = np.asarray(metrics["acc"])
        bits = np.asarray(metrics["uplink_bits"])
        rounds = eval_round_indices(cfg, self.spec.eval_every)
        if participation is None:
            participation = [cfg.clients_per_round] * cfg.rounds
        dp_eps, dp_delta = dp_epsilon_schedule(
            cfg, participation, tree_num_params(self.spec.params))
        return RunResult(
            algorithm=cfg.algorithm, engine=engine, config=cfg,
            seed=cfg.seed, eval_rounds=tuple(rounds),
            acc=tuple(float(acc[r]) for r in rounds),
            local_loss=tuple(float(x) for x in loss),
            uplink_bits_round=tuple(float(b) for b in bits),
            uplink_bits_per_client=uplink_bits(cfg, self.spec.params),
            num_params=tree_num_params(self.spec.params),
            schedule=schedule, num_dispatches=dispatches, wall_s=wall_s,
            participation_round=tuple(int(p) for p in participation),
            dp_epsilon=dp_eps, dp_delta=dp_delta)

    def _run_host_loop(self, cfg: FLConfig, engine: str) -> RunResult:
        from .simulation import _run_batched          # no import cycle:
        from .looped import run_federated_looped      # lazy, one-way
        schedule = make_client_schedule(cfg)
        schedule, valid, _ = self._degrade_schedule(
            cfg, engine, schedule, self._availability(cfg))
        batch_fn = self.spec.data.batch_fn(steps=cfg.local_steps,
                                           batch=cfg.batch_size)
        eval_fn = self._host_eval_fn()
        cw = (list(self.spec.client_weights)
              if self.spec.client_weights is not None else None)
        runner = (run_federated_looped if engine == "looped"
                  else _run_batched)
        hist = runner(self.spec.loss_fn, self.spec.params, batch_fn,
                      eval_fn, cfg, schedule=schedule,
                      eval_every=self.spec.eval_every, client_weights=cw,
                      valid=valid)
        result = RunResult.from_history(cfg, engine, hist)
        dp_eps, dp_delta = dp_epsilon_schedule(
            cfg, result.participation_round, result.num_params)
        return dataclasses.replace(result, dp_epsilon=dp_eps,
                                   dp_delta=dp_delta)

    # ---- sweep --------------------------------------------------------

    def sweep(self, seeds: Union[int, Sequence[int]] = 4, *,
              grid: Optional[Mapping[str, Sequence[Any]]] = None,
              vmapped: bool = True,
              sharding: Optional[str] = None,
              devices: Optional[int] = None,
              chunk: Optional[int] = None) -> SweepResult:
        """Run a multi-seed (× config-grid) sweep.

        ``seeds`` is either a count (seeds ``cfg.seed .. cfg.seed+S-1``)
        or an explicit sequence.  With ``vmapped=True`` (default) the S
        seeds of each grid point run as ONE vmapped scan program — one
        compile, S experiments resident per dispatch; ``vmapped=False``
        host-loops a single seed-polymorphic compiled program (the
        fallback, and the baseline the sweep benchmark compares against).
        ``sharding="devices"`` additionally spreads the seed axis over
        the local devices via ``shard_map`` (S/D seeds vmapped per
        device, still one compile, no collectives); ``devices`` pins the
        mesh size (default: the largest divisor of S that fits the
        machine — 1 degenerates to the plain vmapped program).  ``grid``
        maps FLConfig field names to value lists; the grid cross product
        is host-looped (axes like batch size change shapes, and closure
        constants like lr live outside the traced argument set), with
        seeds vmapped/sharded *within* each point.
        """
        if isinstance(self.spec.data, CohortedDataset):
            raise ValueError(
                "sweep() runs the vmapped scan programs, which need the "
                "whole population device-resident (a FederatedDataset); "
                "host-loop engine='cohort' runs via run() per seed")
        if sharding not in (None, "none", "devices"):
            raise ValueError(
                f"unknown sharding {sharding!r} (None or 'devices')")
        sharded = sharding == "devices"
        if sharded and not vmapped:
            raise ValueError(
                "sharding='devices' shards the vmapped program; it cannot "
                "combine with vmapped=False")
        if devices is not None and not sharded:
            raise ValueError(
                "devices= only applies to sharding='devices' — without it "
                "the argument would be silently ignored")
        if isinstance(seeds, (int, np.integer)):
            if seeds <= 0:
                raise ValueError(f"need at least one seed, got {seeds}")
            seed_list = tuple(self.cfg.seed + i for i in range(int(seeds)))
        else:
            seed_list = tuple(int(s) for s in seeds)
            if not seed_list:
                raise ValueError("need at least one seed")
        grid = dict(grid or {})
        for field in grid:
            if field not in {f.name for f in dataclasses.fields(FLConfig)}:
                raise ValueError(f"unknown FLConfig field {field!r} in grid")
        if "seed" in grid:
            raise ValueError(
                "the seed axis is not a grid field — pass seeds=[...] "
                "(a 'seed' grid would be silently shadowed by it)")
        points = [dict(zip(grid, vals))
                  for vals in itertools.product(*grid.values())] or [{}]

        if sharded:
            n_dev = (sweep_device_count(len(seed_list)) if devices is None
                     else int(devices))
            if n_dev < 1 or len(seed_list) % n_dev:
                raise ValueError(
                    f"{len(seed_list)} seeds do not divide over "
                    f"{n_dev} devices (pick devices dividing the seed "
                    "count, or omit it for auto)")
        else:
            n_dev = 1

        t0 = time.time()
        out = []
        for overrides in points:
            cfg = dataclasses.replace(self.cfg, **overrides)
            cfg.validate()
            get_algorithm(cfg.algorithm).validate(cfg)
            if cfg.num_clients != self.spec.data.num_clients:
                # must fail here: in-program client_idx[cid] gathers would
                # silently CLAMP out-of-range client ids, not raise
                raise ValueError(
                    f"grid point {overrides} sets num_clients="
                    f"{cfg.num_clients} but the dataset has "
                    f"{self.spec.data.num_clients} clients")
            runs = (self._sweep_point_vmapped(cfg, seed_list, chunk,
                                              devices=n_dev)
                    if vmapped else
                    self._sweep_point_host(cfg, seed_list, chunk))
            out.append(SweepPoint(
                overrides=tuple(sorted(overrides.items())),
                seeds=seed_list, runs=tuple(runs)))
        return SweepResult(points=tuple(out), seeds=seed_list,
                           vmapped=vmapped, wall_s=time.time() - t0,
                           devices=n_dev)

    def _sweep_point_vmapped(self, cfg: FLConfig, seeds: Tuple[int, ...],
                             chunk: Optional[int],
                             devices: int = 1) -> List[RunResult]:
        S = len(seeds)
        kind = "sweep_sharded" if devices > 1 else "sweep"
        run_sweep, state0, metrics0 = self._program(kind, cfg, devices)
        per_seed = [make_client_schedule(cfg, s) for s in seeds]
        traces = [self._availability(cfg, s) for s in seeds]
        valids = None
        participations = None
        if any(t is not None for t in traces):
            # each seed keeps its own trace (seed-salted like the
            # schedules) — the (S, R, K) valid mask rides the same vmap
            valids, participations = [], []
            for i, s in enumerate(seeds):
                cfg_s = dataclasses.replace(cfg, seed=s)
                sched, valid, part = self._degrade_schedule(
                    cfg_s, "scan", per_seed[i], traces[i])
                if valid is None:                    # mixed grids: pad
                    valid = np.ones(sched.shape, np.float32)
                    part = np.full((cfg.rounds,), cfg.clients_per_round,
                                   np.int64)
                per_seed[i] = sched
                valids.append(valid)
                participations.append(part)
            valids = np.stack(valids)                           # (S, R, K)
        schedules = np.stack(per_seed)                          # (S, R, K)
        sched_dev = jnp.asarray(schedules, jnp.int32)
        valid_dev = (None if valids is None
                     else jnp.asarray(valids, jnp.float32))
        seeds_dev = jnp.asarray(seeds, jnp.int32)

        def bcast(t):
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    jnp.asarray(x)[None], (S,) + jnp.shape(x)), t)

        w, state, metrics = (bcast(self.spec.params), bcast(state0),
                             bcast(metrics0))
        n_chunk = cfg.rounds if chunk is None else max(1, int(chunk))
        n_chunk = min(n_chunk, cfg.rounds)
        t0 = time.time()
        dispatches = 0
        for r0 in range(0, cfg.rounds, n_chunk):
            n = min(n_chunk, cfg.rounds - r0)
            if valid_dev is None:
                w, state, metrics = run_sweep(
                    seeds_dev, w, state, metrics, jnp.int32(r0),
                    sched_dev[:, r0:r0 + n], n_rounds=n)
            else:
                w, state, metrics = run_sweep(
                    seeds_dev, w, state, metrics, jnp.int32(r0),
                    sched_dev[:, r0:r0 + n], valid_dev[:, r0:r0 + n],
                    n_rounds=n)
            dispatches += 1
        wall = time.time() - t0
        loss = np.asarray(metrics["loss"])                      # (S, R)
        acc = np.asarray(metrics["acc"])
        bits = np.asarray(metrics["uplink_bits"])
        rounds = eval_round_indices(cfg, self.spec.eval_every)
        bpc = uplink_bits(cfg, self.spec.params)
        n_params = tree_num_params(self.spec.params)
        return [RunResult(
            algorithm=cfg.algorithm, engine="scan",
            config=dataclasses.replace(cfg, seed=s), seed=s,
            eval_rounds=tuple(rounds),
            acc=tuple(float(acc[i, r]) for r in rounds),
            local_loss=tuple(float(x) for x in loss[i]),
            uplink_bits_round=tuple(float(b) for b in bits[i]),
            uplink_bits_per_client=bpc, num_params=n_params,
            schedule=schedules[i], num_dispatches=dispatches,
            wall_s=wall / S,
            participation_round=tuple(
                int(p) for p in (
                    [cfg.clients_per_round] * cfg.rounds
                    if participations is None else participations[i])),
            # NOTE: at fixed dp_seed all seeds of a vmapped sweep share
            # one noise realization per round (the DP stream is keyed on
            # (dp_seed, round) only) — the accountant is per-run either
            # way, so the ε schedule below is exact per seed
            dp_epsilon=dp_epsilon_schedule(
                cfg, ([cfg.clients_per_round] * cfg.rounds
                      if participations is None
                      else participations[i]), n_params)[0],
            dp_delta=(cfg.privacy.delta
                      if cfg.privacy is not None else 0.0),
        ) for i, s in enumerate(seeds)]

    def _sweep_point_host(self, cfg: FLConfig, seeds: Tuple[int, ...],
                          chunk: Optional[int]) -> List[RunResult]:
        """Fallback: S sequential dispatches of ONE seeded program."""
        return [self._run_scan(dataclasses.replace(cfg, seed=s), chunk)
                for s in seeds]
