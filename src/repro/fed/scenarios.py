"""Paper-scenario builders: Dirichlet-skewed specs + degradation curves.

The partitioners in :mod:`repro.data.synthetic` implement the paper's
federated splits (§5.1.2) but until now only the IID path was wired into
an :class:`~repro.fed.api.ExperimentSpec` by callers; the Non-IID-1
Dirichlet partitioner sat dormant.  :func:`make_synthetic_spec` builds a
complete spec from ``(partition kind, alpha)`` so heterogeneity is one
argument away, and the two curve helpers turn the availability tier
(ROADMAP 4(b)) into the plots the robustness story needs:

:func:`dropout_curve`
    accuracy vs dropout rate — ONE :meth:`Experiment.sweep` call over a
    ``{"availability": ["bernoulli"], "dropout": [...]}`` grid (the S
    seeds of each dropout point run as one vmapped scan program).

:func:`alpha_curve`
    accuracy vs Dirichlet ``alpha`` — alpha changes the DATA partition,
    not an ``FLConfig`` field, so each alpha is its own spec/sweep; the
    per-alpha multi-seed sweep is still vmapped.

Both return plain nested dicts (JSON-ready) keyed by the swept value.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax
import numpy as np

from ..data import (make_federated_dataset, make_image_task, make_partition)
from ..models.cnn import mlp_apply, mlp_init, mlp_loss
from .algorithms import FLConfig
from .api import Experiment, ExperimentSpec


def make_synthetic_spec(cfg: FLConfig, *, partition: str = "iid",
                        alpha: float = 0.3, labels_per_client: int = 3,
                        n: int = 4000, hw: int = 16, n_classes: int = 8,
                        noise: float = 0.6, d_hidden: int = 32,
                        data_seed: int = 0,
                        batch_seed: int = 7) -> ExperimentSpec:
    """A complete MLP-on-synthetic-images spec for any partitioner.

    ``partition`` is one of :func:`repro.data.make_partition`'s kinds —
    ``"iid"``, ``"noniid1"`` (Dirichlet(``alpha``) label skew) or
    ``"noniid2"`` (``labels_per_client`` labels per client).  The task,
    model and test split are deterministic in ``data_seed``, so two specs
    differing only in ``partition``/``alpha`` hold identical samples
    partitioned differently — exactly what an accuracy-vs-α curve needs.
    """
    task = make_image_task(data_seed, n=n, hw=hw, n_classes=n_classes,
                           noise=noise)
    parts = make_partition(partition, data_seed, task.y, cfg.num_clients,
                           alpha=alpha, labels_per_client=labels_per_client)
    n_test = max(1, n // 8)
    ds = make_federated_dataset(task.x, task.y, parts,
                                batch_seed=batch_seed,
                                x_test=task.x[:n_test],
                                y_test=task.y[:n_test])
    params = mlp_init(jax.random.key(data_seed), d_in=hw * hw,
                      d_hidden=d_hidden, n_classes=n_classes)
    return ExperimentSpec(loss_fn=mlp_loss, params=params, data=ds,
                          config=cfg, eval_apply=mlp_apply)


def _point_summary(runs) -> Dict[str, Any]:
    accs = np.asarray([r.final_acc for r in runs], np.float64)
    return {
        "final_acc_mean": float(accs.mean()),
        "final_acc_std": float(accs.std()),
        "final_acc": [float(a) for a in accs],
        "participation_round": [list(r.participation_round) for r in runs],
    }


def dropout_curve(spec: ExperimentSpec, *,
                  dropouts: Sequence[float] = (0.0, 0.2, 0.4, 0.6),
                  seeds: Any = 3,
                  availability: str = "bernoulli",
                  churn: Optional[float] = None,
                  avail_resample: bool = False) -> Dict[str, Any]:
    """Accuracy vs dropout from ONE vmapped sweep.

    Every (dropout × seed) trajectory comes out of the same compiled
    sweep program; the ``dropout=0.0`` point is bitwise the undegraded
    baseline (the availability mask traces to all-ones), so the curve's
    left edge doubles as a regression anchor.
    """
    cfg = spec.config
    if availability == "always" or (availability == "bernoulli"
                                    and churn is not None):
        raise ValueError(
            "dropout_curve sweeps a degradation axis — availability must "
            "be 'bernoulli' (churn=None) or 'markov'")
    overrides: Dict[str, Sequence[Any]] = {
        "availability": [availability],
        "dropout": [float(d) for d in dropouts],
    }
    if churn is not None:
        overrides["churn"] = [float(churn)]
    if avail_resample:
        overrides["avail_resample"] = [True]
    exp = Experiment(spec)
    res = exp.sweep(seeds=seeds, grid=overrides)
    curve: Dict[str, Any] = {
        "algorithm": cfg.algorithm, "availability": availability,
        "seeds": list(res.seeds), "points": {},
    }
    for pt in res.points:
        d = dict(pt.overrides)["dropout"]
        curve["points"][f"{d:g}"] = _point_summary(pt.runs)
    return curve


def alpha_curve(cfg: FLConfig, *,
                alphas: Sequence[float] = (0.1, 0.3, 1.0, 10.0),
                seeds: Any = 3,
                dropout: float = 0.0,
                spec_kw: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Accuracy vs Dirichlet ``alpha`` (Non-IID-1), optionally degraded.

    Each alpha rebuilds the partition (same samples, same model init —
    see :func:`make_synthetic_spec`), then runs a multi-seed vmapped
    sweep; with ``dropout > 0`` every point also rides a Bernoulli
    availability trace, giving the heterogeneity × dropout interaction
    from the same code path as :func:`dropout_curve`.
    """
    spec_kw = dict(spec_kw or {})
    if dropout > 0.0:
        cfg = dataclasses.replace(cfg, availability="bernoulli",
                                  dropout=float(dropout))
    curve: Dict[str, Any] = {
        "algorithm": cfg.algorithm, "partition": "noniid1",
        "dropout": float(dropout), "points": {},
    }
    for alpha in alphas:
        spec = make_synthetic_spec(cfg, partition="noniid1",
                                   alpha=float(alpha), **spec_kw)
        res = Experiment(spec).sweep(seeds=seeds)
        curve["seeds"] = list(res.seeds)
        curve["points"][f"{alpha:g}"] = _point_summary(res.points[0].runs)
    return curve
