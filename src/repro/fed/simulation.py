"""Legacy ``run_federated`` shim + the host-loop engine runners.

The user-facing experiment surface is the declarative API in
``fed/api.py`` (:class:`~repro.fed.ExperimentSpec` +
:class:`~repro.fed.Experiment` → typed :class:`~repro.fed.RunResult`).
This module keeps two things:

  1. :func:`run_federated` — the seed-era kwarg entry point, now a THIN
     deprecated shim over ``Experiment``: with a device-resident
     :class:`~repro.data.FederatedDataset` it builds a spec, runs the
     requested engine, and returns ``RunResult.to_history()`` (identical
     trajectories, unified key schema).  Legacy host batch callbacks
     (``(round, client_id) -> stacked batches``) still work on the
     batched/looped engines only.
  2. the host-loop runners (``_run_batched`` here, ``fed/looped.py``'s
     reference loop) that ``Experiment.run(engine="batched"|"looped")``
     drives; both now record the SAME history keys as the scan engine
     (``repro.fed.api.HISTORY_KEYS``), including ``uplink_bits_round``
     and ``num_dispatches``.

All engines consume the same precomputed seed-stable ``(R, K)``
client-selection schedule (``make_client_schedule``), so every paper
table/figure can be emitted from any engine interchangeably.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tree_num_params
from ..data.federated import FederatedDataset
from .api import ENGINES  # noqa: F401  (one engine list for shim + API)
from .engine import (ALGORITHMS, FLConfig, make_client_schedule,  # noqa: F401
                     make_experiment_program, make_round_engine,
                     stack_client_batches, uplink_bits)

Pytree = Any


def _base_history(cfg: FLConfig, params: Pytree, schedule: np.ndarray,
                  engine: str) -> Dict[str, Any]:
    return {
        "algorithm": cfg.algorithm, "engine": engine,
        "acc": [], "round": [], "local_loss": [],
        "uplink_bits_per_client": uplink_bits(cfg, params),
        "params": tree_num_params(params),
        "schedule": schedule,
    }


def run_federated(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    init_params: Pytree,
    data: Union[FederatedDataset, Callable[[int, int], Any]],
    # FederatedDataset (device-resident; required for engine="scan") or the
    # legacy (round, client_id) -> stacked (steps, batch, ...) callback
    eval_fn: Optional[Callable[[Pytree], float]],
    cfg: FLConfig,
    *,
    eval_every: int = 1,
    client_weights: Optional[List[float]] = None,
    engine: str = "batched",
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    chunk: Optional[int] = None,
) -> Dict[str, Any]:
    """DEPRECATED: use :class:`repro.fed.Experiment` instead.

    Kept as a compatibility shim — with a :class:`FederatedDataset` it
    delegates to ``Experiment(...).run(engine=...).to_history()`` and
    reproduces the exact same trajectories at a fixed seed.
    """
    warnings.warn(
        "run_federated is deprecated; build an ExperimentSpec and call "
        "Experiment(spec).run() (repro.fed.api) instead",
        DeprecationWarning, stacklevel=2)
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (one of {ENGINES})")

    if isinstance(data, FederatedDataset):
        from .api import Experiment, ExperimentSpec
        spec = ExperimentSpec(
            loss_fn=loss_fn, params=init_params, data=data, config=cfg,
            eval_program=eval_program, eval_fn=eval_fn,
            eval_every=eval_every,
            client_weights=(tuple(client_weights)
                            if client_weights is not None else None))
        return Experiment(spec).run(engine=engine,
                                    chunk=chunk).to_history()

    # legacy host-callback data: batched/looped only
    if engine == "scan":
        raise ValueError(
            "engine='scan' gathers batches in-program and needs a "
            "device-resident FederatedDataset, not a host callback "
            "(see repro.data.make_federated_dataset)")
    if eval_fn is None:
        if eval_program is None:
            raise ValueError("need eval_fn or eval_program")
        jitted_eval = jax.jit(eval_program)
        eval_fn = lambda p: float(jitted_eval(p))  # noqa: E731

    schedule = make_client_schedule(cfg)
    if engine == "looped":
        from .looped import run_federated_looped
        return run_federated_looped(
            loss_fn, init_params, data, eval_fn, cfg,
            eval_every=eval_every, client_weights=client_weights,
            schedule=schedule)
    return _run_batched(loss_fn, init_params, data, eval_fn, cfg,
                        schedule=schedule, eval_every=eval_every,
                        client_weights=client_weights)


# ---------------------------------------------------------------------------
# engine="batched": one program per round, host-stacked batches
# ---------------------------------------------------------------------------

def _run_batched(loss_fn, init_params, client_batch_fn, eval_fn, cfg,
                 *, schedule, eval_every, client_weights, valid=None):
    if cfg.int_mask_agg and client_weights is not None:
        # same guard as the scan chunk body: the integer count aggregate
        # folds ONE weight scalar — per-client weights need the f32 path
        raise ValueError(
            "int_mask_agg requires uniform client weights "
            "(client_weights=None)")
    if cfg.int_mask_agg and valid is not None:
        raise ValueError(
            "int_mask_agg cannot mask dropped clients on engine="
            "'batched' — run availability scenarios on engine='cohort' "
            "or 'service'")
    if cfg.privacy is not None and client_weights is not None:
        raise ValueError(
            "privacy= requires uniform client weights "
            "(client_weights=None): the clipped-count sensitivity bound "
            "assumes every client contributes one unweighted mask")
    if cfg.privacy is not None and valid is not None:
        raise ValueError(
            "privacy= cannot mask dropped clients on engine='batched' — "
            "the count wire sums every stacked row; run availability "
            "scenarios on engine='cohort', 'looped' or 'service'")
    w = init_params
    history = _base_history(cfg, w, schedule, "batched")
    if client_weights is None:
        client_weights = [1.0] * cfg.num_clients

    round_fn, state = make_round_engine(loss_fn, cfg, init_params)

    loss_buf: List[jax.Array] = []      # device scalars, read once at end
    bits_buf: List[jax.Array] = []      # per-round MEASURED wire bits
    participation: List[int] = []
    t0 = time.time()
    for rnd in range(cfg.rounds):
        picked = schedule[rnd]
        batches = stack_client_batches(
            [client_batch_fn(rnd, int(cid)) for cid in picked])
        weights = jnp.asarray([client_weights[int(c)] for c in picked],
                              jnp.float32)
        if valid is None:
            nv = len(picked)
            w, state, losses, wire_bits = round_fn(
                w, state, batches, jnp.asarray(picked, jnp.int32),
                jnp.int32(rnd), weights)
            loss_buf.append(jnp.mean(losses[:, -1]))
            bits_buf.append(wire_bits)
        else:
            # dropped clients carry zero aggregation weight — the
            # normalizing codecs then average exactly the survivors
            valid_r = jnp.asarray(valid[rnd], jnp.float32)
            nv = int(np.asarray(valid[rnd]).sum())
            w, state, losses, wire_bits = round_fn(
                w, state, batches, jnp.asarray(picked, jnp.int32),
                jnp.int32(rnd), weights * valid_r)
            loss_buf.append(jnp.sum(valid_r * losses[:, -1]) / nv)
            bits_buf.append(wire_bits * nv / len(picked))
        participation.append(nv)
        if rnd % eval_every == 0 or rnd == cfg.rounds - 1:
            history["acc"].append(float(eval_fn(w)))
            history["round"].append(rnd)
    history["local_loss"] = [float(x) for x in np.asarray(jnp.stack(loss_buf))]
    history["uplink_bits_round"] = [
        float(b) for b in np.asarray(jnp.stack(bits_buf))]
    history["participation_round"] = participation
    history["num_dispatches"] = cfg.rounds      # one round program per round
    history["wall_s"] = time.time() - t0
    history["final_acc"] = history["acc"][-1]
    from .api import dp_epsilon_schedule        # lazy, one-way (like shim)
    eps, delta = dp_epsilon_schedule(cfg, participation,
                                     history["params"])
    history["dp_epsilon"] = list(eps)
    history["dp_delta"] = delta
    return history
