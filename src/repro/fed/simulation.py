"""Federated simulation engine — the paper's experimental harness.

Runs R rounds of K-client FL with any of:
  fedavg            float updates (Eq. 3)
  fedmrn / fedmrns  masked random noise updates, PSM local training (Alg. 1)
  <compressor>      FedAvg local training + post-training compression of u
                    (signsgd, stochsign, terngrad, topk, qsgd, drive, eden,
                     post_sm — the paper's baseline zoo)
  fedpm             supermask-as-weights baseline (masks on frozen noise)
  fedsparsify       magnitude-pruned weight upload baseline

Execution model (``fed/engine.py``): each round is ONE jitted XLA program —
all K selected clients run as a vmap over a stacked client axis, with
local training, mask sampling, Pallas-backed bit-packing, and server
aggregation fused end-to-end.  This host loop only samples client ids,
stacks their batches, and reads metrics; per-round losses stay on device
and the only host syncs are the eval reads.

``engine="looped"`` dispatches to the legacy per-client reference loop
(``fed/looped.py``) — kept for parity tests and the engine benchmark.

The engine records per-round global accuracy, local losses, and exact
uplink bits, so every paper table/figure can be emitted from one
``history`` dict.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tree_num_params
from .engine import (ALGORITHMS, FLConfig, make_round_engine,  # noqa: F401
                     stack_client_batches, uplink_bits)

Pytree = Any


def run_federated(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    init_params: Pytree,
    client_batch_fn: Callable[[int, int], Any],
    # (round, client_id) -> stacked (steps, batch, ...) local batches
    eval_fn: Callable[[Pytree], float],
    cfg: FLConfig,
    *,
    eval_every: int = 1,
    client_weights: Optional[List[float]] = None,
    engine: str = "batched",
) -> Dict[str, Any]:
    if engine == "looped":
        from .looped import run_federated_looped
        return run_federated_looped(
            loss_fn, init_params, client_batch_fn, eval_fn, cfg,
            eval_every=eval_every, client_weights=client_weights)
    if engine != "batched":
        raise ValueError(f"unknown engine {engine!r}")

    rng = np.random.RandomState(cfg.seed)
    w = init_params
    history: Dict[str, Any] = {
        "algorithm": cfg.algorithm, "acc": [], "round": [],
        "local_loss": [], "uplink_bits_per_client": uplink_bits(cfg, w),
        "params": tree_num_params(w),
    }
    if client_weights is None:
        client_weights = [1.0] * cfg.num_clients

    round_fn, state = make_round_engine(loss_fn, cfg, init_params)

    loss_buf: List[jax.Array] = []      # device scalars, read once at end
    t0 = time.time()
    for rnd in range(cfg.rounds):
        picked = rng.choice(cfg.num_clients, cfg.clients_per_round,
                            replace=False)
        batches = stack_client_batches(
            [client_batch_fn(rnd, int(cid)) for cid in picked])
        weights = jnp.asarray([client_weights[int(c)] for c in picked],
                              jnp.float32)
        w, state, losses = round_fn(
            w, state, batches, jnp.asarray(picked, jnp.int32),
            jnp.int32(rnd), weights)
        loss_buf.append(jnp.mean(losses[:, -1]))
        if rnd % eval_every == 0 or rnd == cfg.rounds - 1:
            history["acc"].append(float(eval_fn(w)))
            history["round"].append(rnd)
    history["local_loss"] = [float(x) for x in np.asarray(jnp.stack(loss_buf))]
    history["wall_s"] = time.time() - t0
    history["final_acc"] = history["acc"][-1]
    return history
