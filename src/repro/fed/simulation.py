"""Federated simulation driver — the paper's experimental harness.

Runs R rounds of K-client FL with any of:
  fedavg            float updates (Eq. 3)
  fedmrn / fedmrns  masked random noise updates, PSM local training (Alg. 1)
  <compressor>      FedAvg local training + post-training compression of u
                    (signsgd, stochsign, terngrad, topk, qsgd, drive, eden,
                     post_sm — the paper's baseline zoo)
  fedpm             supermask-as-weights baseline (masks on frozen noise)
  fedsparsify       magnitude-pruned weight upload baseline

This module is a THIN host driver over the three execution engines built
from the same pure round bodies (``fed/engine.py``):

  engine="scan"      a whole experiment chunk is ONE jitted program:
                     ``lax.scan`` over ``chunk`` rounds with in-program
                     client selection, device-resident batch gathering
                     (requires a :class:`~repro.data.FederatedDataset`),
                     on-device eval, and ``(R,)`` metric buffers — the
                     host dispatches ⌈R/chunk⌉ programs and reads the
                     buffers once at the end.
  engine="batched"   one jitted program per round (PR-1 model): the host
                     stacks batches, dispatches, and reads eval per round.
  engine="looped"    the seed's per-client reference loop
                     (``fed/looped.py``) — parity tests + benchmark.

All engines consume the same precomputed seed-stable ``(R, K)``
client-selection schedule (``make_client_schedule``) and materialise the
same ``history`` dict (per-round accuracy at eval rounds, local losses,
exact uplink bits), so every paper table/figure can be emitted from any
engine interchangeably.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tree_num_params
from ..data.federated import FederatedDataset
from .engine import (ALGORITHMS, FLConfig, make_client_schedule,  # noqa: F401
                     make_experiment_program, make_round_engine,
                     stack_client_batches, uplink_bits)

Pytree = Any

ENGINES = ("scan", "batched", "looped")


def _base_history(cfg: FLConfig, params: Pytree,
                  schedule: np.ndarray) -> Dict[str, Any]:
    return {
        "algorithm": cfg.algorithm, "acc": [], "round": [],
        "local_loss": [], "uplink_bits_per_client": uplink_bits(cfg, params),
        "params": tree_num_params(params),
        "schedule": schedule,
    }


def _eval_rounds(cfg: FLConfig, eval_every: int) -> List[int]:
    return [r for r in range(cfg.rounds)
            if r % eval_every == 0 or r == cfg.rounds - 1]


def run_federated(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    init_params: Pytree,
    data: Union[FederatedDataset, Callable[[int, int], Any]],
    # FederatedDataset (device-resident; required for engine="scan") or the
    # legacy (round, client_id) -> stacked (steps, batch, ...) callback
    eval_fn: Optional[Callable[[Pytree], float]],
    cfg: FLConfig,
    *,
    eval_every: int = 1,
    client_weights: Optional[List[float]] = None,
    engine: str = "batched",
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    # pure on-device eval (params -> accuracy); required for engine="scan",
    # and substituted for a missing eval_fn on the host-loop engines
    chunk: Optional[int] = None,
    # rounds fused per scan dispatch (engine="scan"); default: all R rounds
    # in one dispatch — scan trip count is free at compile time, so chunking
    # only matters when you want intermediate host visibility

) -> Dict[str, Any]:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (one of {ENGINES})")

    schedule = make_client_schedule(cfg)

    if engine == "scan":
        if not isinstance(data, FederatedDataset):
            raise ValueError(
                "engine='scan' gathers batches in-program and needs a "
                "device-resident FederatedDataset, not a host callback "
                "(see repro.data.make_federated_dataset)")
        if eval_program is None:
            raise ValueError(
                "engine='scan' folds eval into the program and needs a "
                "pure eval_program (params -> accuracy); build one with "
                "repro.core.make_eval_program")
        return _run_scan(loss_fn, init_params, data, eval_program, cfg,
                         schedule, eval_every=eval_every,
                         client_weights=client_weights, chunk=chunk)

    # host-loop engines: adapt a FederatedDataset to the callback contract
    # (same key derivation as the in-program gather → identical batches)
    if isinstance(data, FederatedDataset):
        client_batch_fn = data.batch_fn(steps=cfg.local_steps,
                                        batch=cfg.batch_size)
    else:
        client_batch_fn = data
    if eval_fn is None:
        if eval_program is None:
            raise ValueError("need eval_fn or eval_program")
        jitted_eval = jax.jit(eval_program)
        eval_fn = lambda p: float(jitted_eval(p))  # noqa: E731

    if engine == "looped":
        from .looped import run_federated_looped
        return run_federated_looped(
            loss_fn, init_params, client_batch_fn, eval_fn, cfg,
            eval_every=eval_every, client_weights=client_weights,
            schedule=schedule)
    return _run_batched(loss_fn, init_params, client_batch_fn, eval_fn, cfg,
                        schedule, eval_every=eval_every,
                        client_weights=client_weights)


# ---------------------------------------------------------------------------
# engine="batched": one program per round, host-stacked batches
# ---------------------------------------------------------------------------

def _run_batched(loss_fn, init_params, client_batch_fn, eval_fn, cfg,
                 schedule, *, eval_every, client_weights):
    w = init_params
    history = _base_history(cfg, w, schedule)
    if client_weights is None:
        client_weights = [1.0] * cfg.num_clients

    round_fn, state = make_round_engine(loss_fn, cfg, init_params)

    loss_buf: List[jax.Array] = []      # device scalars, read once at end
    t0 = time.time()
    for rnd in range(cfg.rounds):
        picked = schedule[rnd]
        batches = stack_client_batches(
            [client_batch_fn(rnd, int(cid)) for cid in picked])
        weights = jnp.asarray([client_weights[int(c)] for c in picked],
                              jnp.float32)
        w, state, losses = round_fn(
            w, state, batches, jnp.asarray(picked, jnp.int32),
            jnp.int32(rnd), weights)
        loss_buf.append(jnp.mean(losses[:, -1]))
        if rnd % eval_every == 0 or rnd == cfg.rounds - 1:
            history["acc"].append(float(eval_fn(w)))
            history["round"].append(rnd)
    history["local_loss"] = [float(x) for x in np.asarray(jnp.stack(loss_buf))]
    history["wall_s"] = time.time() - t0
    history["final_acc"] = history["acc"][-1]
    return history


# ---------------------------------------------------------------------------
# engine="scan": ⌈R/chunk⌉ dispatches, metrics read once at the end
# ---------------------------------------------------------------------------

def _run_scan(loss_fn, init_params, data: FederatedDataset, eval_program,
              cfg, schedule, *, eval_every, client_weights, chunk):
    if data.num_clients != cfg.num_clients:
        raise ValueError(
            f"dataset has {data.num_clients} clients, cfg expects "
            f"{cfg.num_clients}")
    chunk = cfg.rounds if chunk is None else max(1, int(chunk))
    chunk = min(chunk, cfg.rounds)

    run_chunk, state, metrics = make_experiment_program(
        loss_fn, cfg, init_params, data, eval_program=eval_program,
        eval_every=eval_every, client_weights=client_weights)

    w = init_params
    history = _base_history(cfg, w, schedule)
    sched_dev = jnp.asarray(schedule, jnp.int32)
    t0 = time.time()
    dispatches = 0
    for r0 in range(0, cfg.rounds, chunk):
        n = min(chunk, cfg.rounds - r0)
        w, state, metrics = run_chunk(
            w, state, metrics, jnp.int32(r0), sched_dev[r0:r0 + n],
            n_rounds=n)
        dispatches += 1

    # the ONLY device→host reads of the whole experiment
    loss = np.asarray(metrics["loss"])
    acc = np.asarray(metrics["acc"])
    bits = np.asarray(metrics["uplink_bits"])
    history["round"] = _eval_rounds(cfg, eval_every)
    history["acc"] = [float(acc[r]) for r in history["round"]]
    history["local_loss"] = [float(x) for x in loss]
    history["uplink_bits_round"] = [float(b) for b in bits]
    history["num_dispatches"] = dispatches
    history["wall_s"] = time.time() - t0
    history["final_acc"] = history["acc"][-1]
    return history
