"""Federated simulation engine — the paper's experimental harness.

Runs R rounds of K-client FL with any of:
  fedavg            float updates (Eq. 3)
  fedmrn / fedmrns  masked random noise updates, PSM local training (Alg. 1)
  <compressor>      FedAvg local training + post-training compression of u
                    (signsgd, stochsign, terngrad, topk, qsgd, drive, eden,
                     post_sm — the paper's baseline zoo)
  fedpm             supermask-as-weights baseline (masks on frozen noise)
  fedsparsify       magnitude-pruned weight upload baseline

All local computation is jitted once per algorithm; clients share the
jitted program.  The engine records per-round global accuracy, local
losses, and exact uplink bits, so every paper table/figure can be emitted
from one ``history`` dict.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (FedMRNConfig, NoiseConfig, client_local_update,
                    client_round_key, gen_noise, make_compressor,
                    server_aggregate, server_aggregate_updates,
                    sgd_local_update, baseline_record, fedmrn_record,
                    tree_num_params)
from ..core.compressors import REGISTRY as COMPRESSOR_REGISTRY

Pytree = Any

ALGORITHMS = (("fedavg", "fedmrn", "fedmrns", "fedpm", "fedsparsify")
              + tuple(c for c in COMPRESSOR_REGISTRY if c != "none"))


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedmrn"
    num_clients: int = 20
    clients_per_round: int = 5
    rounds: int = 30
    local_steps: int = 20
    batch_size: int = 32
    lr: float = 0.1
    seed: int = 0
    # fedmrn specifics (paper defaults: uniform, 1e-2 / 5e-3)
    noise_dist: str = "uniform"
    noise_alpha: float = 1e-2
    use_sm: bool = True
    use_pm: bool = True
    error_feedback: bool = False
    # beyond-paper: one shared noise G(s_t) per ROUND (instead of per
    # client).  Masks stay per-client, so the uplink is unchanged (1 bpp),
    # but Σ_k G(s_k)⊙m_k = G(s_t) ⊙ Σ_k m_k — the server aggregation
    # becomes an integer mask-count (popcount) scaled by one noise tensor,
    # and at pod scale the mask all-gather can become a ⌈log2(K+1)⌉-bit
    # integer all-reduce (a further ~3× cross-client traffic cut at K=16).
    shared_noise: bool = False
    # baselines
    topk_frac: float = 0.03
    sparsify_frac: float = 0.03    # fedsparsify keeps top 3% of weights
    qsgd_bits: int = 2

    def fedmrn_config(self) -> FedMRNConfig:
        mode = "signed" if self.algorithm == "fedmrns" else "binary"
        return FedMRNConfig(
            mask_mode=mode,
            noise=NoiseConfig(dist=self.noise_dist, alpha=self.noise_alpha),
            use_sm=self.use_sm, use_pm=self.use_pm,
            error_feedback=self.error_feedback, lr=self.lr)


def _uplink_bits(cfg: FLConfig, params: Pytree) -> int:
    P = tree_num_params(params)
    L = len(jax.tree_util.tree_leaves(params))
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        return fedmrn_record(P).uplink_bits
    if cfg.algorithm == "fedavg":
        return 32 * P
    if cfg.algorithm == "fedpm":
        return baseline_record("fedpm", P, L).uplink_bits
    if cfg.algorithm == "fedsparsify":
        return baseline_record("fedsparsify", P, L,
                               topk_frac=cfg.sparsify_frac).uplink_bits
    return baseline_record(cfg.algorithm, P, L, topk_frac=cfg.topk_frac,
                           qsgd_bits=cfg.qsgd_bits).uplink_bits


# ---------------------------------------------------------------------------
# FedPM baseline: supermask on frozen noise as *weights* (paper §2.2)
# ---------------------------------------------------------------------------

def _fedpm_local(loss_fn, w_init, scores, batches, *, lr, key):
    """Train sigmoid-scores; weights = w_init ⊙ Bern(sigmoid(s)) with STE."""

    def masked_params(s, k):
        leaves, treedef = jax.tree_util.tree_flatten(s)
        w_leaves = jax.tree_util.tree_leaves(w_init)
        out = []
        for i, (sl, wl) in enumerate(zip(leaves, w_leaves)):
            prob = jax.nn.sigmoid(sl)
            m = jax.random.bernoulli(jax.random.fold_in(k, i), prob)
            m = prob + jax.lax.stop_gradient(m.astype(prob.dtype) - prob)
            out.append(wl * m)
        return jax.tree_util.tree_unflatten(treedef, out)

    def step(s, inp):
        tau, batch = inp
        k = jax.random.fold_in(key, tau)

        def fwd(s_):
            return loss_fn(masked_params(s_, k), batch)

        loss, g = jax.value_and_grad(fwd)(s)
        s = jax.tree_util.tree_map(lambda a, gi: a - lr * gi, s, g)
        return s, loss

    n = jax.tree_util.tree_leaves(batches)[0].shape[0]
    s_final, losses = jax.lax.scan(step, scores,
                                   (jnp.arange(n), batches))
    # uplink: Bernoulli-sampled masks
    masks = jax.tree_util.tree_map(
        lambda sl: jax.random.bernoulli(key, jax.nn.sigmoid(sl)).astype(
            jnp.float32), s_final)
    return masks, losses


def _fedsparsify_local(loss_fn, w, batches, *, lr, frac):
    w_new, losses = sgd_local_update(loss_fn, w, batches, lr=lr)
    w_new = jax.tree_util.tree_map(jnp.add, w, w_new)  # u → w_local

    def prune(x):
        flat = jnp.abs(x).reshape(-1)
        k = max(1, int(np.ceil(frac * flat.shape[0])))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)

    return jax.tree_util.tree_map(prune, w_new), losses


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

def run_federated(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    init_params: Pytree,
    client_batch_fn: Callable[[int, int], Any],
    # (round, client_id) -> stacked (steps, batch, ...) local batches
    eval_fn: Callable[[Pytree], float],
    cfg: FLConfig,
    *,
    eval_every: int = 1,
    client_weights: Optional[List[float]] = None,
) -> Dict[str, Any]:
    rng = np.random.RandomState(cfg.seed)
    w = init_params
    mrn_cfg = cfg.fedmrn_config()
    history: Dict[str, Any] = {
        "algorithm": cfg.algorithm, "acc": [], "round": [],
        "local_loss": [], "uplink_bits_per_client": _uplink_bits(cfg, w),
        "params": tree_num_params(w),
    }
    if client_weights is None:
        client_weights = [1.0] * cfg.num_clients

    # jitted workers (compiled once, reused by every client/round)
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        local = jax.jit(partial(client_local_update, loss_fn, cfg=mrn_cfg,
                                base_seed=cfg.seed))
    elif cfg.algorithm == "fedpm":
        local_pm = jax.jit(partial(_fedpm_local, loss_fn, lr=cfg.lr))
        noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)
        w_frozen = gen_noise(jax.random.key(cfg.seed), w, noise_cfg)
        scores_global = jax.tree_util.tree_map(jnp.zeros_like, w)
    elif cfg.algorithm == "fedsparsify":
        local_sp = jax.jit(partial(_fedsparsify_local, loss_fn, lr=cfg.lr,
                                   frac=cfg.sparsify_frac))
    else:
        local_sgd = jax.jit(partial(sgd_local_update, loss_fn, lr=cfg.lr))
        compressor = (None if cfg.algorithm == "fedavg" else
                      make_compressor(cfg.algorithm,
                                      topk_frac=cfg.topk_frac,
                                      qsgd_bits=cfg.qsgd_bits,
                                      noise=mrn_cfg.noise))
        if compressor is not None:
            comp_fn = jax.jit(compressor.roundtrip)

    residuals: Dict[int, Pytree] = {}
    t0 = time.time()
    for rnd in range(cfg.rounds):
        picked = rng.choice(cfg.num_clients, cfg.clients_per_round,
                            replace=False)
        weights = [client_weights[c] for c in picked]
        losses = []

        if cfg.algorithm in ("fedmrn", "fedmrns"):
            results = []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                noise_id = 0 if cfg.shared_noise else int(cid)
                res = local(w, batches, round_idx=rnd, client_id=noise_id,
                            train_key=jax.random.fold_in(
                                jax.random.key(cfg.seed + 1),
                                rnd * 1000 + int(cid)),
                            init_residual=residuals.get(int(cid)))
                if cfg.error_feedback:
                    residuals[int(cid)] = res.residual
                results.append(res)
                losses.append(float(res.losses[-1]))
            w = server_aggregate(w, results, weights, cfg=mrn_cfg)

        elif cfg.algorithm == "fedpm":
            mask_sum = jax.tree_util.tree_map(jnp.zeros_like, scores_global)
            tot = 0.0
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                masks, ls = local_pm(
                    w_frozen, scores_global, batches,
                    key=jax.random.fold_in(jax.random.key(cfg.seed + 2),
                                           rnd * 1000 + int(cid)))
                mask_sum = jax.tree_util.tree_map(jnp.add, mask_sum, masks)
                tot += 1.0
                losses.append(float(ls[-1]))
            probs = jax.tree_util.tree_map(
                lambda m: jnp.clip(m / tot, 1e-4, 1 - 1e-4), mask_sum)
            scores_global = jax.tree_util.tree_map(
                lambda p_: jnp.log(p_ / (1 - p_)), probs)   # sigmoid^-1
            w = jax.tree_util.tree_map(
                lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)

        elif cfg.algorithm == "fedsparsify":
            ws = []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                w_local, ls = local_sp(w, batches)
                ws.append(w_local)
                losses.append(float(ls[-1]))
            zero = jax.tree_util.tree_map(jnp.zeros_like, w)
            w = server_aggregate_updates(zero, ws, weights)

        else:  # fedavg + post-training compressors
            updates = []
            for cid in picked:
                batches = client_batch_fn(rnd, int(cid))
                u, ls = local_sgd(w, batches)
                if compressor is not None:
                    u = comp_fn(u, jax.random.fold_in(
                        jax.random.key(cfg.seed + 3),
                        rnd * 1000 + int(cid)))
                updates.append(u)
                losses.append(float(ls[-1]))
            w = server_aggregate_updates(w, updates, weights)

        history["local_loss"].append(float(np.mean(losses)))
        if rnd % eval_every == 0 or rnd == cfg.rounds - 1:
            history["acc"].append(float(eval_fn(w)))
            history["round"].append(rnd)
    history["wall_s"] = time.time() - t0
    history["final_acc"] = history["acc"][-1]
    return history
