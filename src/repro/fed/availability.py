"""Client availability traces + service-tier fault injection.

Every engine used to assume ideal clients: uniform availability, no
dropouts, no hung seats, no corrupt frames.  This module makes degraded
rounds a first-class, *measured* scenario (ROADMAP direction 4(b)):

:class:`AvailabilityTrace`
    A seeded per-round × per-client availability matrix plus optional
    per-client heterogeneous ``local_steps``.  Generators: ``always``
    (the ideal baseline), ``bernoulli`` (iid per-round dropout) and
    ``markov`` (on/off churn with a stationary dropout rate).  Traces
    compose with :func:`~repro.fed.engine.make_client_schedule`:
    ``valid_for(schedule)`` yields the ``(R, K)`` f32 mask the engines
    thread into the codec ``partial_aggregate(..., valid=)`` chain, so a
    round with d dropped clients aggregates exactly the K−d survivors
    instead of averaging in garbage.  ``resample_schedule`` is the
    Ji et al. 2020 dynamic-sampling plugin: dropped scheduled clients
    are replaced by seeded draws from the round's still-available spare
    clients (``FLConfig.avail_resample``).

:class:`FaultPlan`
    Injected service-tier faults — uplink drops, delays (generalizing
    ``straggler_slots``), truncated/corrupt frames (the coordinator must
    answer 400, never crash), mid-round client crashes and hung seats —
    exercised against both sync (quorum) and async (staleness-weighted)
    round modes, with participation/survival counters carried in the
    history schema and :class:`~repro.fed.service.ServiceReport`.

Everything is derived from seeds with ``np.random.RandomState`` — the
same trace reproduces bit-for-bit across engines, which is what the
dropped-run ≡ survivors-only-run parity tests lean on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .algorithms import FLConfig

# decorrelates the trace RNG from the schedule RNG at equal seeds
_TRACE_SEED_SALT = 1_000_003

AVAILABILITY_KINDS = ("always", "bernoulli", "markov")


@dataclasses.dataclass(frozen=True)
class AvailabilityTrace:
    """A seeded ``(rounds, num_clients)`` availability matrix.

    ``avail[r, c]`` is True when client ``c`` can participate in round
    ``r``.  ``local_steps`` (optional, ``(num_clients,)`` int32) models
    compute heterogeneity — per-client local step counts; only the
    service engine honours it (the fused engines bake ``local_steps``
    into compiled shapes and refuse such a trace).
    """

    kind: str
    avail: np.ndarray
    local_steps: Optional[np.ndarray] = None

    def __post_init__(self):
        a = np.asarray(self.avail, bool)
        if a.ndim != 2:
            raise ValueError(
                f"avail must be (rounds, num_clients), got shape {a.shape}")
        object.__setattr__(self, "avail", a)
        if self.local_steps is not None:
            ls = np.asarray(self.local_steps, np.int32)
            if ls.shape != (a.shape[1],):
                raise ValueError(
                    f"local_steps must be ({a.shape[1]},), got {ls.shape}")
            if (ls <= 0).any():
                raise ValueError("local_steps entries must be positive")
            object.__setattr__(self, "local_steps", ls)

    # ---- shape ---------------------------------------------------------

    @property
    def rounds(self) -> int:
        return self.avail.shape[0]

    @property
    def num_clients(self) -> int:
        return self.avail.shape[1]

    # ---- generators ----------------------------------------------------

    @classmethod
    def always(cls, rounds: int, num_clients: int,
               local_steps: Optional[np.ndarray] = None
               ) -> "AvailabilityTrace":
        """Every client available every round (the ideal baseline)."""
        return cls("always", np.ones((rounds, num_clients), bool),
                   local_steps)

    @classmethod
    def bernoulli(cls, seed: int, rounds: int, num_clients: int,
                  dropout: float,
                  local_steps: Optional[np.ndarray] = None
                  ) -> "AvailabilityTrace":
        """iid per-(round, client) dropout with probability ``dropout``."""
        if not 0.0 <= dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {dropout}")
        rng = np.random.RandomState(seed)
        avail = rng.random_sample((rounds, num_clients)) >= dropout
        return cls("bernoulli", avail, local_steps)

    @classmethod
    def markov(cls, seed: int, rounds: int, num_clients: int,
               dropout: float, churn: float = 0.5,
               local_steps: Optional[np.ndarray] = None
               ) -> "AvailabilityTrace":
        """Two-state on/off churn per client.

        The chain's stationary unavailable probability is ``dropout``
        (so long-run participation matches the Bernoulli trace at the
        same rate) and ``churn`` in (0, 1] sets how fast states flip:
        P(up→down) = churn·dropout, P(down→up) = churn·(1−dropout).
        Initial states are drawn from the stationary distribution.
        """
        if not 0.0 <= dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {dropout}")
        if not 0.0 < churn <= 1.0:
            raise ValueError(f"churn must be in (0, 1], got {churn}")
        rng = np.random.RandomState(seed)
        p_down = churn * dropout
        p_up = churn * (1.0 - dropout)
        avail = np.empty((rounds, num_clients), bool)
        up = rng.random_sample(num_clients) >= dropout
        for r in range(rounds):
            avail[r] = up
            u = rng.random_sample(num_clients)
            up = np.where(up, u >= p_down, u < p_up)
        return cls("markov", avail, local_steps)

    @classmethod
    def heterogeneous_steps(cls, seed: int, num_clients: int, *,
                            choices: Tuple[int, ...] = (1, 2, 4)
                            ) -> np.ndarray:
        """Seeded per-client local step counts (service engine only)."""
        if not choices or any(int(c) <= 0 for c in choices):
            raise ValueError(f"choices must be positive ints, {choices!r}")
        rng = np.random.RandomState(seed)
        return rng.choice(np.asarray(choices, np.int32),
                          size=num_clients).astype(np.int32)

    # ---- composition with the (R, K) schedule --------------------------

    def _check_schedule(self, schedule: np.ndarray) -> np.ndarray:
        schedule = np.asarray(schedule, np.int32)
        if schedule.ndim != 2 or schedule.shape[0] > self.rounds:
            raise ValueError(
                f"schedule {schedule.shape} does not fit trace "
                f"({self.rounds} rounds)")
        if schedule.min() < 0 or schedule.max() >= self.num_clients:
            raise ValueError(
                f"schedule references clients outside 0.."
                f"{self.num_clients - 1}")
        return schedule

    def valid_for(self, schedule: np.ndarray) -> np.ndarray:
        """The ``(R, K)`` f32 validity mask of a client schedule —
        ``1.0`` where the scheduled client is available that round."""
        schedule = self._check_schedule(schedule)
        rows = np.arange(schedule.shape[0])[:, None]
        return self.avail[rows, schedule].astype(np.float32)

    def participation(self, schedule: np.ndarray) -> np.ndarray:
        """Per-round survivor counts, ``(R,)`` int."""
        return self.valid_for(schedule).sum(axis=1).astype(np.int64)

    def resample_schedule(self, schedule: np.ndarray,
                          seed: int) -> np.ndarray:
        """Dynamic sampling (Ji et al. 2020): replace each round's
        dropped scheduled clients with seeded draws from that round's
        available, not-yet-scheduled clients.  Rounds with too few
        spares keep the unreplaced dropped entries (they stay masked
        invalid by ``valid_for``)."""
        schedule = self._check_schedule(schedule).copy()
        rng = np.random.RandomState(seed + _TRACE_SEED_SALT)
        for r in range(schedule.shape[0]):
            row = schedule[r]
            dead = [k for k, c in enumerate(row) if not self.avail[r, c]]
            if not dead:
                continue
            taken = set(int(c) for c in row)
            spares = [c for c in np.flatnonzero(self.avail[r])
                      if int(c) not in taken]
            if not spares:
                continue
            picks = rng.choice(np.asarray(spares, np.int32),
                               size=min(len(dead), len(spares)),
                               replace=False)
            for k, c in zip(dead, picks):
                row[k] = c
        return schedule


def make_availability(cfg: FLConfig,
                      seed: Optional[int] = None
                      ) -> Optional[AvailabilityTrace]:
    """Build the trace ``cfg`` describes (None for ``"always"``).

    The trace seed is ``seed`` (default ``cfg.seed``) salted so the
    availability stream never aliases the schedule RNG at equal seeds.
    """
    if cfg.availability == "always":
        return None
    base = (cfg.seed if seed is None else int(seed)) + _TRACE_SEED_SALT
    if cfg.availability == "bernoulli":
        return AvailabilityTrace.bernoulli(base, cfg.rounds,
                                           cfg.num_clients, cfg.dropout)
    if cfg.availability == "markov":
        return AvailabilityTrace.markov(base, cfg.rounds, cfg.num_clients,
                                        cfg.dropout, cfg.churn)
    raise ValueError(
        f"unknown availability {cfg.availability!r} "
        f"(one of {AVAILABILITY_KINDS})")


def check_engine_support(cfg: FLConfig,
                         trace: Optional[AvailabilityTrace],
                         engine: str) -> None:
    """Refuse config/engine combinations that would silently mis-count
    dropped clients instead of masking them."""
    if trace is None:
        return
    if trace.rounds < cfg.rounds or trace.num_clients != cfg.num_clients:
        raise ValueError(
            f"availability trace is ({trace.rounds}, {trace.num_clients}) "
            f"but cfg needs ({cfg.rounds}, {cfg.num_clients})")
    if cfg.int_mask_agg and engine not in ("cohort", "service"):
        # the scan/batched/looped count aggregate folds wn[0] over the
        # summed counts — a zeroed dropped-client weight would poison it;
        # the cohort/service partial chain masks counts correctly
        raise ValueError(
            "int_mask_agg cannot mask dropped clients on engine="
            f"{engine!r} (the count aggregate folds one weight scalar) — "
            "run availability scenarios on engine='cohort' or 'service'")
    if cfg.privacy is not None and engine not in ("cohort", "looped",
                                                  "service"):
        # the DP count release must sum EXACTLY the surviving clients —
        # scan/batched stack all K rows and mask by weight, which the
        # unweighted count wire cannot honour; looped genuinely excludes
        # dropped clients and cohort/service mask via the valid= chain
        raise ValueError(
            "privacy= cannot mask dropped clients on engine="
            f"{engine!r} — run availability scenarios on "
            "engine='cohort', 'looped' or 'service'")
    if cfg.error_feedback:
        raise ValueError(
            "error_feedback under partial participation would update "
            "dropped clients' residual slots — availability traces do "
            "not support it yet")
    if trace.local_steps is not None and engine != "service":
        raise ValueError(
            "per-client local_steps are served per seat by the service "
            f"engine only; engine={engine!r} bakes cfg.local_steps into "
            "compiled shapes")


def require_survivors(valid: np.ndarray, *, resample_hint: bool) -> None:
    """Raise before dispatch when any round would aggregate 0 clients."""
    valid = np.asarray(valid)
    empty = np.flatnonzero(valid.sum(axis=-1) == 0)
    if empty.size:
        hint = ("" if resample_hint else
                " — lower dropout or set avail_resample=True")
        raise ValueError(
            f"availability trace leaves round(s) {empty[:8].tolist()} "
            f"with zero surviving clients{hint}")


# ---------------------------------------------------------------------------
# service-tier fault injection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic injected faults, keyed by ``(round, worker slot)``.

    ``drop_uplinks``     the seat computes its update but never POSTs it
                         (a mid-flight network loss) — the round can only
                         close at a sync ``quorum`` / async ``min_fresh``.
    ``delay_uplinks``    ``(round, slot, lag)``: the POST is withheld
                         until the coordinator is ``lag`` rounds past the
                         sending round (generalizes ``straggler_slots``).
    ``corrupt_uplinks``  the seat POSTs a truncated frame; the
                         coordinator must answer 400 (serde refuses the
                         bytes) and never crash — the real message is
                         lost, exactly like a drop plus a reject counter.
    ``crash_slots``      the seat exits at the start of that round and
                         never participates again.
    ``hang_slots``       the seat sleeps ``hang_sleep_s`` at the start of
                         that round — the regression target of the
                         hung-worker satellite (``join`` returns with the
                         thread still alive).
    """

    drop_uplinks: Tuple[Tuple[int, int], ...] = ()
    delay_uplinks: Tuple[Tuple[int, int, int], ...] = ()
    corrupt_uplinks: Tuple[Tuple[int, int], ...] = ()
    crash_slots: Tuple[Tuple[int, int], ...] = ()
    hang_slots: Tuple[Tuple[int, int], ...] = ()
    hang_sleep_s: float = 120.0

    def validate(self, rounds: int, num_slots: int) -> None:
        def check(name, pairs):
            for entry in pairs:
                r, s = entry[0], entry[1]
                if not (0 <= r < rounds and 0 <= s < num_slots):
                    raise ValueError(
                        f"FaultPlan.{name} entry {entry} outside "
                        f"rounds 0..{rounds - 1} / slots 0.."
                        f"{num_slots - 1}")
        check("drop_uplinks", self.drop_uplinks)
        check("delay_uplinks", self.delay_uplinks)
        check("corrupt_uplinks", self.corrupt_uplinks)
        check("crash_slots", self.crash_slots)
        check("hang_slots", self.hang_slots)
        for r, s, lag in self.delay_uplinks:
            if lag < 1:
                raise ValueError(
                    f"delay_uplinks lag must be >= 1, got {lag}")
        if self.hang_sleep_s <= 0:
            raise ValueError("hang_sleep_s must be positive")

    # ---- lookups (worker loop hot path) --------------------------------

    def drops(self, r: int, slot: int) -> bool:
        return (r, slot) in self.drop_uplinks

    def delay(self, r: int, slot: int) -> int:
        for rr, ss, lag in self.delay_uplinks:
            if rr == r and ss == slot:
                return lag
        return 0

    def corrupts(self, r: int, slot: int) -> bool:
        return (r, slot) in self.corrupt_uplinks

    def crashes(self, r: int, slot: int) -> bool:
        return (r, slot) in self.crash_slots

    def hangs(self, r: int, slot: int) -> bool:
        return (r, slot) in self.hang_slots

    def lost_uplinks(self) -> int:
        """Messages the plan guarantees never aggregate (drops +
        corrupts) — the balance term the accounting tests close on."""
        return len(self.drop_uplinks) + len(self.corrupt_uplinks)
