"""Algorithm plugin registry — every FL family behind one interface.

An :class:`Algorithm` packages everything the execution engines need to
know about one federated-learning family:

  init_state(cfg, params)       cross-round state pytree ({} if stateless)
  make_round_body(loss_fn, cfg, params)
                                -> seeded_round_body(seed, w, state,
                                       batches, picked, round_idx, weights)
                                   -> (new_w, new_state, losses)
  uplink_record(cfg, params)    exact per-client uplink bits of one round
  validate(cfg)                 raise ValueError on a nonsense config

The round body is PURE and takes the experiment ``seed`` as a *traced*
int32 scalar (not a closure constant): that is what lets a multi-seed
sweep ``vmap`` the whole experiment program over a seed axis with one
compile (``fed.engine.make_sweep_program``).  The drivers in
``fed/engine.py`` bind ``seed = cfg.seed`` for ordinary single-seed runs,
so trajectories are unchanged.

Built-in families (extracted from the seed-era ``if/elif`` branches):

  fedmrn / fedmrns   PSM local training → masks → packed uplink → Eq.(5)
  fedavg             float updates, plus one registered algorithm per
                     post-training compressor (signsgd … post_sm)
  fedpm              supermask-as-weights baseline (Isik et al.)
  fedsparsify        magnitude-pruned weight upload baseline

Third-party algorithms register WITHOUT touching engine internals::

    from repro.fed import Algorithm, register_algorithm

    register_algorithm(Algorithm(
        name="my_algo",
        make_round_body=my_builder,      # (loss_fn, cfg, params) -> body
        init_state=lambda cfg, p: {},
        uplink_record=lambda cfg, p: 32 * tree_num_params(p),
    ))

and every engine (scan / batched / looped drivers), the Experiment API,
examples, and benchmarks pick it up by name.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (FedMRNConfig, NoiseConfig, baseline_record,
                    client_round_key, fedmrn_record, final_mask_key,
                    gen_noise, make_compressor, mix_add, psm_local_train,
                    sample_final_mask, sgd_local_update, tree_masked_noise,
                    tree_num_params, tree_pack_stacked, tree_unpack_stacked)
from ..core.compressors import REGISTRY as COMPRESSOR_REGISTRY

Pytree = Any
RoundBody = Callable[..., Tuple[Pytree, Pytree, jax.Array]]


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedmrn"
    num_clients: int = 20
    clients_per_round: int = 5
    rounds: int = 30
    local_steps: int = 20
    batch_size: int = 32
    lr: float = 0.1
    seed: int = 0
    # fedmrn specifics (paper defaults: uniform, 1e-2 / 5e-3)
    noise_dist: str = "uniform"
    noise_alpha: float = 1e-2
    use_sm: bool = True
    use_pm: bool = True
    error_feedback: bool = False
    # beyond-paper: one shared noise G(s_t) per ROUND (instead of per
    # client).  Masks stay per-client, so the uplink is unchanged (1 bpp),
    # but Σ_k G(s_k)⊙m_k = G(s_t) ⊙ Σ_k m_k — the server aggregation
    # becomes an integer mask-count (popcount) scaled by one noise tensor,
    # and at pod scale the mask all-gather can become a ⌈log2(K+1)⌉-bit
    # integer all-reduce (a further ~3× cross-client traffic cut at K=16).
    shared_noise: bool = False
    # baselines
    topk_frac: float = 0.03
    sparsify_frac: float = 0.03    # fedsparsify keeps top 3% of weights
    qsgd_bits: int = 2
    # kernel backend for masking/packing: "ref" | "pallas" | None (auto)
    backend: Optional[str] = None

    def fedmrn_config(self) -> FedMRNConfig:
        mode = "signed" if self.algorithm == "fedmrns" else "binary"
        return FedMRNConfig(
            mask_mode=mode,
            noise=NoiseConfig(dist=self.noise_dist, alpha=self.noise_alpha),
            use_sm=self.use_sm, use_pm=self.use_pm,
            error_feedback=self.error_feedback, lr=self.lr,
            backend=self.backend)

    def validate(self) -> None:
        """Generic sanity checks shared by every algorithm."""
        if self.rounds <= 0:
            raise ValueError(f"rounds must be positive, got {self.rounds}")
        if self.local_steps <= 0:
            raise ValueError(
                f"local_steps must be positive, got {self.local_steps}")
        if self.batch_size <= 0:
            raise ValueError(
                f"batch_size must be positive, got {self.batch_size}")
        if not 0 < self.clients_per_round <= self.num_clients:
            raise ValueError(
                f"clients_per_round={self.clients_per_round} must be in "
                f"[1, num_clients={self.num_clients}]")


# ---------------------------------------------------------------------------
# the plugin interface + registry
# ---------------------------------------------------------------------------

def _no_state(cfg: FLConfig, params: Pytree) -> Dict[str, Pytree]:
    return {}


def _no_validate(cfg: FLConfig) -> None:
    return None


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """One pluggable FL family: round body + state + uplink accounting.

    ``make_round_body(loss_fn, cfg, params)`` must return a PURE function

        body(seed, w, state, batches, picked, round_idx, weights)
            -> (new_w, new_state, losses)     # losses: (K, S) device array

    where ``seed`` is a (possibly traced) int32 scalar — derive every PRNG
    key from it (``jax.random.key(seed + c)`` / ``client_round_key``), not
    from ``cfg.seed``, or multi-seed sweeps silently reuse one stream.

    ``uplink_kind`` declares what crosses the wire each round: ``"mask"``
    families ship (packed) mask bits whose server aggregation is a
    mask-count — the pod path defaults them to shared noise, so the
    server sum becomes a popcount-style mask count scaled by ONE noise
    tensor (no per-client noise regeneration); ``"dense"`` families ship
    float updates (the 32 bpp all-reduce baseline).  Purely advisory —
    every engine runs either kind.
    """

    name: str
    make_round_body: Callable[[Callable, FLConfig, Pytree], RoundBody]
    uplink_record: Callable[[FLConfig, Pytree], int]
    init_state: Callable[[FLConfig, Pytree], Pytree] = _no_state
    validate: Callable[[FLConfig], None] = _no_validate
    uplink_kind: str = "dense"       # "mask" | "dense" (pod aggregation hint)


ALGORITHMS: Dict[str, Algorithm] = {}


def register_algorithm(algo: Algorithm, *, overwrite: bool = False) -> Algorithm:
    """Add ``algo`` to the registry (raises on duplicate names)."""
    if not algo.name:
        raise ValueError("algorithm needs a non-empty name")
    if algo.name in ALGORITHMS and not overwrite:
        raise ValueError(
            f"algorithm {algo.name!r} already registered "
            "(pass overwrite=True to replace)")
    ALGORITHMS[algo.name] = algo
    return algo


def get_algorithm(name: str) -> Algorithm:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r} "
            f"(registered: {', '.join(sorted(ALGORITHMS))})") from None


def list_algorithms() -> Tuple[str, ...]:
    return tuple(sorted(ALGORITHMS))


def uplink_bits(cfg: FLConfig, params: Pytree) -> int:
    """Exact per-client uplink cost of one round (for history accounting)."""
    return get_algorithm(cfg.algorithm).uplink_record(cfg, params)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _tree_zeros_like(t: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def _weighted_sum(weights: jax.Array, stacked: Pytree) -> Pytree:
    """Σ_k w_k · leaf[k] over the leading client axis of every leaf."""
    return jax.tree_util.tree_map(
        lambda x: jnp.tensordot(weights, x.astype(jnp.float32), axes=1),
        stacked)


# ---------------------------------------------------------------------------
# per-client local updates for the baselines (shared with the looped engine)
# ---------------------------------------------------------------------------

def fedpm_local(loss_fn, w_init, scores, batches, *, lr, key):
    """Train sigmoid-scores; weights = w_init ⊙ Bern(sigmoid(s)) with STE."""

    def masked_params(s, k):
        leaves, treedef = jax.tree_util.tree_flatten(s)
        w_leaves = jax.tree_util.tree_leaves(w_init)
        out = []
        for i, (sl, wl) in enumerate(zip(leaves, w_leaves)):
            prob = jax.nn.sigmoid(sl)
            m = jax.random.bernoulli(jax.random.fold_in(k, i), prob)
            m = prob + jax.lax.stop_gradient(m.astype(prob.dtype) - prob)
            out.append(wl * m)
        return jax.tree_util.tree_unflatten(treedef, out)

    def step(s, inp):
        tau, batch = inp
        k = jax.random.fold_in(key, tau)

        def fwd(s_):
            return loss_fn(masked_params(s_, k), batch)

        loss, g = jax.value_and_grad(fwd)(s)
        s = jax.tree_util.tree_map(lambda a, gi: a - lr * gi, s, g)
        return s, loss

    n = jax.tree_util.tree_leaves(batches)[0].shape[0]
    s_final, losses = jax.lax.scan(step, scores,
                                   (jnp.arange(n), batches))
    # uplink: Bernoulli-sampled masks, one independent draw per leaf
    # (folding the leaf index keeps same-shaped leaves decorrelated)
    leaves, treedef = jax.tree_util.tree_flatten(s_final)
    mask_key = jax.random.fold_in(key, n + 1)
    masks = jax.tree_util.tree_unflatten(treedef, [
        jax.random.bernoulli(jax.random.fold_in(mask_key, i),
                             jax.nn.sigmoid(sl)).astype(jnp.float32)
        for i, sl in enumerate(leaves)])
    return masks, losses


def fedsparsify_local(loss_fn, w, batches, *, lr, frac):
    w_new, losses = sgd_local_update(loss_fn, w, batches, lr=lr)
    w_new = jax.tree_util.tree_map(jnp.add, w, w_new)  # u → w_local

    def prune(x):
        flat = jnp.abs(x).reshape(-1)
        k = max(1, int(np.ceil(frac * flat.shape[0])))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)

    return jax.tree_util.tree_map(prune, w_new), losses


# ---------------------------------------------------------------------------
# built-in round bodies, one per algorithm family
# ---------------------------------------------------------------------------

def _fedmrn_body(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
    mrn = cfg.fedmrn_config()
    ef = cfg.error_feedback

    def round_fn(seed, w, state, batches, picked, round_idx, weights):
        train_base = jax.random.key(seed + 1)

        def per_client(b, cid, r0):
            noise_id = jnp.int32(0) if cfg.shared_noise else cid
            seed_key = client_round_key(seed, round_idx, noise_id)
            noise = gen_noise(seed_key, w, mrn.noise)
            train_key = jax.random.fold_in(train_base,
                                           round_idx * 1000 + cid)
            u, losses = psm_local_train(loss_fn, w, b, noise, train_key,
                                        cfg=mrn, u0=r0 if ef else None)
            # step count from the batches, NOT cfg.local_steps — the mask
            # key must track the real S or parity with the looped
            # reference breaks when a caller varies steps per round
            num_steps = jax.tree_util.tree_leaves(b)[0].shape[0]
            m = sample_final_mask(
                u, noise, final_mask_key(train_key, num_steps), cfg=mrn)
            residual = (jax.tree_util.tree_map(
                jnp.subtract, u, tree_masked_noise(noise, m))
                if ef else None)
            return m, losses, residual

        r0 = (jax.tree_util.tree_map(lambda r: r[picked],
                                     state["residuals"])
              if ef else jnp.zeros((picked.shape[0],)))
        masks, losses, residuals = jax.vmap(per_client)(batches, picked, r0)

        # ---- uplink: the wire payload, packed in one kernel launch ------
        payload = tree_pack_stacked(masks, mode=mrn.mask_mode,
                                    backend=cfg.backend)

        # ---- server: unpack, regen noise from seeds, Eq. (5) ------------
        m_rec = tree_unpack_stacked(payload, w, mode=mrn.mask_mode,
                                    backend=cfg.backend)
        wn = weights / jnp.sum(weights)
        if cfg.shared_noise:
            # Σ_k p'_k G(s_t)⊙m_k = G(s_t) ⊙ Σ_k p'_k m_k: one noise
            # tensor scales an (integer-valued) mask average
            noise = gen_noise(client_round_key(seed, round_idx, 0),
                              w, mrn.noise)
            m_avg = _weighted_sum(wn, m_rec)
            agg = jax.tree_util.tree_map(
                lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_avg)
        else:
            def decode(cid, m_c):
                noise = gen_noise(client_round_key(seed, round_idx, cid),
                                  w, mrn.noise)
                return jax.tree_util.tree_map(
                    lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_c)

            u_hats = jax.vmap(decode)(picked, m_rec)
            agg = _weighted_sum(wn, u_hats)
        new_w = jax.tree_util.tree_map(mix_add, w, agg)

        new_state = state
        if ef:
            new_state = {"residuals": jax.tree_util.tree_map(
                lambda r, nr: r.at[picked].set(nr),
                state["residuals"], residuals)}
        return new_w, new_state, losses

    return round_fn


def _fedmrn_state(cfg: FLConfig, params: Pytree) -> Dict[str, Pytree]:
    if not cfg.error_feedback:
        return {}
    # Device-resident residual stack: num_clients × model size.  Keeps
    # the gather/scatter inside the round program (no host sync), at
    # the cost of a dense buffer — fine for simulation-scale client
    # counts; a cross-silo run with thousands of clients should shard
    # this stack or carry residuals host-side instead.
    return {"residuals": jax.tree_util.tree_map(
        lambda p: jnp.zeros((cfg.num_clients,) + p.shape, p.dtype),
        params)}


def _fedmrn_validate(cfg: FLConfig) -> None:
    if cfg.noise_alpha <= 0:
        raise ValueError(
            f"noise_alpha must be positive, got {cfg.noise_alpha}")
    NoiseConfig(dist=cfg.noise_dist, alpha=cfg.noise_alpha)  # checks dist


def _fedavg_family_body(compressor_name: Optional[str]):
    """Round-body builder for fedavg and every post-training compressor."""

    def build(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
        mrn = cfg.fedmrn_config()
        compressor = (None if compressor_name is None else
                      make_compressor(compressor_name,
                                      topk_frac=cfg.topk_frac,
                                      qsgd_bits=cfg.qsgd_bits,
                                      noise=mrn.noise))

        def round_fn(seed, w, state, batches, picked, round_idx, weights):
            comp_base = jax.random.key(seed + 3)

            def per_client(b, cid):
                u, losses = sgd_local_update(loss_fn, w, b, lr=cfg.lr)
                if compressor is not None:
                    u = compressor.roundtrip(
                        u, jax.random.fold_in(comp_base,
                                              round_idx * 1000 + cid))
                return u, losses

            updates, losses = jax.vmap(per_client)(batches, picked)
            wn = weights / jnp.sum(weights)
            agg = _weighted_sum(wn, updates)
            new_w = jax.tree_util.tree_map(mix_add, w, agg)
            return new_w, state, losses

        return round_fn

    return build


def _fedpm_body(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
    noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)

    def round_fn(seed, w, state, batches, picked, round_idx, weights):
        # frozen random init, regenerated from the traced seed: keeps the
        # body pure in `seed` so sweeps can vmap over it.  The expression
        # is loop-invariant inside the experiment scan (seed is a chunk
        # argument), and one RNG pass over the params is small next to a
        # round's K×S training steps either way.
        w_frozen = gen_noise(jax.random.key(seed), params, noise_cfg)
        key_base = jax.random.key(seed + 2)
        scores = state["scores"]

        def per_client(b, cid):
            return fedpm_local(
                loss_fn, w_frozen, scores, b, lr=cfg.lr,
                key=jax.random.fold_in(key_base, round_idx * 1000 + cid))

        masks, losses = jax.vmap(per_client)(batches, picked)
        K = picked.shape[0]
        # Beta(1,1)-posterior (Laplace-smoothed) mask-frequency estimate,
        # accumulated in f32 regardless of param dtype.  The raw K-client
        # mean hits exactly 0/1 whenever all clients agree, and logit of
        # the clipped value (±9.2) saturates next round's sigmoid scores —
        # training freezes.  Smoothing bounds scores to |logit| ≤ ln(K+1).
        probs = jax.tree_util.tree_map(
            lambda m: (jnp.sum(m.astype(jnp.float32), axis=0) + 1.0)
            / (K + 2.0), masks)
        new_scores = jax.tree_util.tree_map(
            lambda p_: jnp.log(p_ / (1 - p_)), probs)      # sigmoid^-1
        new_w = jax.tree_util.tree_map(
            lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)
        return new_w, {"scores": new_scores}, losses

    return round_fn


def _fedsparsify_body(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
    def round_fn(seed, w, state, batches, picked, round_idx, weights):
        def per_client(b, cid):
            return fedsparsify_local(loss_fn, w, b, lr=cfg.lr,
                                     frac=cfg.sparsify_frac)

        w_locals, losses = jax.vmap(per_client)(batches, picked)
        wn = weights / jnp.sum(weights)
        new_w = _weighted_sum(wn, w_locals)
        new_w = jax.tree_util.tree_map(lambda p, a: a.astype(p.dtype),
                                       w, new_w)
        return new_w, state, losses

    return round_fn


# ---------------------------------------------------------------------------
# uplink accounting + built-in registration
# ---------------------------------------------------------------------------

def _fedmrn_bits(cfg, params):
    return fedmrn_record(tree_num_params(params)).uplink_bits


def _fedavg_bits(cfg, params):
    return 32 * tree_num_params(params)


def _baseline_bits(name, **rec_kw):
    def bits(cfg, params):
        P = tree_num_params(params)
        L = len(jax.tree_util.tree_leaves(params))
        kw = {k: getattr(cfg, v) for k, v in rec_kw.items()}
        return baseline_record(name, P, L, **kw).uplink_bits
    return bits


def _frac_validate(field):
    def validate(cfg):
        v = getattr(cfg, field)
        if not 0 < v <= 1:
            raise ValueError(f"{field} must be in (0, 1], got {v}")
    return validate


def _qsgd_validate(cfg):
    if cfg.qsgd_bits < 1:
        raise ValueError(f"qsgd_bits must be >= 1, got {cfg.qsgd_bits}")


def _compressor_bits(name):
    if name == "topk":
        return _baseline_bits(name, topk_frac="topk_frac")
    if name == "qsgd":
        return _baseline_bits(name, qsgd_bits="qsgd_bits")
    return _baseline_bits(name)


def _register_builtins() -> None:
    for name in ("fedmrn", "fedmrns"):
        register_algorithm(Algorithm(
            name=name, make_round_body=_fedmrn_body,
            uplink_record=_fedmrn_bits, init_state=_fedmrn_state,
            validate=_fedmrn_validate, uplink_kind="mask"))
    register_algorithm(Algorithm(
        name="fedavg", make_round_body=_fedavg_family_body(None),
        uplink_record=_fedavg_bits))
    register_algorithm(Algorithm(
        name="fedpm", make_round_body=_fedpm_body,
        uplink_record=_baseline_bits("fedpm"),
        init_state=lambda cfg, p: {"scores": _tree_zeros_like(p)},
        uplink_kind="mask"))
    register_algorithm(Algorithm(
        name="fedsparsify", make_round_body=_fedsparsify_body,
        uplink_record=_baseline_bits("fedsparsify",
                                     topk_frac="sparsify_frac"),
        validate=_frac_validate("sparsify_frac")))
    for comp in COMPRESSOR_REGISTRY:
        if comp == "none":
            continue
        register_algorithm(Algorithm(
            name=comp, make_round_body=_fedavg_family_body(comp),
            uplink_record=_compressor_bits(comp),
            validate=(_frac_validate("topk_frac") if comp == "topk"
                      else _qsgd_validate if comp == "qsgd"
                      else _no_validate)))


_register_builtins()
