"""Algorithm plugin registry — every FL family behind one interface.

An :class:`Algorithm` packages everything the execution engines need to
know about one federated-learning family:

  init_state(cfg, params)       cross-round state pytree ({} if stateless)
  make_round_body(loss_fn, cfg, params)
                                -> seeded_round_body(seed, w, state,
                                       batches, picked, round_idx, weights)
                                   -> (new_w, new_state, losses[,
                                       wire_bits])
  codec(cfg, params)            -> the family's typed uplink wire format
                                   (an :class:`~repro.fed.codecs.
                                   UplinkCodec`): what the round body
                                   routes client outputs through
                                   (encode → stacked WireMsg →
                                   aggregate), and where engines read
                                   the measured comm cost
  validate(cfg)                 raise ValueError on a nonsense config

The round body is PURE and takes the experiment ``seed`` as a *traced*
int32 scalar (not a closure constant): that is what lets a multi-seed
sweep ``vmap`` the whole experiment program over a seed axis with one
compile (``fed.engine.make_sweep_program``).  The drivers in
``fed/engine.py`` bind ``seed = cfg.seed`` for ordinary single-seed runs,
so trajectories are unchanged.  The optional 4th output ``wire_bits``
is the round's K-client MEASURED uplink (summed encoded ``WireMsg``
buffer sizes — ``codec.round_bits(msg)``); engines fall back to the
codec's static report for legacy 3-tuple bodies.

Built-in families (extracted from the seed-era ``if/elif`` branches):

  fedmrn / fedmrns   PSM local training → MaskCodec (packed masks +
                     64-bit seed) → Eq.(5) via codec.aggregate
  fedavg             DenseCodec f32 updates, plus one registered
                     algorithm per post-training compressor (signsgd →
                     SignCodec, topk → SparseCodec, the rest roundtrip
                     in-body over DenseCodec transport)
  fedpm              supermask-as-weights baseline (Isik et al.) —
                     MaskCodec mask-frequency aggregation
  fedsparsify        magnitude-pruned weight upload → SparseCodec

Third-party algorithms register WITHOUT touching engine internals::

    from repro.fed import Algorithm, register_algorithm
    from repro.fed.codecs import DenseCodec, template_of

    register_algorithm(Algorithm(
        name="my_algo",
        make_round_body=my_builder,      # (loss_fn, cfg, params) -> body
        init_state=lambda cfg, p: {},
        codec=lambda cfg, p: DenseCodec(template_of(p), name="my_algo"),
    ))

and every engine (scan / batched / looped drivers), the pod path, the
Experiment API, examples, and benchmarks pick it up by name.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (FedMRNConfig, NoiseConfig, baseline_record,
                    client_round_key, final_mask_key,
                    gen_noise, make_compressor, mix_add, psm_local_train,
                    sample_final_mask, sgd_local_update, tree_masked_noise,
                    tree_num_params)
from ..core.compressors import REGISTRY as COMPRESSOR_REGISTRY
from ..core.masking import tree_bernoulli_stacked
from .codecs import (DenseCodec, MaskCodec, QuantCodec, SignCodec,
                     SparseCodec, UplinkCodec, min_count_dtype,
                     template_of)
from .privacy.dp import PrivacyConfig, check_privacy_support

Pytree = Any
RoundBody = Callable[..., Tuple[Pytree, Pytree, jax.Array]]
# the cohort tier's split round body: (stacked msg, agg weights, losses)
# out of one cohort's clients, and a server apply over the merged
# aggregate — see Algorithm.make_cohort_body
CohortBody = Tuple[UplinkCodec, Callable[..., Tuple[Any, jax.Array,
                                                    jax.Array]],
                   Callable[..., Tuple[Pytree, Pytree]]]


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedmrn"
    num_clients: int = 20
    clients_per_round: int = 5
    rounds: int = 30
    local_steps: int = 20
    batch_size: int = 32
    lr: float = 0.1
    seed: int = 0
    # fedmrn specifics (paper defaults: uniform, 1e-2 / 5e-3)
    noise_dist: str = "uniform"
    noise_alpha: float = 1e-2
    use_sm: bool = True
    use_pm: bool = True
    error_feedback: bool = False
    # beyond-paper: one shared noise G(s_t) per ROUND (instead of per
    # client).  Masks stay per-client, so the uplink is unchanged (1 bpp),
    # but Σ_k G(s_k)⊙m_k = G(s_t) ⊙ Σ_k m_k — the server aggregation
    # becomes an integer mask-count (popcount) scaled by one noise tensor,
    # and at pod scale the mask all-gather can become a ⌈log2(K+1)⌉-bit
    # integer all-reduce (a further ~3× cross-client traffic cut at K=16).
    shared_noise: bool = False
    # aggregate mask COUNTS in the minimal integer dtype holding
    # ⌈log2(K+1)⌉ bits instead of f32 (the pod-path wire format for mask
    # families — the cross-client all-reduce then moves int8/int16 words).
    # Requires uniform client weights (engines enforce) and a
    # count-aggregatable format (fedpm, or fedmrn with shared_noise).
    int_mask_agg: bool = False
    # baselines
    topk_frac: float = 0.03
    sparsify_frac: float = 0.03    # fedsparsify keeps top 3% of weights
    qsgd_bits: int = 2
    # client availability (ROADMAP 4(b)): a seeded per-round dropout
    # trace derived from the run seed; engines mask dropped clients out
    # of the aggregate (exactly the K−d survivors are averaged).
    availability: str = "always"   # "always" | "bernoulli" | "markov"
    dropout: float = 0.0           # drop prob / Markov stationary rate
    churn: float = 0.5             # markov: state-flip speed in (0, 1]
    # Ji et al. 2020 dynamic sampling: re-draw dropped scheduled clients
    # from the round's still-available spares before masking
    avail_resample: bool = False
    # distributed DP on the mask-count wire (fed/privacy/): clip each
    # client's count contribution, add one discrete noise draw to the
    # merged round count at finalize, account (ε, δ) per round at the
    # recorded participation.  Count-aggregatable mask families only
    # (fedmrn/fedmrns need shared_noise); requires uniform client
    # weights (engines enforce, same rule as int_mask_agg).
    privacy: Optional[PrivacyConfig] = None
    # kernel backend for masking/packing: "ref" | "pallas" | None (auto)
    backend: Optional[str] = None

    def fedmrn_config(self) -> FedMRNConfig:
        mode = "signed" if self.algorithm == "fedmrns" else "binary"
        return FedMRNConfig(
            mask_mode=mode,
            noise=NoiseConfig(dist=self.noise_dist, alpha=self.noise_alpha),
            use_sm=self.use_sm, use_pm=self.use_pm,
            error_feedback=self.error_feedback, lr=self.lr,
            backend=self.backend)

    def validate(self) -> None:
        """Generic sanity checks shared by every algorithm."""
        if self.rounds <= 0:
            raise ValueError(f"rounds must be positive, got {self.rounds}")
        if self.local_steps <= 0:
            raise ValueError(
                f"local_steps must be positive, got {self.local_steps}")
        if self.batch_size <= 0:
            raise ValueError(
                f"batch_size must be positive, got {self.batch_size}")
        if not 0 < self.clients_per_round <= self.num_clients:
            raise ValueError(
                f"clients_per_round={self.clients_per_round} must be in "
                f"[1, num_clients={self.num_clients}]")
        if self.availability not in ("always", "bernoulli", "markov"):
            raise ValueError(
                f"availability {self.availability!r} is not 'always', "
                "'bernoulli' or 'markov'")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(
                f"dropout must be in [0, 1), got {self.dropout}")
        if not 0.0 < self.churn <= 1.0:
            raise ValueError(f"churn must be in (0, 1], got {self.churn}")
        check_privacy_support(self)


# ---------------------------------------------------------------------------
# the plugin interface + registry
# ---------------------------------------------------------------------------

def _no_state(cfg: FLConfig, params: Pytree) -> Dict[str, Pytree]:
    return {}


def _no_validate(cfg: FLConfig) -> None:
    return None


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """One pluggable FL family: round body + state + wire format.

    ``make_round_body(loss_fn, cfg, params)`` must return a PURE function

        body(seed, w, state, batches, picked, round_idx, weights)
            -> (new_w, new_state, losses[, wire_bits])
                                              # losses: (K, S) device array

    where ``seed`` is a (possibly traced) int32 scalar — derive every PRNG
    key from it (``jax.random.key(seed + c)`` / ``client_round_key``), not
    from ``cfg.seed``, or multi-seed sweeps silently reuse one stream.
    The optional ``wire_bits`` output is the round's measured K-client
    uplink (``codec.round_bits(stacked_msg)``) — engines substitute the
    codec's static report when a legacy body returns a 3-tuple.

    ``codec(cfg, params)`` returns the family's
    :class:`~repro.fed.codecs.UplinkCodec` — the typed wire format the
    round body routes client outputs through and the single source of
    comm accounting (``codec.wire_bits(params) -> CommRecord``); every
    algorithm MUST declare one (a plugin that only wants a cost report
    wraps it in a :class:`DenseCodec` ``record=`` override).
    """

    name: str
    make_round_body: Callable[[Callable, FLConfig, Pytree], RoundBody]
    codec: Optional[Callable[[FLConfig, Pytree], UplinkCodec]] = None
    init_state: Callable[[FLConfig, Pytree], Pytree] = _no_state
    validate: Callable[[FLConfig], None] = _no_validate
    # the streaming cohort tier's SPLIT round body (optional):
    #
    #   make_cohort_body(loss_fn, cfg, params)
    #       -> (codec,
    #           uplink(seed, w, state, batches, cids, weights, round_idx)
    #               -> (stacked WireMsg, agg_weights (Kc,), losses (Kc,S)),
    #           apply(seed, w, state, aggregate, round_idx,
    #                 n_valid=None)          # merged partial weight mass
    #               -> (new_w, new_state))   # (degraded-round engines
    #                                        #  pass it; fedpm's smoothing
    #                                        #  denominator needs it)
    #
    # The engine runs `uplink` once per cohort, folds the messages into
    # codec partials (codec.partial_aggregate / merge_partials), and
    # calls `apply` once per round on the finalized aggregate — the
    # trajectory must match make_round_body over the concatenated
    # client stack.  None → the family cannot stream (engines raise).
    make_cohort_body: Optional[
        Callable[[Callable, FLConfig, Pytree], CohortBody]] = None


ALGORITHMS: Dict[str, Algorithm] = {}


def register_algorithm(algo: Algorithm, *, overwrite: bool = False) -> Algorithm:
    """Add ``algo`` to the registry (raises on duplicate names)."""
    if not algo.name:
        raise ValueError("algorithm needs a non-empty name")
    if algo.codec is None:
        raise ValueError(
            f"algorithm {algo.name!r} must declare codec= (an UplinkCodec "
            "factory (cfg, params) -> UplinkCodec; see repro.fed.codecs)")
    if algo.name in ALGORITHMS and not overwrite:
        raise ValueError(
            f"algorithm {algo.name!r} already registered "
            "(pass overwrite=True to replace)")
    ALGORITHMS[algo.name] = algo
    return algo


def get_algorithm(name: str) -> Algorithm:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r} "
            f"(registered: {', '.join(sorted(ALGORITHMS))})") from None


def list_algorithms() -> Tuple[str, ...]:
    return tuple(sorted(ALGORITHMS))


def algorithm_codec(cfg: FLConfig, params: Pytree) -> UplinkCodec:
    """The registered algorithm's uplink codec for this config/model."""
    return get_algorithm(cfg.algorithm).codec(cfg, params)


def uplink_bits(cfg: FLConfig, params: Pytree) -> int:
    """Exact per-client uplink cost of one round (for history accounting).

    Measured from the codec's encoded buffer sizes (or its ``record``
    override when the wire buffers stand in for another format).
    """
    return int(algorithm_codec(cfg, params).wire_bits(params).uplink_bits)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _tree_zeros_like(t: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, t)


# ---------------------------------------------------------------------------
# per-client local updates for the baselines (shared with the looped engine)
# ---------------------------------------------------------------------------

def fedpm_local(loss_fn, w_init, scores, batches, *, lr, key, sample=True):
    """Train sigmoid-scores; weights = w_init ⊙ Bern(sigmoid(s)) with STE.

    ``sample=False`` skips the final uplink draw and returns the trained
    scores — the fused round body then hands ``sigmoid(scores)`` to
    ``MaskCodec.uplink_stacked``, which performs the SAME Bernoulli draw
    (identical key/uniform streams) inside the fused mask-uplink kernel.
    """

    def masked_params(s, k):
        leaves, treedef = jax.tree_util.tree_flatten(s)
        w_leaves = jax.tree_util.tree_leaves(w_init)
        out = []
        for i, (sl, wl) in enumerate(zip(leaves, w_leaves)):
            prob = jax.nn.sigmoid(sl)
            m = jax.random.bernoulli(jax.random.fold_in(k, i), prob)
            m = prob + jax.lax.stop_gradient(m.astype(prob.dtype) - prob)
            out.append(wl * m)
        return jax.tree_util.tree_unflatten(treedef, out)

    def step(s, inp):
        tau, batch = inp
        k = jax.random.fold_in(key, tau)

        def fwd(s_):
            return loss_fn(masked_params(s_, k), batch)

        loss, g = jax.value_and_grad(fwd)(s)
        s = jax.tree_util.tree_map(lambda a, gi: a - lr * gi, s, g)
        return s, loss

    n = jax.tree_util.tree_leaves(batches)[0].shape[0]
    s_final, losses = jax.lax.scan(step, scores,
                                   (jnp.arange(n), batches))
    if not sample:
        return s_final, losses
    # uplink: Bernoulli-sampled masks, one independent draw per leaf
    # (folding the leaf index keeps same-shaped leaves decorrelated)
    leaves, treedef = jax.tree_util.tree_flatten(s_final)
    mask_key = jax.random.fold_in(key, n + 1)
    masks = jax.tree_util.tree_unflatten(treedef, [
        jax.random.bernoulli(jax.random.fold_in(mask_key, i),
                             jax.nn.sigmoid(sl)).astype(jnp.float32)
        for i, sl in enumerate(leaves)])
    return masks, losses


def fedsparsify_local(loss_fn, w, batches, *, lr, frac):
    w_new, losses = sgd_local_update(loss_fn, w, batches, lr=lr)
    w_new = jax.tree_util.tree_map(jnp.add, w, w_new)  # u → w_local

    def prune(x):
        flat = jnp.abs(x).reshape(-1)
        k = max(1, int(np.ceil(frac * flat.shape[0])))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)

    return jax.tree_util.tree_map(prune, w_new), losses


# ---------------------------------------------------------------------------
# built-in round bodies, one per algorithm family
# ---------------------------------------------------------------------------

def _fedmrn_codec(cfg: FLConfig, params: Pytree) -> MaskCodec:
    """Packed masks + the 64-bit noise seed — the paper's wire format."""
    mrn = cfg.fedmrn_config()
    return MaskCodec(
        template_of(params), name=cfg.algorithm, mode=mrn.mask_mode,
        noise=mrn.noise, shared_noise=cfg.shared_noise,
        count_dtype=(min_count_dtype(cfg.clients_per_round)
                     if cfg.int_mask_agg else None),
        backend=cfg.backend, privacy=cfg.privacy)


def _fedmrn_body(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
    mrn = cfg.fedmrn_config()
    ef = cfg.error_feedback
    codec = _fedmrn_codec(cfg, params)
    # DM masks and error-feedback residuals need the materialized mask
    # tree; everything else ships through the fused uplink, which samples
    # + packs + count-reduces in one kernel pass on the pallas backend
    # (and stays the staged legacy composition, bitwise, on ref)
    fused = mrn.use_sm and not ef

    def round_fn(seed, w, state, batches, picked, round_idx, weights):
        train_base = jax.random.key(seed + 1)

        def per_client(b, cid, r0):
            noise_id = jnp.int32(0) if cfg.shared_noise else cid
            seed_key = client_round_key(seed, round_idx, noise_id)
            noise = gen_noise(seed_key, w, mrn.noise)
            train_key = jax.random.fold_in(train_base,
                                           round_idx * 1000 + cid)
            u, losses = psm_local_train(loss_fn, w, b, noise, train_key,
                                        cfg=mrn, u0=r0 if ef else None)
            # step count from the batches, NOT cfg.local_steps — the mask
            # key must track the real S or parity with the looped
            # reference breaks when a caller varies steps per round
            num_steps = jax.tree_util.tree_leaves(b)[0].shape[0]
            mask_key = final_mask_key(train_key, num_steps)
            if fused:
                # the final draw happens inside codec.uplink_stacked
                return u, seed_key, mask_key, losses
            m = sample_final_mask(u, noise, mask_key, cfg=mrn)
            residual = (jax.tree_util.tree_map(
                jnp.subtract, u, tree_masked_noise(noise, m))
                if ef else None)
            return m, seed_key, losses, residual

        r0 = (jax.tree_util.tree_map(lambda r: r[picked],
                                     state["residuals"])
              if ef else jnp.zeros((picked.shape[0],)))

        if fused:
            # ---- uplink + server sum in ONE fused pass (Eq. 5) ---------
            u_stack, seed_keys, mask_keys, losses = jax.vmap(per_client)(
                batches, picked, r0)
            msg, agg = codec.uplink_stacked(u_stack, seed_keys, mask_keys,
                                            weights, round_idx=round_idx)
            new_w = jax.tree_util.tree_map(mix_add, w, agg)
            return new_w, state, losses, codec.round_bits(msg)

        masks, seed_keys, losses, residuals = jax.vmap(per_client)(
            batches, picked, r0)
        # ---- uplink: (packed masks, seeds) encoded in one kernel launch
        msg = codec.encode_stacked({"mask": masks, "seed": seed_keys})
        # ---- server: the codec is the decode boundary — Eq. (5) --------
        new_w = codec.aggregate_apply(msg, weights, w, round_idx=round_idx)

        new_state = state
        if ef:
            new_state = {"residuals": jax.tree_util.tree_map(
                lambda r, nr: r.at[picked].set(nr),
                state["residuals"], residuals)}
        return new_w, new_state, losses, codec.round_bits(msg)

    return round_fn


def _fedmrn_state(cfg: FLConfig, params: Pytree) -> Dict[str, Pytree]:
    if not cfg.error_feedback:
        return {}
    # Device-resident residual stack: num_clients × model size.  Keeps
    # the gather/scatter inside the round program (no host sync), at
    # the cost of a dense buffer — fine for simulation-scale client
    # counts; a cross-silo run with thousands of clients should shard
    # this stack or carry residuals host-side instead.
    return {"residuals": jax.tree_util.tree_map(
        lambda p: jnp.zeros((cfg.num_clients,) + p.shape, p.dtype),
        params)}


def _fedmrn_validate(cfg: FLConfig) -> None:
    if cfg.noise_alpha <= 0:
        raise ValueError(
            f"noise_alpha must be positive, got {cfg.noise_alpha}")
    if cfg.int_mask_agg and not cfg.shared_noise:
        raise ValueError(
            "int_mask_agg needs shared_noise for fedmrn: with per-client "
            "noise the server update Σ w'_k G(s_k)⊙m_k is not a function "
            "of mask counts")
    NoiseConfig(dist=cfg.noise_dist, alpha=cfg.noise_alpha)  # checks dist


def _fedmrn_cohort_body(loss_fn, cfg: FLConfig, params: Pytree) -> CohortBody:
    """Cohort-streaming split of the FedMRN round: PSM train + mask draw
    per cohort, Eq. (5) applied once on the merged codec partials."""
    if cfg.error_feedback:
        raise ValueError(
            "engine='cohort' streams cohorts through device memory; "
            "error_feedback keeps a C × P residual stack resident — run "
            "it on engine='scan'")
    mrn = cfg.fedmrn_config()
    codec = _fedmrn_codec(cfg, params)

    def uplink(seed, w, state, batches, cids, weights, round_idx):
        train_base = jax.random.key(seed + 1)

        def per_client(b, cid):
            noise_id = jnp.int32(0) if cfg.shared_noise else cid
            seed_key = client_round_key(seed, round_idx, noise_id)
            noise = gen_noise(seed_key, w, mrn.noise)
            train_key = jax.random.fold_in(train_base,
                                           round_idx * 1000 + cid)
            u, losses = psm_local_train(loss_fn, w, b, noise, train_key,
                                        cfg=mrn)
            num_steps = jax.tree_util.tree_leaves(b)[0].shape[0]
            mask_key = final_mask_key(train_key, num_steps)
            m = sample_final_mask(u, noise, mask_key, cfg=mrn)
            return m, seed_key, losses

        masks, seed_keys, losses = jax.vmap(per_client)(batches, cids)
        msg = codec.encode_stacked({"mask": masks, "seed": seed_keys})
        return msg, weights, losses

    def apply(seed, w, state, agg, round_idx, n_valid=None):
        return jax.tree_util.tree_map(mix_add, w, agg), state

    return codec, uplink, apply


# compressors whose quantization IS the codec's encode step (no in-body
# roundtrip): deterministic sign → SignCodec, magnitude top-k →
# SparseCodec, stochastic uniform quantizers → QuantCodec
_CODEC_COMPRESSORS = ("signsgd", "topk", "qsgd", "terngrad")


def _fedavg_family_codec(compressor_name: Optional[str]):
    """Codec factory for fedavg + every post-training compressor entry."""

    def factory(cfg: FLConfig, params: Pytree) -> UplinkCodec:
        t = template_of(params)
        if compressor_name is None:
            return DenseCodec(t, name="fedavg")
        if compressor_name == "signsgd":
            return SignCodec(t, name="signsgd", backend=cfg.backend)
        if compressor_name == "topk":
            return SparseCodec(t, name="topk", frac=cfg.topk_frac)
        if compressor_name == "qsgd":
            return QuantCodec(t, name="qsgd",
                              levels=(1 << cfg.qsgd_bits) - 1,
                              paper_bpp=float(cfg.qsgd_bits))
        if compressor_name == "terngrad":
            return QuantCodec(t, name="terngrad", levels=1,
                              paper_bpp=math.log2(3))
        # the remaining stochastic compressors roundtrip inside the body;
        # the f32 transport stands in for the quantized format, whose
        # true cost the record reports (exact + paper, comm.py §5.1.3)
        P = tree_num_params(params)
        L = len(jax.tree_util.tree_leaves(params))
        rec = baseline_record(compressor_name, P, L,
                              topk_frac=cfg.topk_frac,
                              qsgd_bits=cfg.qsgd_bits)
        return DenseCodec(t, name=compressor_name, record=rec)

    return factory


def _fedavg_family_body(compressor_name: Optional[str]):
    """Round-body builder for fedavg and every post-training compressor."""

    def build(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
        mrn = cfg.fedmrn_config()
        codec = _fedavg_family_codec(compressor_name)(cfg, params)
        compressor = (None if compressor_name is None
                      or compressor_name in _CODEC_COMPRESSORS else
                      make_compressor(compressor_name,
                                      topk_frac=cfg.topk_frac,
                                      qsgd_bits=cfg.qsgd_bits,
                                      noise=mrn.noise))

        def round_fn(seed, w, state, batches, picked, round_idx, weights):
            comp_base = jax.random.key(seed + 3)

            def per_client(b, cid):
                u, losses = sgd_local_update(loss_fn, w, b, lr=cfg.lr)
                ckey = jax.random.fold_in(comp_base,
                                          round_idx * 1000 + cid)
                if compressor is not None:
                    u = compressor.roundtrip(u, ckey)
                return u, ckey, losses

            updates, ckeys, losses = jax.vmap(per_client)(batches, picked)
            payload = {"value": updates}
            if codec.needs_key:
                # stochastic quantizers draw inside encode — same key
                # chain the in-body roundtrip used (ckeys dead-code
                # otherwise)
                payload["key"] = ckeys
            msg = codec.encode_stacked(payload)
            agg = codec.aggregate(msg, weights)
            new_w = jax.tree_util.tree_map(mix_add, w, agg)
            return new_w, state, losses, codec.round_bits(msg)

        return round_fn

    return build


def _fedavg_family_cohort_body(compressor_name: Optional[str]):
    """Cohort-tier builder for fedavg + the post-training compressors."""

    def build(loss_fn, cfg: FLConfig, params: Pytree) -> CohortBody:
        mrn = cfg.fedmrn_config()
        codec = _fedavg_family_codec(compressor_name)(cfg, params)
        compressor = (None if compressor_name is None
                      or compressor_name in _CODEC_COMPRESSORS else
                      make_compressor(compressor_name,
                                      topk_frac=cfg.topk_frac,
                                      qsgd_bits=cfg.qsgd_bits,
                                      noise=mrn.noise))

        def uplink(seed, w, state, batches, cids, weights, round_idx):
            comp_base = jax.random.key(seed + 3)

            def per_client(b, cid):
                u, losses = sgd_local_update(loss_fn, w, b, lr=cfg.lr)
                ckey = jax.random.fold_in(comp_base,
                                          round_idx * 1000 + cid)
                if compressor is not None:
                    u = compressor.roundtrip(u, ckey)
                return u, ckey, losses

            updates, ckeys, losses = jax.vmap(per_client)(batches, cids)
            payload = {"value": updates}
            if codec.needs_key:
                payload["key"] = ckeys
            return codec.encode_stacked(payload), weights, losses

        def apply(seed, w, state, agg, round_idx, n_valid=None):
            return jax.tree_util.tree_map(mix_add, w, agg), state

        return codec, uplink, apply

    return build


def _fedpm_codec(cfg: FLConfig, params: Pytree) -> MaskCodec:
    """Bernoulli-sampled masks, no noise seed: the server aggregate is
    the raw VOTE count (``normalize=False``; the body passes unit
    weights and applies the Beta(1,1) smoothing), integer-dtype when
    ``int_mask_agg``."""
    return MaskCodec(
        template_of(params), name="fedpm", mode="binary", normalize=False,
        count_dtype=(min_count_dtype(cfg.clients_per_round)
                     if cfg.int_mask_agg else None),
        backend=cfg.backend, privacy=cfg.privacy)


def fedpm_posterior(m_sum: Pytree, nv, *, clamp: bool):
    """Beta(1,1)-smoothed mask posterior + logit scores from a vote sum.

    ``clamp`` bounds the smoothed probability to the open interval the
    NOISELESS release spans, [1/(nv+2), (nv+1)/(nv+2)] — the DP count
    noise can push a raw sum below −1 or past nv+1, whose logit is NaN
    and would freeze training.  With ``clamp=False`` this is exactly the
    pre-privacy expression, bitwise.
    """
    probs = jax.tree_util.tree_map(lambda s: (s + 1.0) / (nv + 2.0), m_sum)
    if clamp:
        lo = 1.0 / (nv + 2.0)
        hi = (nv + 1.0) / (nv + 2.0)
        probs = jax.tree_util.tree_map(
            lambda p_: jnp.clip(p_, lo, hi), probs)
    scores = jax.tree_util.tree_map(
        lambda p_: jnp.log(p_ / (1 - p_)), probs)          # sigmoid^-1
    return probs, scores


def _fedpm_body(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
    noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)
    codec = _fedpm_codec(cfg, params)

    def round_fn(seed, w, state, batches, picked, round_idx, weights):
        # frozen random init, regenerated from the traced seed: keeps the
        # body pure in `seed` so sweeps can vmap over it.  The expression
        # is loop-invariant inside the experiment scan (seed is a chunk
        # argument), and one RNG pass over the params is small next to a
        # round's K×S training steps either way.
        w_frozen = gen_noise(jax.random.key(seed), params, noise_cfg)
        key_base = jax.random.key(seed + 2)
        scores = state["scores"]

        def per_client(b, cid):
            ckey = jax.random.fold_in(key_base, round_idx * 1000 + cid)
            s_final, losses = fedpm_local(loss_fn, w_frozen, scores, b,
                                          lr=cfg.lr, key=ckey, sample=False)
            nb = jax.tree_util.tree_leaves(b)[0].shape[0]
            mask_key = jax.random.fold_in(ckey, nb + 1)
            probs_k = jax.tree_util.tree_map(jax.nn.sigmoid, s_final)
            return probs_k, mask_key, losses

        probs_k, mask_keys, losses = jax.vmap(per_client)(batches, picked)
        # ---- uplink: the fused mask draw + pack + vote count -----------
        # the posterior counts VOTES — one per surviving client,
        # ``client_weights`` magnitudes ignored (the original FedPM
        # rule): weighted counts could exceed K, push probs past 1 and
        # NaN the logit below.  A zero weight marks a DROPPED client
        # (availability trace) and casts no vote.
        votes = (weights > 0).astype(jnp.float32)
        msg, m_sum = codec.uplink_stacked(probs_k, None, mask_keys,
                                          votes, probs=True,
                                          round_idx=round_idx)
        nv = jnp.sum(votes)
        # Beta(1,1)-posterior (Laplace-smoothed) mask-frequency estimate,
        # accumulated in f32 regardless of param dtype.  The raw nv-client
        # mean hits exactly 0/1 whenever all clients agree, and logit of
        # the clipped value (±9.2) saturates next round's sigmoid scores —
        # training freezes.  Smoothing bounds scores to |logit| ≤ ln(nv+1);
        # under privacy the noisy sum is additionally clamped back into
        # the noiseless release's span before the logit (NaN guard).
        probs, new_scores = fedpm_posterior(m_sum, nv,
                                            clamp=cfg.privacy is not None)
        new_w = jax.tree_util.tree_map(
            lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)
        return new_w, {"scores": new_scores}, losses, codec.round_bits(msg)

    return round_fn


def _fedpm_cohort_body(loss_fn, cfg: FLConfig, params: Pytree) -> CohortBody:
    """Cohort-streaming FedPM: per-cohort vote counts, Beta(1,1)-smoothed
    posterior applied once on the merged count."""
    noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)
    codec = _fedpm_codec(cfg, params)

    def uplink(seed, w, state, batches, cids, weights, round_idx):
        w_frozen = gen_noise(jax.random.key(seed), params, noise_cfg)
        key_base = jax.random.key(seed + 2)
        scores = state["scores"]

        def per_client(b, cid):
            ckey = jax.random.fold_in(key_base, round_idx * 1000 + cid)
            s_final, losses = fedpm_local(loss_fn, w_frozen, scores, b,
                                          lr=cfg.lr, key=ckey, sample=False)
            nb = jax.tree_util.tree_leaves(b)[0].shape[0]
            mask_key = jax.random.fold_in(ckey, nb + 1)
            probs_k = jax.tree_util.tree_map(jax.nn.sigmoid, s_final)
            return probs_k, mask_key, losses

        probs_k, mask_keys, losses = jax.vmap(per_client)(batches, cids)
        # same Bernoulli draw (key/uniform streams) the fused uplink
        # performs; votes carry unit weight (original FedPM rule)
        masks = tree_bernoulli_stacked(probs_k, mask_keys)
        msg = codec.encode_stacked({"mask": masks})
        return msg, jnp.ones_like(weights), losses

    def apply(seed, w, state, m_sum, round_idx, n_valid=None):
        # the smoothing denominator is the number of VOTES aggregated —
        # under availability/quorum degradation the engines pass the
        # merged partial's weight mass (ones × valid) as ``n_valid``
        K = (jnp.float32(cfg.clients_per_round) if n_valid is None
             else n_valid)
        probs, new_scores = fedpm_posterior(m_sum, K,
                                            clamp=cfg.privacy is not None)
        w_frozen = gen_noise(jax.random.key(seed), params, noise_cfg)
        new_w = jax.tree_util.tree_map(
            lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)
        return new_w, {"scores": new_scores}

    return codec, uplink, apply


def _fedsparsify_codec(cfg: FLConfig, params: Pytree) -> SparseCodec:
    return SparseCodec(template_of(params), name="fedsparsify",
                       frac=cfg.sparsify_frac)


def _fedsparsify_body(loss_fn, cfg: FLConfig, params: Pytree) -> RoundBody:
    codec = _fedsparsify_codec(cfg, params)

    def round_fn(seed, w, state, batches, picked, round_idx, weights):
        def per_client(b, cid):
            return fedsparsify_local(loss_fn, w, b, lr=cfg.lr,
                                     frac=cfg.sparsify_frac)

        w_locals, losses = jax.vmap(per_client)(batches, picked)
        # the pruned local WEIGHTS are the payload: top-k values+indices
        msg = codec.encode_stacked({"value": w_locals})
        new_w = codec.aggregate(msg, weights)
        new_w = jax.tree_util.tree_map(lambda p, a: a.astype(p.dtype),
                                       w, new_w)
        return new_w, state, losses, codec.round_bits(msg)

    return round_fn


def _fedsparsify_cohort_body(loss_fn, cfg: FLConfig,
                             params: Pytree) -> CohortBody:
    codec = _fedsparsify_codec(cfg, params)

    def uplink(seed, w, state, batches, cids, weights, round_idx):
        def per_client(b, cid):
            return fedsparsify_local(loss_fn, w, b, lr=cfg.lr,
                                     frac=cfg.sparsify_frac)

        w_locals, losses = jax.vmap(per_client)(batches, cids)
        return codec.encode_stacked({"value": w_locals}), weights, losses

    def apply(seed, w, state, agg, round_idx, n_valid=None):
        new_w = jax.tree_util.tree_map(lambda p, a: a.astype(p.dtype),
                                       w, agg)
        return new_w, state

    return codec, uplink, apply


# ---------------------------------------------------------------------------
# validation + built-in registration
# ---------------------------------------------------------------------------

def _frac_validate(field):
    def validate(cfg):
        v = getattr(cfg, field)
        if not 0 < v <= 1:
            raise ValueError(f"{field} must be in (0, 1], got {v}")
    return validate


def _qsgd_validate(cfg):
    if cfg.qsgd_bits < 1:
        raise ValueError(f"qsgd_bits must be >= 1, got {cfg.qsgd_bits}")


def _register_builtins() -> None:
    for name in ("fedmrn", "fedmrns"):
        register_algorithm(Algorithm(
            name=name, make_round_body=_fedmrn_body, codec=_fedmrn_codec,
            init_state=_fedmrn_state, validate=_fedmrn_validate,
            make_cohort_body=_fedmrn_cohort_body))
    register_algorithm(Algorithm(
        name="fedavg", make_round_body=_fedavg_family_body(None),
        codec=_fedavg_family_codec(None),
        make_cohort_body=_fedavg_family_cohort_body(None)))
    register_algorithm(Algorithm(
        name="fedpm", make_round_body=_fedpm_body, codec=_fedpm_codec,
        init_state=lambda cfg, p: {"scores": _tree_zeros_like(p)},
        make_cohort_body=_fedpm_cohort_body))
    register_algorithm(Algorithm(
        name="fedsparsify", make_round_body=_fedsparsify_body,
        codec=_fedsparsify_codec,
        validate=_frac_validate("sparsify_frac"),
        make_cohort_body=_fedsparsify_cohort_body))
    for comp in COMPRESSOR_REGISTRY:
        if comp == "none":
            continue
        register_algorithm(Algorithm(
            name=comp, make_round_body=_fedavg_family_body(comp),
            codec=_fedavg_family_codec(comp),
            validate=(_frac_validate("topk_frac") if comp == "topk"
                      else _qsgd_validate if comp == "qsgd"
                      else _no_validate),
            make_cohort_body=_fedavg_family_cohort_body(comp)))


_register_builtins()
