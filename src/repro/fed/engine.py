"""Round + experiment programs — rounds as pure bodies, experiments as scans.

Each algorithm *family* exposes ONE pure round body

  round_body(w, state, batches, picked, round_idx, weights)
      -> (new_w, new_state, losses)            # losses: (K, S) device array

in which the K selected clients run as a ``vmap`` over a stacked client
axis — local PSM training, final mask sampling, bit-packing (the
Pallas-backed uplink hot path), and server aggregation fused end-to-end.
Families:

  fedmrn / fedmrns   PSM local training → masks → packed uplink → Eq.(5)
  fedavg + post-training compressors (signsgd … post_sm)
  fedpm              supermask-as-weights baseline
  fedsparsify        magnitude-pruned weight upload baseline

The SAME body is reused by three drivers:

  1. ``make_round_engine``       → ``jit(round_body)``: one XLA program
     per round, fed host-stacked batches (the PR-1 batched engine);
  2. ``make_experiment_program`` → ``lax.scan`` of the body over ``chunk``
     rounds per dispatch: client selection, batch gathering (from a
     device-resident :class:`~repro.data.federated.FederatedDataset`),
     on-device eval every ``eval_every`` rounds, and per-round metric
     buffers all live inside the program — zero host transfers inside a
     chunk;
  3. ``fed/looped.py``           → the seed's per-client reference loop
     (parity + benchmark baseline).

Client selection is NOT sampled inside the program: every driver consumes
the same seed-stable ``(R, K)`` schedule from :func:`make_client_schedule`
(the scan program indexes a device copy of it), so looped / batched /
scan trajectories are exactly comparable at fixed seed.

``state`` carries cross-round algorithm state (error-feedback residuals
stacked over ALL clients, fedpm global scores); ``{}`` when stateless.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (FedMRNConfig, NoiseConfig, baseline_record,
                    client_round_key, fedmrn_record, final_mask_key,
                    gen_noise, make_compressor, mix_add, psm_local_train,
                    sample_final_mask, sgd_local_update, tree_masked_noise,
                    tree_num_params, tree_pack_stacked, tree_unpack_stacked)
from ..core.compressors import REGISTRY as COMPRESSOR_REGISTRY

Pytree = Any

ALGORITHMS = (("fedavg", "fedmrn", "fedmrns", "fedpm", "fedsparsify")
              + tuple(c for c in COMPRESSOR_REGISTRY if c != "none"))


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedmrn"
    num_clients: int = 20
    clients_per_round: int = 5
    rounds: int = 30
    local_steps: int = 20
    batch_size: int = 32
    lr: float = 0.1
    seed: int = 0
    # fedmrn specifics (paper defaults: uniform, 1e-2 / 5e-3)
    noise_dist: str = "uniform"
    noise_alpha: float = 1e-2
    use_sm: bool = True
    use_pm: bool = True
    error_feedback: bool = False
    # beyond-paper: one shared noise G(s_t) per ROUND (instead of per
    # client).  Masks stay per-client, so the uplink is unchanged (1 bpp),
    # but Σ_k G(s_k)⊙m_k = G(s_t) ⊙ Σ_k m_k — the server aggregation
    # becomes an integer mask-count (popcount) scaled by one noise tensor,
    # and at pod scale the mask all-gather can become a ⌈log2(K+1)⌉-bit
    # integer all-reduce (a further ~3× cross-client traffic cut at K=16).
    shared_noise: bool = False
    # baselines
    topk_frac: float = 0.03
    sparsify_frac: float = 0.03    # fedsparsify keeps top 3% of weights
    qsgd_bits: int = 2
    # kernel backend for masking/packing: "ref" | "pallas" | None (auto)
    backend: Optional[str] = None

    def fedmrn_config(self) -> FedMRNConfig:
        mode = "signed" if self.algorithm == "fedmrns" else "binary"
        return FedMRNConfig(
            mask_mode=mode,
            noise=NoiseConfig(dist=self.noise_dist, alpha=self.noise_alpha),
            use_sm=self.use_sm, use_pm=self.use_pm,
            error_feedback=self.error_feedback, lr=self.lr,
            backend=self.backend)


def uplink_bits(cfg: FLConfig, params: Pytree) -> int:
    """Exact per-client uplink cost of one round (for history accounting)."""
    P = tree_num_params(params)
    L = len(jax.tree_util.tree_leaves(params))
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        return fedmrn_record(P).uplink_bits
    if cfg.algorithm == "fedavg":
        return 32 * P
    if cfg.algorithm == "fedpm":
        return baseline_record("fedpm", P, L).uplink_bits
    if cfg.algorithm == "fedsparsify":
        return baseline_record("fedsparsify", P, L,
                               topk_frac=cfg.sparsify_frac).uplink_bits
    return baseline_record(cfg.algorithm, P, L, topk_frac=cfg.topk_frac,
                           qsgd_bits=cfg.qsgd_bits).uplink_bits


def _tree_zeros_like(t: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def stack_client_batches(batches: list) -> Pytree:
    """[K × (S, B, ...) pytrees] → one pytree with a leading client axis.

    The round programs' input contract: every leaf gains a leading K dim.
    """
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *batches)


def _weighted_sum(weights: jax.Array, stacked: Pytree) -> Pytree:
    """Σ_k w_k · leaf[k] over the leading client axis of every leaf."""
    return jax.tree_util.tree_map(
        lambda x: jnp.tensordot(weights, x.astype(jnp.float32), axes=1),
        stacked)


# ---------------------------------------------------------------------------
# per-client local updates for the baselines (shared with the looped engine)
# ---------------------------------------------------------------------------

def fedpm_local(loss_fn, w_init, scores, batches, *, lr, key):
    """Train sigmoid-scores; weights = w_init ⊙ Bern(sigmoid(s)) with STE."""

    def masked_params(s, k):
        leaves, treedef = jax.tree_util.tree_flatten(s)
        w_leaves = jax.tree_util.tree_leaves(w_init)
        out = []
        for i, (sl, wl) in enumerate(zip(leaves, w_leaves)):
            prob = jax.nn.sigmoid(sl)
            m = jax.random.bernoulli(jax.random.fold_in(k, i), prob)
            m = prob + jax.lax.stop_gradient(m.astype(prob.dtype) - prob)
            out.append(wl * m)
        return jax.tree_util.tree_unflatten(treedef, out)

    def step(s, inp):
        tau, batch = inp
        k = jax.random.fold_in(key, tau)

        def fwd(s_):
            return loss_fn(masked_params(s_, k), batch)

        loss, g = jax.value_and_grad(fwd)(s)
        s = jax.tree_util.tree_map(lambda a, gi: a - lr * gi, s, g)
        return s, loss

    n = jax.tree_util.tree_leaves(batches)[0].shape[0]
    s_final, losses = jax.lax.scan(step, scores,
                                   (jnp.arange(n), batches))
    # uplink: Bernoulli-sampled masks, one independent draw per leaf
    # (folding the leaf index keeps same-shaped leaves decorrelated)
    leaves, treedef = jax.tree_util.tree_flatten(s_final)
    mask_key = jax.random.fold_in(key, n + 1)
    masks = jax.tree_util.tree_unflatten(treedef, [
        jax.random.bernoulli(jax.random.fold_in(mask_key, i),
                             jax.nn.sigmoid(sl)).astype(jnp.float32)
        for i, sl in enumerate(leaves)])
    return masks, losses


def fedsparsify_local(loss_fn, w, batches, *, lr, frac):
    w_new, losses = sgd_local_update(loss_fn, w, batches, lr=lr)
    w_new = jax.tree_util.tree_map(jnp.add, w, w_new)  # u → w_local

    def prune(x):
        flat = jnp.abs(x).reshape(-1)
        k = max(1, int(np.ceil(frac * flat.shape[0])))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)

    return jax.tree_util.tree_map(prune, w_new), losses


# ---------------------------------------------------------------------------
# round programs, one per algorithm family
# ---------------------------------------------------------------------------

def _make_fedmrn_round(loss_fn, cfg: FLConfig, params: Pytree):
    mrn = cfg.fedmrn_config()
    ef = cfg.error_feedback

    def round_fn(w, state, batches, picked, round_idx, weights):
        train_base = jax.random.key(cfg.seed + 1)

        def per_client(b, cid, r0):
            noise_id = jnp.int32(0) if cfg.shared_noise else cid
            seed_key = client_round_key(cfg.seed, round_idx, noise_id)
            noise = gen_noise(seed_key, w, mrn.noise)
            train_key = jax.random.fold_in(train_base,
                                           round_idx * 1000 + cid)
            u, losses = psm_local_train(loss_fn, w, b, noise, train_key,
                                        cfg=mrn, u0=r0 if ef else None)
            # step count from the batches, NOT cfg.local_steps — the mask
            # key must track the real S or parity with the looped
            # reference breaks when a caller varies steps per round
            num_steps = jax.tree_util.tree_leaves(b)[0].shape[0]
            m = sample_final_mask(
                u, noise, final_mask_key(train_key, num_steps), cfg=mrn)
            residual = (jax.tree_util.tree_map(
                jnp.subtract, u, tree_masked_noise(noise, m))
                if ef else None)
            return m, losses, residual

        r0 = (jax.tree_util.tree_map(lambda r: r[picked],
                                     state["residuals"])
              if ef else jnp.zeros((picked.shape[0],)))
        masks, losses, residuals = jax.vmap(per_client)(batches, picked, r0)

        # ---- uplink: the wire payload, packed in one kernel launch ------
        payload = tree_pack_stacked(masks, mode=mrn.mask_mode,
                                    backend=cfg.backend)

        # ---- server: unpack, regen noise from seeds, Eq. (5) ------------
        m_rec = tree_unpack_stacked(payload, w, mode=mrn.mask_mode,
                                    backend=cfg.backend)
        wn = weights / jnp.sum(weights)
        if cfg.shared_noise:
            # Σ_k p'_k G(s_t)⊙m_k = G(s_t) ⊙ Σ_k p'_k m_k: one noise
            # tensor scales an (integer-valued) mask average
            noise = gen_noise(client_round_key(cfg.seed, round_idx, 0),
                              w, mrn.noise)
            m_avg = _weighted_sum(wn, m_rec)
            agg = jax.tree_util.tree_map(
                lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_avg)
        else:
            def decode(cid, m_c):
                noise = gen_noise(client_round_key(cfg.seed, round_idx, cid),
                                  w, mrn.noise)
                return jax.tree_util.tree_map(
                    lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_c)

            u_hats = jax.vmap(decode)(picked, m_rec)
            agg = _weighted_sum(wn, u_hats)
        new_w = jax.tree_util.tree_map(mix_add, w, agg)

        new_state = state
        if ef:
            new_state = {"residuals": jax.tree_util.tree_map(
                lambda r, nr: r.at[picked].set(nr),
                state["residuals"], residuals)}
        return new_w, new_state, losses

    state0 = {}
    if ef:
        # Device-resident residual stack: num_clients × model size.  Keeps
        # the gather/scatter inside the round program (no host sync), at
        # the cost of a dense buffer — fine for simulation-scale client
        # counts; a cross-silo run with thousands of clients should shard
        # this stack or carry residuals host-side instead.
        state0 = {"residuals": jax.tree_util.tree_map(
            lambda p: jnp.zeros((cfg.num_clients,) + p.shape, p.dtype),
            params)}
    return round_fn, state0


def _make_fedavg_round(loss_fn, cfg: FLConfig, params: Pytree):
    mrn = cfg.fedmrn_config()
    compressor = (None if cfg.algorithm == "fedavg" else
                  make_compressor(cfg.algorithm, topk_frac=cfg.topk_frac,
                                  qsgd_bits=cfg.qsgd_bits, noise=mrn.noise))

    def round_fn(w, state, batches, picked, round_idx, weights):
        comp_base = jax.random.key(cfg.seed + 3)

        def per_client(b, cid):
            u, losses = sgd_local_update(loss_fn, w, b, lr=cfg.lr)
            if compressor is not None:
                u = compressor.roundtrip(
                    u, jax.random.fold_in(comp_base, round_idx * 1000 + cid))
            return u, losses

        updates, losses = jax.vmap(per_client)(batches, picked)
        wn = weights / jnp.sum(weights)
        agg = _weighted_sum(wn, updates)
        new_w = jax.tree_util.tree_map(mix_add, w, agg)
        return new_w, state, losses

    return round_fn, {}


def _make_fedpm_round(loss_fn, cfg: FLConfig, params: Pytree):
    noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)
    w_frozen = gen_noise(jax.random.key(cfg.seed), params, noise_cfg)

    def round_fn(w, state, batches, picked, round_idx, weights):
        key_base = jax.random.key(cfg.seed + 2)
        scores = state["scores"]

        def per_client(b, cid):
            return fedpm_local(
                loss_fn, w_frozen, scores, b, lr=cfg.lr,
                key=jax.random.fold_in(key_base, round_idx * 1000 + cid))

        masks, losses = jax.vmap(per_client)(batches, picked)
        K = picked.shape[0]
        # Beta(1,1)-posterior (Laplace-smoothed) mask-frequency estimate,
        # accumulated in f32 regardless of param dtype.  The raw K-client
        # mean hits exactly 0/1 whenever all clients agree, and logit of
        # the clipped value (±9.2) saturates next round's sigmoid scores —
        # training freezes.  Smoothing bounds scores to |logit| ≤ ln(K+1).
        probs = jax.tree_util.tree_map(
            lambda m: (jnp.sum(m.astype(jnp.float32), axis=0) + 1.0)
            / (K + 2.0), masks)
        new_scores = jax.tree_util.tree_map(
            lambda p_: jnp.log(p_ / (1 - p_)), probs)      # sigmoid^-1
        new_w = jax.tree_util.tree_map(
            lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)
        return new_w, {"scores": new_scores}, losses

    state0 = {"scores": _tree_zeros_like(params)}
    return round_fn, state0


def _make_fedsparsify_round(loss_fn, cfg: FLConfig, params: Pytree):
    def round_fn(w, state, batches, picked, round_idx, weights):
        def per_client(b, cid):
            return fedsparsify_local(loss_fn, w, b, lr=cfg.lr,
                                     frac=cfg.sparsify_frac)

        w_locals, losses = jax.vmap(per_client)(batches, picked)
        wn = weights / jnp.sum(weights)
        new_w = _weighted_sum(wn, w_locals)
        new_w = jax.tree_util.tree_map(lambda p, a: a.astype(p.dtype),
                                       w, new_w)
        return new_w, state, losses

    return round_fn, {}


def make_round_body(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
) -> Tuple[Callable, Dict[str, Pytree]]:
    """Build the PURE (un-jitted) round body + initial state for a family.

    The body is the unit every driver composes: jitted directly by
    :func:`make_round_engine`, scanned by :func:`make_experiment_program`.
    """
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        return _make_fedmrn_round(loss_fn, cfg, params)
    if cfg.algorithm == "fedpm":
        return _make_fedpm_round(loss_fn, cfg, params)
    if cfg.algorithm == "fedsparsify":
        return _make_fedsparsify_round(loss_fn, cfg, params)
    if cfg.algorithm == "fedavg" or cfg.algorithm in COMPRESSOR_REGISTRY:
        return _make_fedavg_round(loss_fn, cfg, params)
    raise ValueError(f"unknown algorithm {cfg.algorithm!r}")


def make_round_engine(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
) -> Tuple[Callable, Dict[str, Pytree]]:
    """Build (jitted round_fn, initial state) for ``cfg.algorithm``."""
    round_body, state0 = make_round_body(loss_fn, cfg, params)
    return jax.jit(round_body), state0


# ---------------------------------------------------------------------------
# experiment-level: client schedule, metric buffers, multi-round scan program
# ---------------------------------------------------------------------------

def make_client_schedule(cfg: FLConfig) -> np.ndarray:
    """Seed-stable ``(R, K)`` int32 client-selection schedule.

    Reproduces the legacy per-round ``rng.choice`` sequence exactly (same
    RandomState, same call order), but precomputed up front so no engine
    interleaves host RNG with device dispatches.  ALL engines — looped,
    batched, scan — consume this one schedule; the scan program indexes a
    device copy of it.
    """
    rng = np.random.RandomState(cfg.seed)
    return np.stack([
        rng.choice(cfg.num_clients, cfg.clients_per_round, replace=False)
        for _ in range(cfg.rounds)]).astype(np.int32)


def init_metric_buffers(cfg: FLConfig) -> Dict[str, jax.Array]:
    """Preallocated per-round ``(R,)`` device buffers the scan writes into.

    ``acc`` starts at NaN — rounds the program does not evaluate stay NaN,
    so the driver can slice out the eval rounds without guessing.
    """
    R = cfg.rounds
    return {
        "loss": jnp.zeros((R,), jnp.float32),
        "acc": jnp.full((R,), jnp.nan, jnp.float32),
        # per-round TOTAL uplink (K clients); f32 holds >2^31 bit counts
        "uplink_bits": jnp.zeros((R,), jnp.float32),
    }


def make_experiment_program(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # FederatedDataset
    *,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> Tuple[Callable, Dict[str, Pytree], Dict[str, jax.Array]]:
    """Fuse a whole experiment chunk into ONE jitted program.

    Returns ``(run_chunk, state0, metrics0)`` where

      run_chunk(w, state, metrics, r0, schedule_chunk, n_rounds=n)
          -> (new_w, new_state, new_metrics)

    ``lax.scan``s the family's round body over ``n`` consecutive rounds
    starting at round ``r0``: per-round client selection comes from the
    ``(n, K)`` ``schedule_chunk`` slice, batches are gathered in-program
    from the device-resident ``data``, eval runs on-device every
    ``eval_every`` rounds (plus the final round), and per-round
    loss/accuracy/uplink-bits land in the preallocated ``(R,)`` buffers
    carried through ``metrics``.  Nothing crosses the host boundary
    inside a chunk; ``n_rounds`` is static, so a trailing partial chunk
    costs exactly one extra compile.
    """
    round_body, state0 = make_round_body(loss_fn, cfg, params)
    bits_round = float(cfg.clients_per_round * uplink_bits(cfg, params))
    weights_all = jnp.asarray(
        [1.0] * cfg.num_clients if client_weights is None
        else list(client_weights), jnp.float32)

    def body(carry, inp):
        w, state, metrics = carry
        r, picked = inp
        batches = data.gather_batches(r, picked, steps=cfg.local_steps,
                                      batch=cfg.batch_size)
        weights = weights_all[picked]
        w, state, losses = round_body(w, state, batches, picked, r, weights)
        metrics = dict(metrics)
        metrics["loss"] = metrics["loss"].at[r].set(jnp.mean(losses[:, -1]))
        metrics["uplink_bits"] = metrics["uplink_bits"].at[r].set(bits_round)
        if eval_program is not None:
            do_eval = (r % eval_every == 0) | (r == cfg.rounds - 1)
            acc = jax.lax.cond(do_eval, eval_program,
                               lambda _w: jnp.float32(jnp.nan), w)
            metrics["acc"] = metrics["acc"].at[r].set(acc)
        return (w, state, metrics), None

    @partial(jax.jit, static_argnames=("n_rounds",))
    def run_chunk(w, state, metrics, r0, schedule_chunk, *, n_rounds: int):
        rs = r0 + jnp.arange(n_rounds, dtype=jnp.int32)
        (w, state, metrics), _ = jax.lax.scan(
            body, (w, state, metrics), (rs, schedule_chunk))
        return w, state, metrics

    return run_chunk, state0, init_metric_buffers(cfg)
