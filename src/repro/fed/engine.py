"""Batched round engine — one jitted XLA program per FL round.

The seed engine executed a round as a Python loop over clients with a
blocking ``float(...)`` host sync per client.  Here the whole round is a
single XLA program: the K selected clients run as a ``vmap`` over a
stacked client axis — local PSM training, final mask sampling, bit-packing
(the Pallas-backed uplink hot path), and server aggregation fused
end-to-end.  The only values that ever leave the device during training
are the evaluation reads; per-round losses stay in device buffers.

One round program exists per algorithm *family*:

  fedmrn / fedmrns   PSM local training → masks → packed uplink → Eq.(5)
  fedavg + post-training compressors (signsgd … post_sm)
  fedpm              supermask-as-weights baseline
  fedsparsify        magnitude-pruned weight upload baseline

``make_round_engine`` returns ``(round_fn, state0)``; ``round_fn`` is
jitted once and reused for every round:

  round_fn(w, state, batches, picked, round_idx, weights)
      -> (new_w, new_state, losses)            # losses: (K, S) device array

``state`` carries cross-round algorithm state (error-feedback residuals
stacked over ALL clients, fedpm global scores); ``{}`` when stateless.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (FedMRNConfig, NoiseConfig, baseline_record,
                    client_round_key, fedmrn_record, final_mask_key,
                    gen_noise, make_compressor, mix_add, psm_local_train,
                    sample_final_mask, sgd_local_update, tree_masked_noise,
                    tree_num_params, tree_pack_stacked, tree_unpack_stacked)
from ..core.compressors import REGISTRY as COMPRESSOR_REGISTRY

Pytree = Any

ALGORITHMS = (("fedavg", "fedmrn", "fedmrns", "fedpm", "fedsparsify")
              + tuple(c for c in COMPRESSOR_REGISTRY if c != "none"))


@dataclasses.dataclass(frozen=True)
class FLConfig:
    algorithm: str = "fedmrn"
    num_clients: int = 20
    clients_per_round: int = 5
    rounds: int = 30
    local_steps: int = 20
    batch_size: int = 32
    lr: float = 0.1
    seed: int = 0
    # fedmrn specifics (paper defaults: uniform, 1e-2 / 5e-3)
    noise_dist: str = "uniform"
    noise_alpha: float = 1e-2
    use_sm: bool = True
    use_pm: bool = True
    error_feedback: bool = False
    # beyond-paper: one shared noise G(s_t) per ROUND (instead of per
    # client).  Masks stay per-client, so the uplink is unchanged (1 bpp),
    # but Σ_k G(s_k)⊙m_k = G(s_t) ⊙ Σ_k m_k — the server aggregation
    # becomes an integer mask-count (popcount) scaled by one noise tensor,
    # and at pod scale the mask all-gather can become a ⌈log2(K+1)⌉-bit
    # integer all-reduce (a further ~3× cross-client traffic cut at K=16).
    shared_noise: bool = False
    # baselines
    topk_frac: float = 0.03
    sparsify_frac: float = 0.03    # fedsparsify keeps top 3% of weights
    qsgd_bits: int = 2
    # kernel backend for masking/packing: "ref" | "pallas" | None (auto)
    backend: Optional[str] = None

    def fedmrn_config(self) -> FedMRNConfig:
        mode = "signed" if self.algorithm == "fedmrns" else "binary"
        return FedMRNConfig(
            mask_mode=mode,
            noise=NoiseConfig(dist=self.noise_dist, alpha=self.noise_alpha),
            use_sm=self.use_sm, use_pm=self.use_pm,
            error_feedback=self.error_feedback, lr=self.lr,
            backend=self.backend)


def uplink_bits(cfg: FLConfig, params: Pytree) -> int:
    """Exact per-client uplink cost of one round (for history accounting)."""
    P = tree_num_params(params)
    L = len(jax.tree_util.tree_leaves(params))
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        return fedmrn_record(P).uplink_bits
    if cfg.algorithm == "fedavg":
        return 32 * P
    if cfg.algorithm == "fedpm":
        return baseline_record("fedpm", P, L).uplink_bits
    if cfg.algorithm == "fedsparsify":
        return baseline_record("fedsparsify", P, L,
                               topk_frac=cfg.sparsify_frac).uplink_bits
    return baseline_record(cfg.algorithm, P, L, topk_frac=cfg.topk_frac,
                           qsgd_bits=cfg.qsgd_bits).uplink_bits


def _tree_zeros_like(t: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def stack_client_batches(batches: list) -> Pytree:
    """[K × (S, B, ...) pytrees] → one pytree with a leading client axis.

    The round programs' input contract: every leaf gains a leading K dim.
    """
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *batches)


def _weighted_sum(weights: jax.Array, stacked: Pytree) -> Pytree:
    """Σ_k w_k · leaf[k] over the leading client axis of every leaf."""
    return jax.tree_util.tree_map(
        lambda x: jnp.tensordot(weights, x.astype(jnp.float32), axes=1),
        stacked)


# ---------------------------------------------------------------------------
# per-client local updates for the baselines (shared with the looped engine)
# ---------------------------------------------------------------------------

def fedpm_local(loss_fn, w_init, scores, batches, *, lr, key):
    """Train sigmoid-scores; weights = w_init ⊙ Bern(sigmoid(s)) with STE."""

    def masked_params(s, k):
        leaves, treedef = jax.tree_util.tree_flatten(s)
        w_leaves = jax.tree_util.tree_leaves(w_init)
        out = []
        for i, (sl, wl) in enumerate(zip(leaves, w_leaves)):
            prob = jax.nn.sigmoid(sl)
            m = jax.random.bernoulli(jax.random.fold_in(k, i), prob)
            m = prob + jax.lax.stop_gradient(m.astype(prob.dtype) - prob)
            out.append(wl * m)
        return jax.tree_util.tree_unflatten(treedef, out)

    def step(s, inp):
        tau, batch = inp
        k = jax.random.fold_in(key, tau)

        def fwd(s_):
            return loss_fn(masked_params(s_, k), batch)

        loss, g = jax.value_and_grad(fwd)(s)
        s = jax.tree_util.tree_map(lambda a, gi: a - lr * gi, s, g)
        return s, loss

    n = jax.tree_util.tree_leaves(batches)[0].shape[0]
    s_final, losses = jax.lax.scan(step, scores,
                                   (jnp.arange(n), batches))
    # uplink: Bernoulli-sampled masks, one independent draw per leaf
    # (folding the leaf index keeps same-shaped leaves decorrelated)
    leaves, treedef = jax.tree_util.tree_flatten(s_final)
    mask_key = jax.random.fold_in(key, n + 1)
    masks = jax.tree_util.tree_unflatten(treedef, [
        jax.random.bernoulli(jax.random.fold_in(mask_key, i),
                             jax.nn.sigmoid(sl)).astype(jnp.float32)
        for i, sl in enumerate(leaves)])
    return masks, losses


def fedsparsify_local(loss_fn, w, batches, *, lr, frac):
    w_new, losses = sgd_local_update(loss_fn, w, batches, lr=lr)
    w_new = jax.tree_util.tree_map(jnp.add, w, w_new)  # u → w_local

    def prune(x):
        flat = jnp.abs(x).reshape(-1)
        k = max(1, int(np.ceil(frac * flat.shape[0])))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)

    return jax.tree_util.tree_map(prune, w_new), losses


# ---------------------------------------------------------------------------
# round programs, one per algorithm family
# ---------------------------------------------------------------------------

def _make_fedmrn_round(loss_fn, cfg: FLConfig, params: Pytree):
    mrn = cfg.fedmrn_config()
    ef = cfg.error_feedback

    def round_fn(w, state, batches, picked, round_idx, weights):
        train_base = jax.random.key(cfg.seed + 1)

        def per_client(b, cid, r0):
            noise_id = jnp.int32(0) if cfg.shared_noise else cid
            seed_key = client_round_key(cfg.seed, round_idx, noise_id)
            noise = gen_noise(seed_key, w, mrn.noise)
            train_key = jax.random.fold_in(train_base,
                                           round_idx * 1000 + cid)
            u, losses = psm_local_train(loss_fn, w, b, noise, train_key,
                                        cfg=mrn, u0=r0 if ef else None)
            # step count from the batches, NOT cfg.local_steps — the mask
            # key must track the real S or parity with the looped
            # reference breaks when a caller varies steps per round
            num_steps = jax.tree_util.tree_leaves(b)[0].shape[0]
            m = sample_final_mask(
                u, noise, final_mask_key(train_key, num_steps), cfg=mrn)
            residual = (jax.tree_util.tree_map(
                jnp.subtract, u, tree_masked_noise(noise, m))
                if ef else None)
            return m, losses, residual

        r0 = (jax.tree_util.tree_map(lambda r: r[picked],
                                     state["residuals"])
              if ef else jnp.zeros((picked.shape[0],)))
        masks, losses, residuals = jax.vmap(per_client)(batches, picked, r0)

        # ---- uplink: the wire payload, packed in one kernel launch ------
        payload = tree_pack_stacked(masks, mode=mrn.mask_mode,
                                    backend=cfg.backend)

        # ---- server: unpack, regen noise from seeds, Eq. (5) ------------
        m_rec = tree_unpack_stacked(payload, w, mode=mrn.mask_mode,
                                    backend=cfg.backend)
        wn = weights / jnp.sum(weights)
        if cfg.shared_noise:
            # Σ_k p'_k G(s_t)⊙m_k = G(s_t) ⊙ Σ_k p'_k m_k: one noise
            # tensor scales an (integer-valued) mask average
            noise = gen_noise(client_round_key(cfg.seed, round_idx, 0),
                              w, mrn.noise)
            m_avg = _weighted_sum(wn, m_rec)
            agg = jax.tree_util.tree_map(
                lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_avg)
        else:
            def decode(cid, m_c):
                noise = gen_noise(client_round_key(cfg.seed, round_idx, cid),
                                  w, mrn.noise)
                return jax.tree_util.tree_map(
                    lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_c)

            u_hats = jax.vmap(decode)(picked, m_rec)
            agg = _weighted_sum(wn, u_hats)
        new_w = jax.tree_util.tree_map(mix_add, w, agg)

        new_state = state
        if ef:
            new_state = {"residuals": jax.tree_util.tree_map(
                lambda r, nr: r.at[picked].set(nr),
                state["residuals"], residuals)}
        return new_w, new_state, losses

    state0 = {}
    if ef:
        # Device-resident residual stack: num_clients × model size.  Keeps
        # the gather/scatter inside the round program (no host sync), at
        # the cost of a dense buffer — fine for simulation-scale client
        # counts; a cross-silo run with thousands of clients should shard
        # this stack or carry residuals host-side instead.
        state0 = {"residuals": jax.tree_util.tree_map(
            lambda p: jnp.zeros((cfg.num_clients,) + p.shape, p.dtype),
            params)}
    return round_fn, state0


def _make_fedavg_round(loss_fn, cfg: FLConfig, params: Pytree):
    mrn = cfg.fedmrn_config()
    compressor = (None if cfg.algorithm == "fedavg" else
                  make_compressor(cfg.algorithm, topk_frac=cfg.topk_frac,
                                  qsgd_bits=cfg.qsgd_bits, noise=mrn.noise))

    def round_fn(w, state, batches, picked, round_idx, weights):
        comp_base = jax.random.key(cfg.seed + 3)

        def per_client(b, cid):
            u, losses = sgd_local_update(loss_fn, w, b, lr=cfg.lr)
            if compressor is not None:
                u = compressor.roundtrip(
                    u, jax.random.fold_in(comp_base, round_idx * 1000 + cid))
            return u, losses

        updates, losses = jax.vmap(per_client)(batches, picked)
        wn = weights / jnp.sum(weights)
        agg = _weighted_sum(wn, updates)
        new_w = jax.tree_util.tree_map(mix_add, w, agg)
        return new_w, state, losses

    return round_fn, {}


def _make_fedpm_round(loss_fn, cfg: FLConfig, params: Pytree):
    noise_cfg = NoiseConfig(dist="uniform", alpha=0.1)
    w_frozen = gen_noise(jax.random.key(cfg.seed), params, noise_cfg)

    def round_fn(w, state, batches, picked, round_idx, weights):
        key_base = jax.random.key(cfg.seed + 2)
        scores = state["scores"]

        def per_client(b, cid):
            return fedpm_local(
                loss_fn, w_frozen, scores, b, lr=cfg.lr,
                key=jax.random.fold_in(key_base, round_idx * 1000 + cid))

        masks, losses = jax.vmap(per_client)(batches, picked)
        K = picked.shape[0]
        # Beta(1,1)-posterior (Laplace-smoothed) mask-frequency estimate,
        # accumulated in f32 regardless of param dtype.  The raw K-client
        # mean hits exactly 0/1 whenever all clients agree, and logit of
        # the clipped value (±9.2) saturates next round's sigmoid scores —
        # training freezes.  Smoothing bounds scores to |logit| ≤ ln(K+1).
        probs = jax.tree_util.tree_map(
            lambda m: (jnp.sum(m.astype(jnp.float32), axis=0) + 1.0)
            / (K + 2.0), masks)
        new_scores = jax.tree_util.tree_map(
            lambda p_: jnp.log(p_ / (1 - p_)), probs)      # sigmoid^-1
        new_w = jax.tree_util.tree_map(
            lambda wf, pr: wf * (pr > 0.5), w_frozen, probs)
        return new_w, {"scores": new_scores}, losses

    state0 = {"scores": _tree_zeros_like(params)}
    return round_fn, state0


def _make_fedsparsify_round(loss_fn, cfg: FLConfig, params: Pytree):
    def round_fn(w, state, batches, picked, round_idx, weights):
        def per_client(b, cid):
            return fedsparsify_local(loss_fn, w, b, lr=cfg.lr,
                                     frac=cfg.sparsify_frac)

        w_locals, losses = jax.vmap(per_client)(batches, picked)
        wn = weights / jnp.sum(weights)
        new_w = _weighted_sum(wn, w_locals)
        new_w = jax.tree_util.tree_map(lambda p, a: a.astype(p.dtype),
                                       w, new_w)
        return new_w, state, losses

    return round_fn, {}


def make_round_engine(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
) -> Tuple[Callable, Dict[str, Pytree]]:
    """Build (jitted round_fn, initial state) for ``cfg.algorithm``."""
    if cfg.algorithm in ("fedmrn", "fedmrns"):
        round_fn, state0 = _make_fedmrn_round(loss_fn, cfg, params)
    elif cfg.algorithm == "fedpm":
        round_fn, state0 = _make_fedpm_round(loss_fn, cfg, params)
    elif cfg.algorithm == "fedsparsify":
        round_fn, state0 = _make_fedsparsify_round(loss_fn, cfg, params)
    elif cfg.algorithm == "fedavg" or cfg.algorithm in COMPRESSOR_REGISTRY:
        round_fn, state0 = _make_fedavg_round(loss_fn, cfg, params)
    else:
        raise ValueError(f"unknown algorithm {cfg.algorithm!r}")
    return jax.jit(round_fn), state0
