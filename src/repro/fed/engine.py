"""Execution drivers — rounds as pure bodies, experiments as scans.

Algorithm families live in the plugin registry (``fed/algorithms.py``):
each one exposes a PURE seeded round body

  body(seed, w, state, batches, picked, round_idx, weights)
      -> (new_w, new_state, losses[, wire_bits])   # losses: (K, S)

in which the K selected clients run as a ``vmap`` over a stacked client
axis — local PSM training, mask sampling, and the family's typed uplink
codec (client encode → stacked ``WireMsg`` → ``codec.aggregate``, the
Pallas-backed bit-packing hot path) fused end-to-end.  ``wire_bits`` is
the round's MEASURED K-client uplink (summed encoded buffer sizes);
:func:`normalize_round_outputs` pads legacy 3-tuple bodies with the
codec's static report so every driver records the same metric.  ``seed``
is a traced int32 scalar, which is what lets :func:`make_sweep_program`
vmap a whole experiment over a seed axis with ONE compile.

This module composes those bodies into the execution drivers:

  1. ``make_round_engine``       → ``jit(round_body)``: one XLA program
     per round, fed host-stacked batches (the PR-1 batched engine);
  2. ``make_experiment_program`` → ``lax.scan`` of the body over ``chunk``
     rounds per dispatch: client selection, batch gathering (from a
     device-resident :class:`~repro.data.federated.FederatedDataset`),
     on-device eval every ``eval_every`` rounds, and per-round metric
     buffers all live inside the program — zero host transfers inside a
     chunk;
  3. ``make_sweep_program``      → ``vmap`` of the same chunk program over
     a ``(S,)`` seed axis: S seeds resident per dispatch, one compile
     (the multi-seed sweep engine behind ``Experiment.sweep``);
  4. ``make_sharded_sweep_program`` → ``shard_map`` of the vmapped chunk
     over a 1-D ``seed`` device mesh: S seeds spread across D devices
     (S/D vmapped within each), one compile — seeds are independent, so
     the program needs NO collectives and scales embarrassingly
     (``Experiment.sweep(..., sharding="devices")``);
  5. ``fed/looped.py``           → the seed's per-client reference loop
     (parity + benchmark baseline).

Client selection is NOT sampled inside the program: every driver consumes
the same seed-stable ``(R, K)`` schedule from :func:`make_client_schedule`
(the scan program indexes a device copy of it), so looped / batched /
scan / sweep trajectories are exactly comparable at fixed seed.

``state`` carries cross-round algorithm state (error-feedback residuals
stacked over ALL clients, fedpm global scores); ``{}`` when stateless.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .algorithms import (  # noqa: F401  (re-exported: legacy import site)
    ALGORITHMS, Algorithm, FLConfig, algorithm_codec, fedpm_local,
    fedsparsify_local, get_algorithm, list_algorithms, register_algorithm,
    uplink_bits,
)
from .codecs import MaskCodec, min_count_dtype

Pytree = Any


def normalize_round_outputs(out: Tuple, fallback_bits: float) -> Tuple:
    """Uniform round-body result: ``(w, state, losses, wire_bits)``.

    Codec-routed bodies already return the measured 4-tuple; legacy
    3-tuple plugin bodies are padded with the codec's static wire-bit
    report so every engine records ``uplink_bits_round`` the same way.
    """
    if len(out) == 4:
        return out
    w, state, losses = out
    return w, state, losses, jnp.float32(fallback_bits)


def _normalized_seeded_body(algo: Algorithm, loss_fn, cfg: FLConfig,
                            params: Pytree):
    """The registry body wrapped to the uniform 4-output contract."""
    body = algo.make_round_body(loss_fn, cfg, params)
    codec = algo.codec(cfg, params)
    fallback = float(cfg.clients_per_round
                     * codec.wire_bits(params).uplink_bits)

    def seeded(seed, w, state, batches, picked, round_idx, weights):
        out = body(seed, w, state, batches, picked, round_idx, weights)
        return normalize_round_outputs(out, fallback)

    return seeded


def stack_client_batches(batches: list) -> Pytree:
    """[K × (S, B, ...) pytrees] → one pytree with a leading client axis.

    The round programs' input contract: every leaf gains a leading K dim.
    """
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *batches)


def make_round_body(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
) -> Tuple[Callable, Dict[str, Pytree]]:
    """Build the PURE (un-jitted) round body + initial state for a family.

    The body is the unit every driver composes: jitted directly by
    :func:`make_round_engine`, scanned by :func:`make_experiment_program`.
    The registry body's ``seed`` argument is bound to ``cfg.seed`` here —
    use :func:`make_sweep_program` when seeds must stay a traced axis.
    Returns the NORMALISED body: always
    ``(new_w, new_state, losses, wire_bits)``, where ``wire_bits`` is the
    round's measured K-client uplink (codec-routed bodies measure it from
    the encoded ``WireMsg`` buffers; legacy 3-tuple bodies get the
    codec's static report).
    """
    algo = get_algorithm(cfg.algorithm)
    seeded = _normalized_seeded_body(algo, loss_fn, cfg, params)
    round_fn = partial(seeded, jnp.int32(cfg.seed))
    return round_fn, algo.init_state(cfg, params)


def make_round_engine(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
) -> Tuple[Callable, Dict[str, Pytree]]:
    """Build (jitted round_fn, initial state) for ``cfg.algorithm``."""
    round_body, state0 = make_round_body(loss_fn, cfg, params)
    return jax.jit(round_body), state0


# ---------------------------------------------------------------------------
# experiment-level: client schedule, metric buffers, multi-round scan program
# ---------------------------------------------------------------------------

def make_client_schedule(cfg: FLConfig,
                         seed: Optional[int] = None) -> np.ndarray:
    """Seed-stable ``(R, K)`` int32 client-selection schedule.

    Reproduces the legacy per-round ``rng.choice`` sequence exactly (same
    RandomState, same call order), but precomputed up front so no engine
    interleaves host RNG with device dispatches.  ALL engines — looped,
    batched, scan — consume this one schedule; the scan program indexes a
    device copy of it.  ``seed`` overrides ``cfg.seed`` (sweep axes).
    """
    rng = np.random.RandomState(cfg.seed if seed is None else seed)
    return np.stack([
        rng.choice(cfg.num_clients, cfg.clients_per_round, replace=False)
        for _ in range(cfg.rounds)]).astype(np.int32)


def eval_round_indices(cfg: FLConfig, eval_every: int) -> list:
    """The rounds the program evaluates: every ``eval_every`` + the last."""
    return [r for r in range(cfg.rounds)
            if r % eval_every == 0 or r == cfg.rounds - 1]


def init_metric_buffers(cfg: FLConfig) -> Dict[str, jax.Array]:
    """Preallocated per-round ``(R,)`` device buffers the scan writes into.

    ``acc`` starts at NaN — rounds the program does not evaluate stay NaN,
    so the driver can slice out the eval rounds without guessing.
    """
    R = cfg.rounds
    return {
        "loss": jnp.zeros((R,), jnp.float32),
        "acc": jnp.full((R,), jnp.nan, jnp.float32),
        # per-round TOTAL uplink (K clients); f32 holds >2^31 bit counts
        "uplink_bits": jnp.zeros((R,), jnp.float32),
    }


def _make_chunk_body(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # FederatedDataset
    *,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> Tuple[Callable, Dict[str, Pytree], Dict[str, jax.Array]]:
    """The un-jitted seeded chunk runner shared by every scan driver."""
    algo = get_algorithm(cfg.algorithm)
    round_body = _normalized_seeded_body(algo, loss_fn, cfg, params)
    state0 = algo.init_state(cfg, params)
    cw = None if client_weights is None else list(client_weights)
    if cw is not None and len(cw) != cfg.num_clients:
        # must fail here: inside jit, weights_all[picked] would silently
        # CLAMP out-of-range client ids instead of raising
        raise ValueError(
            f"client_weights has {len(cw)} entries, "
            f"cfg expects {cfg.num_clients}")
    if cfg.int_mask_agg and cw is not None:
        # the integer mask-count aggregate folds ONE weight scalar over
        # the summed counts — per-client weights need the f32 path
        raise ValueError(
            "int_mask_agg requires uniform client weights "
            "(client_weights=None)")
    if cfg.privacy is not None and cw is not None:
        # the DP release is defined on the UNWEIGHTED clipped counts —
        # per-client weights would scale contributions past the clip
        raise ValueError(
            "privacy= requires uniform client weights "
            "(client_weights=None): the clipped-count sensitivity bound "
            "assumes every client contributes one unweighted mask")
    weights_all = jnp.asarray([1.0] * cfg.num_clients if cw is None else cw,
                              jnp.float32)

    def body(seed, carry, inp):
        w, state, metrics = carry
        # the 3-element xs carry a per-round availability mask; the
        # 2-element path is TEXTUALLY today's program (trace-time static
        # branch → an all-available run stays bitwise identical)
        if len(inp) == 3:
            r, picked, valid = inp
            if cfg.int_mask_agg:
                # the integer count aggregate folds wn[0] over the summed
                # counts — a zeroed dropped-client weight poisons it
                raise ValueError(
                    "int_mask_agg cannot mask dropped clients on the "
                    "scan path — run availability scenarios on "
                    "engine='cohort' or 'service'")
            if cfg.privacy is not None:
                # same packed-popcount limitation: the DP count path
                # cannot zero a dropped client's words via weights alone
                raise ValueError(
                    "privacy= cannot mask dropped clients on the scan "
                    "path — run availability scenarios on "
                    "engine='cohort', 'looped' or 'service'")
        else:
            r, picked = inp
            valid = None
        batches = data.gather_batches(r, picked, steps=cfg.local_steps,
                                      batch=cfg.batch_size)
        weights = weights_all[picked]
        if valid is not None:
            # dropped clients still compute (static shapes) but carry a
            # zero aggregation weight: the normalizing codecs then
            # average EXACTLY the survivors (w*1.0 and +0.0 are exact in
            # f32, so this matches a survivors-only run bitwise)
            weights = weights * valid
        w, state, losses, wire_bits = round_body(seed, w, state, batches,
                                                 picked, r, weights)
        metrics = dict(metrics)
        if valid is None:
            loss_r = jnp.mean(losses[:, -1])
        else:
            nv = jnp.sum(valid)
            loss_r = jnp.sum(valid * losses[:, -1]) / nv
            # the wire only carries the survivors' uplinks
            wire_bits = wire_bits * nv / jnp.float32(valid.shape[0])
        metrics["loss"] = metrics["loss"].at[r].set(loss_r)
        # MEASURED wire cost: summed encoded WireMsg buffer sizes, not a
        # precomputed estimate (a constant in-program — shapes are static)
        metrics["uplink_bits"] = metrics["uplink_bits"].at[r].set(wire_bits)
        if eval_program is not None:
            do_eval = (r % eval_every == 0) | (r == cfg.rounds - 1)
            acc = jax.lax.cond(do_eval, eval_program,
                               lambda _w: jnp.float32(jnp.nan), w)
            metrics["acc"] = metrics["acc"].at[r].set(acc)
        return (w, state, metrics), None

    def run_chunk(seed, w, state, metrics, r0, schedule_chunk,
                  n_rounds: int, valid_chunk=None):
        rs = r0 + jnp.arange(n_rounds, dtype=jnp.int32)
        xs = ((rs, schedule_chunk) if valid_chunk is None
              else (rs, schedule_chunk, valid_chunk))
        (w, state, metrics), _ = jax.lax.scan(
            partial(body, seed), (w, state, metrics), xs)
        return w, state, metrics

    return run_chunk, state0, init_metric_buffers(cfg)


def make_experiment_program(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # FederatedDataset
    *,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> Tuple[Callable, Dict[str, Pytree], Dict[str, jax.Array]]:
    """Fuse a whole experiment chunk into ONE jitted program.

    Returns ``(run_chunk, state0, metrics0)`` where

      run_chunk(w, state, metrics, r0, schedule_chunk, n_rounds=n)
          -> (new_w, new_state, new_metrics)

    ``lax.scan``s the family's round body over ``n`` consecutive rounds
    starting at round ``r0``: per-round client selection comes from the
    ``(n, K)`` ``schedule_chunk`` slice, batches are gathered in-program
    from the device-resident ``data``, eval runs on-device every
    ``eval_every`` rounds (plus the final round), and per-round
    loss/accuracy/uplink-bits land in the preallocated ``(R,)`` buffers
    carried through ``metrics``.  Nothing crosses the host boundary
    inside a chunk; ``n_rounds`` is static, so a trailing partial chunk
    costs exactly one extra compile.
    """
    chunk, state0, metrics0 = _make_chunk_body(
        loss_fn, cfg, params, data, eval_program=eval_program,
        eval_every=eval_every, client_weights=client_weights)

    @partial(jax.jit, static_argnames=("n_rounds",))
    def run_chunk(w, state, metrics, r0, schedule_chunk, valid_chunk=None,
                  *, n_rounds: int):
        return chunk(jnp.int32(cfg.seed), w, state, metrics, r0,
                     schedule_chunk, n_rounds, valid_chunk)

    return run_chunk, state0, metrics0


def make_seeded_experiment_program(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # FederatedDataset
    *,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> Tuple[Callable, Dict[str, Pytree], Dict[str, jax.Array]]:
    """:func:`make_experiment_program` with ``seed`` as a traced argument.

      run_chunk(seed, w, state, metrics, r0, schedule_chunk, n_rounds=n)

    One compiled program serves EVERY seed (the host-loop sweep fallback
    dispatches it per seed without recompiling).
    """
    chunk, state0, metrics0 = _make_chunk_body(
        loss_fn, cfg, params, data, eval_program=eval_program,
        eval_every=eval_every, client_weights=client_weights)

    @partial(jax.jit, static_argnames=("n_rounds",))
    def run_chunk(seed, w, state, metrics, r0, schedule_chunk,
                  valid_chunk=None, *, n_rounds: int):
        return chunk(seed, w, state, metrics, r0, schedule_chunk, n_rounds,
                     valid_chunk)

    return run_chunk, state0, metrics0


def make_sweep_program(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # FederatedDataset
    *,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> Tuple[Callable, Dict[str, Pytree], Dict[str, jax.Array]]:
    """Vmap the experiment chunk over a ``(S,)`` seed axis — ONE compile.

    Returns ``(run_sweep, state0, metrics0)`` where ``state0``/``metrics0``
    are per-seed templates (broadcast them to a leading S axis) and

      run_sweep(seeds, w, state, metrics, r0, schedule_chunks, n_rounds=n)
          -> (new_w, new_state, new_metrics)     # all with leading S axis

    ``seeds`` is ``(S,)`` int32, ``schedule_chunks`` is ``(S, n, K)`` (each
    seed keeps its own seed-stable client schedule), and every carry leaf
    gains a leading S dim.  The dataset and eval program are shared across
    the seed axis — S experiments resident per dispatch.
    """
    chunk, state0, metrics0 = _make_chunk_body(
        loss_fn, cfg, params, data, eval_program=eval_program,
        eval_every=eval_every, client_weights=client_weights)

    @partial(jax.jit, static_argnames=("n_rounds",))
    def run_sweep(seeds, w, state, metrics, r0, schedule_chunks,
                  valid_chunks=None, *, n_rounds: int):
        if valid_chunks is None:
            return jax.vmap(
                lambda s, wi, sti, mi, sch: chunk(s, wi, sti, mi, r0, sch,
                                                  n_rounds)
            )(seeds, w, state, metrics, schedule_chunks)
        return jax.vmap(
            lambda s, wi, sti, mi, sch, vc: chunk(s, wi, sti, mi, r0, sch,
                                                  n_rounds, vc)
        )(seeds, w, state, metrics, schedule_chunks, valid_chunks)

    return run_sweep, state0, metrics0


# ---------------------------------------------------------------------------
# sharded sweeps: the seed axis over DEVICES via shard_map
# ---------------------------------------------------------------------------

def sweep_device_count(num_seeds: int,
                       max_devices: Optional[int] = None) -> int:
    """How many devices a ``sharding="devices"`` sweep spreads over.

    The largest divisor of ``num_seeds`` that fits the local device count
    (shard_map needs the seed axis to divide evenly); 1 when nothing
    divides — the sweep then degenerates to the plain vmapped program on
    one device.
    """
    if num_seeds <= 0:
        raise ValueError(f"need at least one seed, got {num_seeds}")
    avail = jax.local_device_count() if max_devices is None else max_devices
    for d in range(min(num_seeds, avail), 0, -1):
        if num_seeds % d == 0:
            return d
    return 1


def make_seed_mesh(devices: int):
    """1-D ``('seed',)`` mesh over the first ``devices`` LOCAL devices.

    Local, not global: :func:`sweep_device_count` sizes the mesh from the
    local count, and under multi-process jax the global list starts with
    other processes' non-addressable devices.
    """
    from jax.sharding import Mesh
    devs = jax.local_devices()
    if devices > len(devs):
        raise ValueError(
            f"asked for {devices} devices, only {len(devs)} present")
    return Mesh(np.asarray(devs[:devices]), ("seed",))


def make_sharded_sweep_program(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # FederatedDataset
    *,
    devices: int,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> Tuple[Callable, Dict[str, Pytree], Dict[str, jax.Array]]:
    """Shard the sweep's seed axis over a ``(devices,)`` mesh — one
    compile, S seeds across D devices instead of all resident on one.

    Call signature and carry layout are identical to
    :func:`make_sweep_program` (``(S, ...)`` leading seed axis on every
    carry leaf); the only constraint is ``S % devices == 0`` — each
    device runs S/D seeds as a local ``vmap`` inside ``shard_map``.
    Seeds are independent experiments, so the lowered program contains NO
    cross-device collectives: the dataset/eval constants replicate, every
    carry stays device-local, and wall time scales with S/D.
    """
    from jax.experimental.shard_map import shard_map

    chunk, state0, metrics0 = _make_chunk_body(
        loss_fn, cfg, params, data, eval_program=eval_program,
        eval_every=eval_every, client_weights=client_weights)
    mesh = make_seed_mesh(devices)
    seed_axis = P("seed")
    carry_specs = (seed_axis, seed_axis, seed_axis)

    @partial(jax.jit, static_argnames=("n_rounds",))
    def run_sweep(seeds, w, state, metrics, r0, schedule_chunks,
                  valid_chunks=None, *, n_rounds: int):
        if seeds.shape[0] % devices:
            raise ValueError(
                f"{seeds.shape[0]} seeds do not divide over {devices} "
                "devices (see sweep_device_count)")

        # check_rep off: the closed-over dataset/eval constants replicate
        # and no collective ever relates the shards — there is nothing
        # for replication checking to verify, and 0.4.x rejects some
        # closed-over-constant patterns under it.
        if valid_chunks is None:
            def shard_fn(seeds_l, w_l, state_l, metrics_l, r0_l, sched_l):
                return jax.vmap(
                    lambda s, wi, sti, mi, sch: chunk(s, wi, sti, mi, r0_l,
                                                      sch, n_rounds)
                )(seeds_l, w_l, state_l, metrics_l, sched_l)

            return shard_map(
                shard_fn, mesh=mesh,
                in_specs=(seed_axis, seed_axis, seed_axis, seed_axis, P(),
                          seed_axis),
                out_specs=carry_specs, check_rep=False,
            )(seeds, w, state, metrics, r0, schedule_chunks)

        def shard_fn_v(seeds_l, w_l, state_l, metrics_l, r0_l, sched_l,
                       valid_l):
            return jax.vmap(
                lambda s, wi, sti, mi, sch, vc: chunk(s, wi, sti, mi, r0_l,
                                                      sch, n_rounds, vc)
            )(seeds_l, w_l, state_l, metrics_l, sched_l, valid_l)

        return shard_map(
            shard_fn_v, mesh=mesh,
            in_specs=(seed_axis, seed_axis, seed_axis, seed_axis, P(),
                      seed_axis, seed_axis),
            out_specs=carry_specs, check_rep=False,
        )(seeds, w, state, metrics, r0, schedule_chunks, valid_chunks)

    return run_sweep, state0, metrics0


# ---------------------------------------------------------------------------
# the streaming cohort tier: larger-than-HBM populations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Visit:
    """One (round, cohort) dispatch of the cohort engine's plan."""

    round_idx: int
    cohort: int
    cids: np.ndarray        # (Kpad,) int32 global ids, padded w/ repeats
    locs: np.ndarray        # (Kpad,) int32 cohort-local rows
    weights: np.ndarray     # (Kpad,) f32 raw client weights
    n_valid: int            # real clients in this visit (rest masked)
    new_block: bool         # first visit touching this staged cohort
    round_end: bool         # last visit of its round (apply/eval follow)


class CohortRunner:
    """The cohort engine built by :func:`make_cohort_engine`.

    Each round's selected clients are grouped by cohort; every group runs
    through one jitted visit program (stage-block gather → the family's
    cohort uplink → ``codec.partial_aggregate``), partials tree-merge
    across the round's cohorts, and one jitted apply turns the finalized
    aggregate into the server update.  Cohort blocks are staged
    host→device on a single background thread (``prefetch=True``) so the
    next cohort's transfer hides behind the current cohort's compute;
    ``prefetch=False`` is the strict-serial ablation (stage → compute →
    stage, a ``block_until_ready`` between).
    """

    def __init__(self, loss_fn, cfg: FLConfig, params: Pytree, data, *,
                 eval_program=None, eval_every: int = 1,
                 client_weights=None):
        from ..data.federated import CohortedDataset, cohort_gather
        if not isinstance(data, CohortedDataset):
            raise ValueError(
                "engine='cohort' needs a CohortedDataset — build one with "
                "make_cohorted_dataset or FederatedDataset.cohorted(size)")
        algo = get_algorithm(cfg.algorithm)
        if algo.make_cohort_body is None:
            raise ValueError(
                f"algorithm {cfg.algorithm!r} declares no cohort body "
                "(Algorithm.make_cohort_body) — run it on engine='scan'")
        cw = None if client_weights is None else list(client_weights)
        if cw is not None and len(cw) != cfg.num_clients:
            raise ValueError(
                f"client_weights has {len(cw)} entries, "
                f"cfg expects {cfg.num_clients}")
        codec, uplink_fn, apply_fn = algo.make_cohort_body(
            loss_fn, cfg, params)
        if cw is not None and isinstance(codec, MaskCodec) \
                and codec.count_dtype is not None:
            raise ValueError(
                "int_mask_agg requires uniform client weights "
                "(client_weights=None)")
        if cw is not None and isinstance(codec, MaskCodec) \
                and codec.privacy is not None:
            raise ValueError(
                "privacy= requires uniform client weights "
                "(client_weights=None): the clipped-count sensitivity "
                "bound assumes every client contributes one unweighted "
                "mask")
        if cw is None and isinstance(codec, MaskCodec) \
                and codec.count_aggregatable and codec.count_dtype is None:
            # uniform weights + count-aggregatable format: cross-cohort
            # partials become ⌈log2(K+1)⌉-bit integer popcount sums (the
            # hierarchical half of ROADMAP direction 2) instead of f32
            codec = dataclasses.replace(
                codec, count_dtype=min_count_dtype(cfg.clients_per_round))
        self.cfg = cfg
        self.data = data
        self.codec = codec
        self._params = params
        self._state0 = algo.init_state(cfg, params)
        self._weights_all = np.asarray(
            [1.0] * cfg.num_clients if cw is None else cw, np.float32)
        self._eval = None if eval_program is None else jax.jit(eval_program)
        self._eval_every = eval_every
        # per-client measured wire bits — linear in K, so K × this equals
        # the scan engine's per-round codec.round_bits(stacked msg)
        self._bits_per_client = float(
            codec.wire_bits(params).uplink_bits)

        steps, batch, seed_b = cfg.local_steps, cfg.batch_size, data.batch_seed

        @jax.jit
        def visit(seed, w, state, block, cids, locs, wts, n_valid, r,
                  avail=None):
            valid = jnp.arange(cids.shape[0], dtype=jnp.int32) < n_valid
            if avail is not None:
                # availability drops compose with the padding mask: a
                # dropped client still computes (static shapes) but its
                # partial weight is zeroed — exactly the K−d survivors
                # aggregate
                valid = valid & (avail > 0)
            batches = cohort_gather(block, r, cids, locs, steps=steps,
                                    batch=batch, batch_seed=seed_b)
            msg, agg_w, losses = uplink_fn(seed, w, state, batches, cids,
                                           wts, r)
            part = codec.partial_aggregate(msg, agg_w, valid=valid,
                                           round_idx=r)
            loss_sum = jnp.sum(jnp.where(valid, losses[:, -1], 0.0))
            return part, loss_sum

        @jax.jit
        def apply_round(seed, w, state, part, r):
            agg = codec.finalize_partial(part)
            # the merged partial's weight mass doubles as the survivor
            # count for bodies that need it (fedpm's Beta smoothing)
            return apply_fn(seed, w, state, agg, r, part["weight"])

        self._visit = visit
        self._merge = jax.jit(codec.merge_partials)
        self._apply = apply_round

    # ---- round plan ----------------------------------------------------

    def plan(self, schedule: np.ndarray) -> List[_Visit]:
        """Group the ``(R, K)`` schedule into padded cohort visits.

        Within a round, cohorts are visited in ascending id; every visit
        is padded to the plan-wide max visit size (one compiled program
        shape) by repeating its first member with the padding masked out
        via ``n_valid``.
        """
        co, lo = self.data.cohort_of, self.data.local_of
        rounds = []
        kpad = 1
        for r in range(schedule.shape[0]):
            per: Dict[int, list] = {}
            for cid in schedule[r]:
                per.setdefault(int(co[cid]), []).append(int(cid))
            rounds.append(sorted(per.items()))
            kpad = max(kpad, max(len(v) for _, v in per.items()))
        visits = []
        prev_j = None
        for r, groups in enumerate(rounds):
            for g, (j, members) in enumerate(groups):
                cids = np.asarray(
                    members + [members[0]] * (kpad - len(members)),
                    np.int32)
                visits.append(_Visit(
                    round_idx=r, cohort=j, cids=cids, locs=lo[cids],
                    weights=self._weights_all[cids], n_valid=len(members),
                    new_block=(j != prev_j),
                    round_end=(g == len(groups) - 1)))
                prev_j = j
        return visits

    # ---- the streaming loop --------------------------------------------

    def run(self, *, seed: Optional[int] = None,
            schedule: Optional[np.ndarray] = None,
            prefetch: bool = True,
            valid: Optional[np.ndarray] = None
            ) -> Tuple[Dict[str, np.ndarray], np.ndarray, int]:
        """Stream the whole experiment; returns ``(metrics, schedule,
        num_dispatches)`` with scan-engine metric layout (``(R,)`` loss /
        NaN-padded acc / uplink_bits buffers).

        ``valid`` is an optional ``(R, K)`` availability mask aligned to
        the schedule (1.0 = the scheduled client uplinks this round); a
        round then aggregates exactly its survivors and the loss /
        uplink-bits metrics count only them.
        """
        cfg = self.cfg
        if seed is None:
            seed = cfg.seed
        if schedule is None:
            schedule = make_client_schedule(cfg, seed)
        participation = None
        if valid is not None:
            valid = np.asarray(valid, np.float32)
            if valid.shape != tuple(schedule.shape):
                raise ValueError(
                    f"valid mask shape {valid.shape} does not match "
                    f"schedule shape {tuple(schedule.shape)}")
            participation = valid.sum(axis=1).astype(np.int64)
            if (participation < 1).any():
                bad = np.nonzero(participation < 1)[0].tolist()
                raise ValueError(
                    f"rounds {bad} have zero surviving clients — lower "
                    "dropout or enable avail_resample")
        visits = self.plan(schedule)
        seed_dev = jnp.int32(seed)
        w, state = self._params, self._state0
        R = cfg.rounds
        loss_sums = [jnp.float32(0.0)] * R
        accs: List[Any] = [np.nan] * R
        eval_rounds = set(eval_round_indices(cfg, self._eval_every))
        dispatches = 0

        stage_points = [i for i, v in enumerate(visits) if v.new_block]
        executor = ThreadPoolExecutor(max_workers=1) if prefetch else None
        try:
            if prefetch:
                sp_iter = iter(stage_points)
                next(sp_iter)                       # visits[0] stages now
                fut = executor.submit(self.data.stage, visits[0].cohort)
                nxt = next(sp_iter, None)
            block = None
            part = None
            for i, v in enumerate(visits):
                if v.new_block:
                    if prefetch:
                        block = fut.result()
                        if nxt is not None:
                            fut = executor.submit(self.data.stage,
                                                  visits[nxt].cohort)
                            nxt = next(sp_iter, None)
                    else:
                        block = self.data.stage(v.cohort)
                if valid is None:
                    p, loss_sum = self._visit(
                        seed_dev, w, state, block, jnp.asarray(v.cids),
                        jnp.asarray(v.locs), jnp.asarray(v.weights),
                        jnp.int32(v.n_valid), jnp.int32(v.round_idx))
                else:
                    # map each visit member back to its schedule slot
                    # (cids are unique within a round) to pick up its
                    # availability bit; padding repeats a member's value —
                    # the n_valid mask kills those rows regardless
                    row = schedule[v.round_idx]
                    slot_of = {int(c): k for k, c in enumerate(row)}
                    avail = np.asarray(
                        [valid[v.round_idx][slot_of[int(c)]]
                         for c in v.cids], np.float32)
                    p, loss_sum = self._visit(
                        seed_dev, w, state, block, jnp.asarray(v.cids),
                        jnp.asarray(v.locs), jnp.asarray(v.weights),
                        jnp.int32(v.n_valid), jnp.int32(v.round_idx),
                        jnp.asarray(avail))
                dispatches += 1
                part = p if part is None else self._merge(part, p)
                r = v.round_idx
                loss_sums[r] = loss_sums[r] + loss_sum
                if v.round_end:
                    w, state = self._apply(seed_dev, w, state, part,
                                           jnp.int32(r))
                    part = None
                    dispatches += 1
                    if self._eval is not None and r in eval_rounds:
                        accs[r] = self._eval(w)
                        dispatches += 1
                elif not prefetch:
                    # strict serial: nothing overlaps the next stage
                    jax.block_until_ready(loss_sum)
        finally:
            if executor is not None:
                executor.shutdown(wait=True)

        K = cfg.clients_per_round
        if participation is None:
            loss = np.asarray(jnp.stack(loss_sums)) / np.float32(K)
            bits = np.full((R,), K * self._bits_per_client, np.float32)
        else:
            denom = participation.astype(np.float32)
            loss = np.asarray(jnp.stack(loss_sums)) / denom
            bits = denom * np.float32(self._bits_per_client)
        metrics = {
            "loss": loss,
            "acc": np.asarray([float(a) for a in accs], np.float32),
            "uplink_bits": bits,
        }
        self.final_params = w
        self.final_state = state
        return metrics, schedule, dispatches


def make_cohort_engine(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    cfg: FLConfig,
    params: Pytree,
    data,                                   # CohortedDataset
    *,
    eval_program: Optional[Callable[[Pytree], jax.Array]] = None,
    eval_every: int = 1,
    client_weights: Optional[Any] = None,
) -> CohortRunner:
    """Build the streaming cohort engine over a ``CohortedDataset``.

    The larger-than-HBM tier: the population's examples and index
    matrices stay host-resident, cohorts are double-buffered onto the
    device while the previous cohort's fused visit program runs, and
    each round's server update comes from hierarchical two-level
    aggregation — per-cohort codec partials (integer popcount sums in
    ``min_count_dtype`` for the count-aggregatable mask formats), then a
    tree-merge across cohorts and ONE finalize + apply.  Trajectories
    match the scan engine at fixed seed (same schedule, batch keys, and
    per-client key derivations; f32 summation order differs only across
    cohort boundaries).

    Returns a :class:`CohortRunner`; call
    ``runner.run(seed=..., prefetch=...)``.
    """
    return CohortRunner(loss_fn, cfg, params, data,
                        eval_program=eval_program, eval_every=eval_every,
                        client_weights=client_weights)
