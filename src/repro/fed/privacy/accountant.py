"""RDP accounting for the per-round DP count release → (ε, δ).

Host-side numpy (the accountant reads the participation the engines
RECORDED, never traced values).  Model per round ``t``:

* the mechanism releases the round's merged d-dimensional count vector
  plus one discrete noise draw of realized per-entry std σ_eff
  (``discrete_gaussian``: the configured σ = z·Δ₂; ``binomial``: √n/2
  for the even n actually drawn — never less than configured);
* one adjacent-dataset swap changes the release by at most the L2
  VECTOR sensitivity Δ₂ = ``PrivacyConfig.l2_sensitivity(mode, d)`` —
  under the default ``adjacency="client"`` a replaced client can move
  all d entries by up to the per-entry bound Δ, so Δ₂ = Δ·√d; under
  ``"entry"`` only one entry moves and Δ₂ = Δ.  The normalized noise
  scale is ``σ_n = σ_eff / Δ₂`` (exactly the configured multiplier z
  for the discrete Gaussian, since its σ is calibrated to z·Δ₂);
* the round touched ``participation[t]`` of ``num_clients`` clients —
  the TRUE survivor count the engine recorded, so a round degraded by
  ``d`` dropouts is accounted at sampling rate q_t = (K−d)/C, not the
  scheduled K/C.  CAVEAT: conditioning on realized dropouts is a valid
  amplification argument only when availability is independent of
  client data (true for every built-in ``AvailabilityTrace`` /
  ``FaultPlan``, which are seed/config-driven); if participation may
  correlate with the data, account at the scheduled rate instead —
  pass ``[K] * rounds`` — since realized ≤ scheduled means this
  function otherwise reports LESS spend, not a bound.

Per-round Rényi divergences compose by summation over rounds; we track
them at integer orders α and convert with the standard Mironov bound
ε(δ) = min_α [ RDP(α) + log(1/δ)/(α−1) ].  For q < 1 the subsampled
Gaussian bound at integer α (Mironov–Talwar–Zhang 2019, Thm. 4) is

    RDP(α) = log( Σ_{j=0..α} C(α,j) (1−q)^{α−j} q^j e^{j(j−1)/(2σ_n²)} )
             / (α − 1)

computed in log space; at q = 1 only the j = α term survives and the
expression reduces to the plain Gaussian α/(2σ_n²), so full
participation needs no special casing (we still shortcut it).

Documented approximations (see ``fed/privacy/README.md``): the
symmetric binomial is accounted as a Gaussian of equal variance — a
heuristic ESTIMATE, not a formal bound (the known binomial-mechanism
bounds, Agarwal et al. 2018, carry extra slack terms we do not track);
the discrete Gaussian uses the continuous-Gaussian RDP curve (a true
upper bound, Canonne–Kamath–Steinke 2020); fixed-size-without-
replacement selection is accounted with the Poisson-subsampling bound
at the same rate; and realized-participation conditioning assumes
data-independent availability (above).
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from .dp import PrivacyConfig

#: integer Rényi orders the accountant tracks — dense where the minimum
#: usually lands, sparse tail for very-low-noise configs
DEFAULT_ORDERS = tuple(range(2, 65)) + (80, 96, 128, 192, 256, 512)


def sigma_normalized(privacy: PrivacyConfig, mode: str,
                     num_params: int) -> float:
    """σ_eff / Δ₂ — noise over the release's L2 VECTOR sensitivity.

    ``num_params`` is the dimension d of the released count vector;
    Δ₂ = ``privacy.l2_sensitivity(mode, d)`` (Δ·√d at the default
    client adjacency).  The discrete Gaussian is calibrated σ = z·Δ₂,
    so this is exactly z; the binomial's realized σ_eff = √n/2 ≥ z·Δ₂.
    """
    if privacy.mechanism == "binomial":
        from .mechanisms import binomial_trials
        n = binomial_trials(privacy, mode, num_params)
        return math.sqrt(n) / 2.0 / privacy.l2_sensitivity(mode,
                                                           num_params)
    # validates num_params even though z alone is the answer
    privacy.l2_sensitivity(mode, num_params)
    return float(privacy.noise_multiplier)


def _logsumexp(terms) -> float:
    m = max(terms)
    return m + math.log(sum(math.exp(t - m) for t in terms))


def rdp_round(q: float, sigma_n: float,
              orders: Sequence[int] = DEFAULT_ORDERS) -> np.ndarray:
    """One round's RDP at each integer order, sampling rate ``q``."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling rate must be in [0, 1], got {q}")
    out = np.zeros(len(orders), np.float64)
    if q == 0.0:
        return out                                  # nobody participated
    for i, alpha in enumerate(orders):
        if q >= 1.0:
            out[i] = alpha / (2.0 * sigma_n * sigma_n)
            continue
        log1mq = math.log1p(-q)
        logq = math.log(q)
        terms = [
            (math.lgamma(alpha + 1) - math.lgamma(j + 1)
             - math.lgamma(alpha - j + 1))
            + (alpha - j) * log1mq + j * logq
            + j * (j - 1) / (2.0 * sigma_n * sigma_n)
            for j in range(alpha + 1)
        ]
        out[i] = max(0.0, _logsumexp(terms)) / (alpha - 1)
    return out


def eps_from_rdp(rdp: np.ndarray, delta: float,
                 orders: Sequence[int] = DEFAULT_ORDERS) -> float:
    """Mironov conversion: ε = min_α [ RDP(α) + log(1/δ)/(α−1) ]."""
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    log_inv = math.log(1.0 / delta)
    return float(min(r + log_inv / (a - 1) for r, a in zip(rdp, orders)))


def round_epsilons(privacy: PrivacyConfig, participation: Sequence[int],
                   num_clients: int, mode: str,
                   num_params: int) -> np.ndarray:
    """Cumulative ε AFTER each round, at the recorded participation.

    ``participation[t]`` is the number of clients whose contribution
    actually entered round ``t``'s release (K − dropouts); rounds
    compose by RDP summation, so the returned array is non-decreasing.
    ``num_params`` is the released vector's dimension — the accountant
    normalizes by the L2 sensitivity Δ₂ at ``privacy.adjacency``.
    Realized-participation accounting assumes data-independent
    availability (module docstring); pass the scheduled counts for the
    conditioning-free upper bound.
    """
    if num_clients < 1:
        raise ValueError(f"num_clients must be >= 1, got {num_clients}")
    sigma_n = sigma_normalized(privacy, mode, num_params)
    acc = np.zeros(len(DEFAULT_ORDERS), np.float64)
    eps = np.empty(len(participation), np.float64)
    cache = {}
    for t, k in enumerate(participation):
        q = min(1.0, int(k) / num_clients)
        if q not in cache:
            cache[q] = rdp_round(q, sigma_n)
        acc = acc + cache[q]
        eps[t] = eps_from_rdp(acc, privacy.delta)
    return eps


def epsilon_after(privacy: PrivacyConfig, participation: Sequence[int],
                  num_clients: int, mode: str,
                  num_params: int) -> float:
    """Total ε of the whole recorded run (inf for an empty run)."""
    if len(participation) == 0:
        return float("inf")
    return float(round_epsilons(privacy, participation,
                                num_clients, mode, num_params)[-1])
