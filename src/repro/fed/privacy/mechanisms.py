"""Seeded, jit-safe discrete noise + count clipping for the DP uplink.

Everything here is counter-based and rejection-free so the same draw is
reproducible inside any engine's compiled program:

  ``symmetric_binomial``   Binom(n, 1/2) − n/2 realized as popcounts of
                           ``jax.random.bits`` words (the last word
                           masked to ``n % 32`` trials) — an EXACT
                           integer sampler with variance n/4, n chosen
                           even so the mean shift is an integer.
  ``discrete_gaussian``    inversion sampling on counter-derived
                           uniforms: a numpy-precomputed CDF over the
                           truncated support [−T, T] (T = ⌈12σ⌉, mass
                           beyond it < 1e-31 · table tail) indexed by
                           ``jnp.searchsorted`` — no rejection loop, so
                           it vmaps/jits like any other primitive.
                           (f32 uniforms resolve ~2⁻²⁴; tail values
                           rarer than that are unreachable, a truncation
                           far below the accountant's δ.)
  ``clip_counts``          the REFERENCE ORACLE for per-client count
                           clipping at the configured sensitivity:
                           binary entries to [0, c], signed to [−c, c].
                           It runs in tests, not on the serving path —
                           mask wires satisfy clip ≥ 1 identically, so
                           the packed popcount path (including the
                           signed ``2c − K`` fixup) IS the clipped sum
                           structurally; the hypothesis property test in
                           ``tests/test_privacy.py`` pins that
                           equivalence ref ≡ pallas-interpret, and is
                           the ONLY thing enforcing it — a future
                           multi-bit wire must either clip at runtime
                           or fail that test.

``dp_noise_tree`` mirrors ``core/noise.py``'s ``gen_noise`` fold-in
idiom (per-leaf ``fold_in(key, i)``) so one key — derived as
``fold_in(key(dp_seed), round)`` by the codec — determines the whole
round's noise tree on every engine.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .dp import PrivacyConfig

Pytree = Any

# one uint32 word of jax.random.bits = 32 fair Bernoulli trials
_WORD = 32


def binomial_trials(privacy: PrivacyConfig, mode: str,
                    num_params: int) -> int:
    """Number of fair trials matching σ = z·Δ₂ (Var = n/4 → n = 4σ²).

    Rounded UP to the next even integer: the accountant then uses the
    realized σ_eff = √n/2 ≥ σ, never less noise than configured.
    Under ``adjacency="client"`` n grows linearly with ``num_params``
    (σ² = z²Δ²d) and the sampler draws ⌈n/32⌉ uint32 words PER ENTRY —
    fine at bench scale, prohibitive for large models; prefer
    ``discrete_gaussian`` there (its CDF table is only O(σ) long).
    """
    sigma = privacy.sigma(mode, num_params)
    n = int(math.ceil(4.0 * sigma * sigma))
    return max(2, n + (n % 2))


def symmetric_binomial(key: jax.Array, shape, n: int) -> jax.Array:
    """One draw of Binom(n, 1/2) − n/2 per element, int32."""
    if n < 2 or n % 2:
        raise ValueError(f"n must be even and >= 2, got {n}")
    W = (n + _WORD - 1) // _WORD
    rem = n - _WORD * (W - 1)                       # trials in last word
    bits = jax.random.bits(key, (W,) + tuple(shape), jnp.uint32)
    if rem < _WORD:
        tail = bits[W - 1] & jnp.uint32((1 << rem) - 1)
        bits = bits.at[W - 1].set(tail)
    pc = jax.lax.population_count(bits).astype(jnp.int32)
    return jnp.sum(pc, axis=0) - jnp.int32(n // 2)


def _dgauss_cdf(sigma: float) -> np.ndarray:
    """Normalized CDF of the discrete Gaussian on [−T, T] (host numpy;
    σ is static config, so this is a trace-time constant)."""
    T = max(1, int(math.ceil(12.0 * sigma)))
    t = np.arange(-T, T + 1, dtype=np.float64)
    logp = -(t * t) / (2.0 * sigma * sigma)
    p = np.exp(logp - logp.max())
    cdf = np.cumsum(p / p.sum())
    cdf[-1] = 1.0                                   # searchsorted-safe
    return cdf


def discrete_gaussian(key: jax.Array, shape, sigma: float) -> jax.Array:
    """One N_Z(0, σ²) draw per element via CDF inversion, int32."""
    if not sigma > 0:
        raise ValueError(f"sigma must be positive, got {sigma}")
    cdf = _dgauss_cdf(sigma)
    T = (len(cdf) - 1) // 2
    u = jax.random.uniform(key, tuple(shape))
    idx = jnp.searchsorted(jnp.asarray(cdf, jnp.float32), u, side="right")
    return (jnp.minimum(idx, 2 * T) - T).astype(jnp.int32)


def dp_noise_tree(key: jax.Array, tree: Pytree, privacy: PrivacyConfig,
                  mode: str) -> Pytree:
    """Int32 noise pytree matching ``tree``'s shapes — the one draw a
    round's finalize adds to its merged count (per-leaf ``fold_in``).

    σ is calibrated to the L2 sensitivity of the WHOLE release: ``tree``
    is the full count template, so d = Σ leaf sizes is the release
    dimension the configured adjacency's Δ₂ is computed at.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    num_params = int(sum(math.prod(jnp.shape(l)) for l in leaves))
    if privacy.mechanism == "binomial":
        n = binomial_trials(privacy, mode, num_params)
        sample = lambda k, s: symmetric_binomial(k, s, n)
    else:
        sigma = privacy.sigma(mode, num_params)
        sample = lambda k, s: discrete_gaussian(k, s, sigma)
    out = []
    for i, leaf in enumerate(leaves):
        out.append(sample(jax.random.fold_in(key, i), jnp.shape(leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def clip_counts(contrib: Pytree, clip: int, mode: str) -> Pytree:
    """Clip ONE client's count contribution at the sensitivity bound.

    Binary entries live in [0, clip]; signed in [−clip, clip].  On the
    1-bit mask wire this is the identity for any clip ≥ 1 — the packed
    popcount partial (with the signed ``2c − K`` fixup) therefore equals
    the clipped per-client sum exactly.  NOTE this function is the TEST
    ORACLE of that structural invariant, not a production op: no engine
    calls it at aggregation time (clipping there would need per-client
    unpacking the fused popcount path exists to avoid).  The sensitivity
    claim rests on the wire staying 1-bit, enforced solely by the
    hypothesis property test in ``tests/test_privacy.py``.
    """
    lo = -clip if mode == "signed" else 0

    def one(x):
        return jnp.clip(x, jnp.asarray(lo, x.dtype),
                        jnp.asarray(clip, x.dtype))

    return jax.tree_util.tree_map(one, contrib)
