"""Distributed DP over the mask-count wire (see README.md here).

Public surface:

  :class:`PrivacyConfig`      frozen config riding on ``FLConfig`` —
                              mechanism, noise multiplier z, clip,
                              adjacency (unit of protection), δ, and
                              the dp_seed of the round noise stream
  :mod:`mechanisms`           seeded discrete samplers + count clipping
  :mod:`accountant`           per-round subsampled RDP → (ε, δ)
"""
from .accountant import (DEFAULT_ORDERS, eps_from_rdp, epsilon_after,
                         rdp_round, round_epsilons, sigma_normalized)
from .dp import (ADJACENCIES, COUNT_FAMILIES, MECHANISMS, PrivacyConfig,
                 check_privacy_support, dp_mask_mode)
from .mechanisms import (binomial_trials, clip_counts, discrete_gaussian,
                         dp_noise_tree, symmetric_binomial)

__all__ = [
    "ADJACENCIES", "COUNT_FAMILIES", "DEFAULT_ORDERS", "MECHANISMS",
    "PrivacyConfig",
    "binomial_trials", "check_privacy_support", "clip_counts",
    "discrete_gaussian", "dp_mask_mode", "dp_noise_tree", "eps_from_rdp",
    "epsilon_after", "rdp_round", "round_epsilons", "sigma_normalized",
    "symmetric_binomial",
]
