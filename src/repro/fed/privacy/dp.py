"""`PrivacyConfig` — distributed DP over the mask-count wire.

FedMRN's uplink is a packed 1-bit mask per parameter, so a client's
contribution to the server-side count vector is bounded BY CONSTRUCTION:
one binary mask adds at most ``1`` per entry, one signed mask moves the
Σ±1 sum by at most ``2`` under replace-one adjacency.  That makes the
aggregated counts the natural place for the distributed/shuffled model
of DP (Girgis et al. 2020, PAPERS.md): clip each client's count
contribution (``mechanisms.clip_counts``), add ONE discrete noise draw
to the merged round count (``mechanisms.dp_noise_tree`` inside
``MaskCodec.finalize_partial``), and account the composition per round
at the participation actually recorded (``accountant.round_epsilons``).

``PrivacyConfig`` is frozen and hashable so it can ride on
:class:`~repro.fed.algorithms.FLConfig` (itself a jit/program-cache
key).  This module deliberately imports nothing from the codec or
engine layers — ``fed/codecs.py`` imports *us*.
"""
from __future__ import annotations

import dataclasses

MECHANISMS = ("discrete_gaussian", "binomial")

#: MaskCodec families whose server aggregate is a pure mask count —
#: the only formats the DP aggregation path can route (per-client-noise
#: fedmrn sums Σ w'_k G(s_k)⊙m_k, which no count release can express).
COUNT_FAMILIES = ("fedmrn", "fedmrns", "fedpm")


def dp_mask_mode(algorithm: str) -> str:
    """The mask mode the accountant's sensitivity is computed at."""
    return "signed" if algorithm == "fedmrns" else "binary"


@dataclasses.dataclass(frozen=True)
class PrivacyConfig:
    """Static description of the distributed-DP count release.

    ``noise_multiplier`` is z = σ/Δ, the noise scale in units of the
    clipped sensitivity — the quantity the RDP accountant actually
    consumes, so sweeping it traces the ε/accuracy frontier directly.
    ``clip`` bounds one client's per-entry count contribution; mask
    wires satisfy any ``clip ≥ 1`` identically (|entry| ≤ 1), but the
    clip is still applied (and property-tested) so the sensitivity
    claim never silently depends on the wire format staying 1-bit.
    """

    mechanism: str = "discrete_gaussian"   # one of MECHANISMS
    noise_multiplier: float = 1.0          # z = σ / sensitivity
    clip: int = 1                          # per-entry contribution bound
    delta: float = 1e-5                    # target δ of the (ε, δ) report
    dp_seed: int = 0                       # noise stream root (fold_in round)

    def validate(self) -> None:
        if self.mechanism not in MECHANISMS:
            raise ValueError(
                f"unknown DP mechanism {self.mechanism!r} "
                f"(supported: {', '.join(MECHANISMS)})")
        if not self.noise_multiplier > 0:
            raise ValueError(
                "noise_multiplier must be positive, got "
                f"{self.noise_multiplier}")
        if not (isinstance(self.clip, int) and self.clip >= 1):
            raise ValueError(
                f"clip must be an integer >= 1 (counts are integers), "
                f"got {self.clip!r}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(
                f"delta must be in (0, 1), got {self.delta}")

    def sensitivity(self, mode: str) -> int:
        """Δ of one round's count release under replace-one adjacency.

        Binary masks: one client's clipped entry lives in [0, clip] →
        Δ = clip.  Signed masks: in [−clip, clip] → Δ = 2·clip (the
        exact width the ``2c − K`` popcount fixup preserves).
        """
        return 2 * self.clip if mode == "signed" else self.clip

    def sigma(self, mode: str) -> float:
        """Target noise standard deviation σ = z · Δ in count units."""
        return self.noise_multiplier * self.sensitivity(mode)


def check_privacy_support(cfg) -> None:
    """Raise unless ``cfg``'s family can route the DP count path.

    Called from :meth:`FLConfig.validate`; takes the config duck-typed
    to keep this module import-free of the algorithm layer.
    """
    privacy = cfg.privacy
    if privacy is None:
        return
    privacy.validate()
    if cfg.algorithm not in COUNT_FAMILIES:
        raise ValueError(
            f"privacy= (distributed DP on mask counts) needs a "
            f"count-aggregatable MaskCodec family "
            f"({', '.join(COUNT_FAMILIES)}), got {cfg.algorithm!r} — "
            "dense/sign/sparse wires have no bounded-count release to "
            "noise")
    if cfg.algorithm in ("fedmrn", "fedmrns") and not cfg.shared_noise:
        raise ValueError(
            "privacy= needs shared_noise for fedmrn/fedmrns: with "
            "per-client noise the server update Σ w'_k G(s_k)⊙m_k is "
            "not a function of the mask counts the DP release protects")
