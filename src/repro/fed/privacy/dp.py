"""`PrivacyConfig` — distributed DP over the mask-count wire.

FedMRN's uplink is a packed 1-bit mask per parameter, so a client's
contribution to the server-side count vector is bounded BY CONSTRUCTION:
one binary mask adds at most ``1`` per entry, one signed mask moves the
Σ±1 sum by at most ``2``.  That per-entry bound is STRUCTURAL — the
packed popcount partial is identically the clipped per-client sum for
any ``clip ≥ 1`` (``mechanisms.clip_counts`` is the reference oracle
the property tests in ``tests/test_privacy.py`` enforce; no runtime
clip op runs on the aggregation path).  The release protects, per
``PrivacyConfig.adjacency``, either a client's WHOLE mask (``"client"``,
the default: the d-entry count vector has L2 sensitivity
``Δ₂ = Δ·√d``) or a single mask entry (``"entry"``: ``Δ₂ = Δ``, the
weaker, explicitly-opt-in unit).  That makes the aggregated counts the
natural place for the distributed/shuffled model of DP (Girgis et al.
2020, PAPERS.md): add ONE discrete noise draw calibrated to ``z·Δ₂``
to the merged round count (``mechanisms.dp_noise_tree`` inside
``MaskCodec.finalize_partial``), and account the composition per round
at the participation actually recorded (``accountant.round_epsilons``;
a documented approximation — see ``fed/privacy/README.md``).

``PrivacyConfig`` is frozen and hashable so it can ride on
:class:`~repro.fed.algorithms.FLConfig` (itself a jit/program-cache
key).  This module deliberately imports nothing from the codec or
engine layers — ``fed/codecs.py`` imports *us*.
"""
from __future__ import annotations

import dataclasses
import math

MECHANISMS = ("discrete_gaussian", "binomial")

#: units of protection the release can be calibrated/accounted at —
#: "client" protects a client's whole d-entry mask (Δ₂ = Δ·√d),
#: "entry" a single mask entry (Δ₂ = Δ; weaker, explicit opt-in)
ADJACENCIES = ("client", "entry")

#: MaskCodec families whose server aggregate is a pure mask count —
#: the only formats the DP aggregation path can route (per-client-noise
#: fedmrn sums Σ w'_k G(s_k)⊙m_k, which no count release can express).
COUNT_FAMILIES = ("fedmrn", "fedmrns", "fedpm")


def dp_mask_mode(algorithm: str) -> str:
    """The mask mode the accountant's sensitivity is computed at."""
    return "signed" if algorithm == "fedmrns" else "binary"


@dataclasses.dataclass(frozen=True)
class PrivacyConfig:
    """Static description of the distributed-DP count release.

    ``noise_multiplier`` is z = σ/Δ₂, the noise scale in units of the
    release's L2 sensitivity under the configured ``adjacency`` — the
    quantity the RDP accountant actually consumes, so sweeping it
    traces the ε/accuracy frontier directly (same convention as the
    DP-SGD clip-norm multiplier).  ``clip`` bounds one client's
    PER-ENTRY count contribution; mask wires satisfy any ``clip ≥ 1``
    identically (|entry| ≤ 1) — the bound is structural, enforced by
    the 1-bit wire format and pinned by property tests against
    ``mechanisms.clip_counts``, not by a runtime clip op on the
    aggregation path.

    ``adjacency`` fixes the unit of protection and therefore Δ₂:

    * ``"client"`` (default) — replace-one-CLIENT adjacency.  Swapping
      one client can move every one of the d released entries by up to
      the per-entry bound Δ, so Δ₂ = Δ·√d and the per-entry noise
      σ = z·Δ·√d grows with the model size: the honest price of
      protecting a whole mask with independent per-entry noise.
    * ``"entry"`` — replace-one-ENTRY adjacency.  The unit of
      protection is a single mask entry (one parameter's bit), NOT a
      client's whole contribution; Δ₂ = Δ independent of d, so the
      noise is cheap but the guarantee is far weaker.  Never the
      default — opting in is an explicit statement of the threat model.
    """

    mechanism: str = "discrete_gaussian"   # one of MECHANISMS
    noise_multiplier: float = 1.0          # z = σ / L2 sensitivity
    clip: int = 1                          # per-entry contribution bound
    delta: float = 1e-5                    # target δ of the (ε, δ) report
    dp_seed: int = 0                       # noise stream root (fold_in round)
    adjacency: str = "client"              # one of ADJACENCIES

    def validate(self) -> None:
        if self.mechanism not in MECHANISMS:
            raise ValueError(
                f"unknown DP mechanism {self.mechanism!r} "
                f"(supported: {', '.join(MECHANISMS)})")
        if not self.noise_multiplier > 0:
            raise ValueError(
                "noise_multiplier must be positive, got "
                f"{self.noise_multiplier}")
        if not (isinstance(self.clip, int) and self.clip >= 1):
            raise ValueError(
                f"clip must be an integer >= 1 (counts are integers), "
                f"got {self.clip!r}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(
                f"delta must be in (0, 1), got {self.delta}")
        if self.adjacency not in ADJACENCIES:
            raise ValueError(
                f"unknown DP adjacency {self.adjacency!r} "
                f"(supported: {', '.join(ADJACENCIES)})")

    def sensitivity(self, mode: str) -> int:
        """Per-ENTRY bound Δ on one client's count contribution.

        Binary masks: one client's entry lives in [0, clip] →
        Δ = clip.  Signed masks: in [−clip, clip] → Δ = 2·clip (the
        exact width the ``2c − K`` popcount fixup preserves).
        """
        return 2 * self.clip if mode == "signed" else self.clip

    def l2_sensitivity(self, mode: str, num_params: int) -> float:
        """Δ₂ of the d-dimensional count release at this adjacency.

        ``"client"``: replacing one client moves every one of the
        ``num_params`` entries by up to Δ → Δ₂ = Δ·√d.  ``"entry"``:
        one entry moves → Δ₂ = Δ, independent of d.
        """
        if not (isinstance(num_params, int) and num_params >= 1):
            raise ValueError(
                f"num_params must be an integer >= 1, got {num_params!r}")
        d = self.sensitivity(mode)
        if self.adjacency == "entry":
            return float(d)
        return d * math.sqrt(num_params)

    def sigma(self, mode: str, num_params: int) -> float:
        """Target per-entry noise std σ = z · Δ₂ in count units."""
        return self.noise_multiplier * self.l2_sensitivity(mode,
                                                           num_params)


def check_privacy_support(cfg) -> None:
    """Raise unless ``cfg``'s family can route the DP count path.

    Called from :meth:`FLConfig.validate`; takes the config duck-typed
    to keep this module import-free of the algorithm layer.
    """
    privacy = cfg.privacy
    if privacy is None:
        return
    privacy.validate()
    if cfg.algorithm not in COUNT_FAMILIES:
        raise ValueError(
            f"privacy= (distributed DP on mask counts) needs a "
            f"count-aggregatable MaskCodec family "
            f"({', '.join(COUNT_FAMILIES)}), got {cfg.algorithm!r} — "
            "dense/sign/sparse wires have no bounded-count release to "
            "noise")
    if cfg.algorithm in ("fedmrn", "fedmrns") and not cfg.shared_noise:
        raise ValueError(
            "privacy= needs shared_noise for fedmrn/fedmrns: with "
            "per-client noise the server update Σ w'_k G(s_k)⊙m_k is "
            "not a function of the mask counts the DP release protects")
