"""Federated training engine (declarative API + simulation + pod modes).

Start with :class:`ExperimentSpec` + :class:`Experiment` (``fed/api.py``);
algorithms plug in through the :data:`ALGORITHMS` registry
(``fed/algorithms.py``); the execution drivers live in ``fed/engine.py``.
"""
from .algorithms import (  # noqa: F401
    ALGORITHMS, Algorithm, FLConfig, algorithm_codec, get_algorithm,
    list_algorithms, register_algorithm, uplink_bits,
)
from .codecs import (  # noqa: F401
    DenseCodec, MaskCodec, QuantCodec, SignCodec, SparseCodec, UplinkCodec,
    WireMsg, mask_count_bits, min_count_dtype, template_of,
)
from .engine import (  # noqa: F401
    CohortRunner, make_client_schedule, make_cohort_engine,
    make_experiment_program, make_round_body, make_round_engine,
    make_seeded_experiment_program, make_sharded_sweep_program,
    make_sweep_program, sweep_device_count,
)
from .api import (  # noqa: F401
    ENGINES, HISTORY_KEYS, Experiment, ExperimentSpec, RunResult,
    SweepPoint, SweepResult, dp_epsilon_schedule,
)
from .privacy import PrivacyConfig  # noqa: F401
from .availability import (  # noqa: F401
    AvailabilityTrace, FaultPlan, make_availability,
)
from .scenarios import (  # noqa: F401
    alpha_curve, dropout_curve, make_synthetic_spec,
)
from .service import (  # noqa: F401
    ServiceConfig, ServiceReport, make_service_engine,
)
from .simulation import run_federated  # noqa: F401
