"""Federated training engine (simulation + sharded pod modes)."""
from .simulation import ALGORITHMS, FLConfig, run_federated  # noqa: F401
