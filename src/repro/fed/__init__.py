"""Federated training engine (simulation + sharded pod modes)."""
from .engine import make_round_engine, uplink_bits  # noqa: F401
from .simulation import ALGORITHMS, FLConfig, run_federated  # noqa: F401
