"""Federated training engine (simulation + sharded pod modes)."""
from .engine import (  # noqa: F401
    make_client_schedule, make_experiment_program, make_round_body,
    make_round_engine, uplink_bits,
)
from .simulation import ALGORITHMS, ENGINES, FLConfig, run_federated  # noqa: F401
