"""Typed uplink wire formats — what literally crosses the client→server wire.

The paper's contribution IS a wire format: clients transmit a packed
1-bit mask plus a 64-bit random seed instead of a float32 update.  This
module makes that format (and every baseline's) a first-class, typed
object instead of an accounting estimate:

  :class:`WireMsg`      one client's encoded payload — a dict of real
                        device buffers (packed words, seeds, scales,
                        indices…) registered as a pytree, so it vmaps
                        over a stacked client axis and flows through
                        jitted round programs.  ``msg.bits`` is the
                        summed buffer size — the *measured* wire cost.
  :class:`UplinkCodec`  the protocol every algorithm family declares:

      encode(payload)            -> WireMsg        (client side)
      decode(msg)                -> payload        (inverse, lossless
                                                    for mask/dense)
      aggregate(stacked, weights)-> server update  (the ONLY way engine
                                                    round bodies may
                                                    cross the wire)
      wire_bits(params)          -> CommRecord     (cost report: exact
                                                    measured + paper +
                                                    downlink bits)

Built-ins:

  :class:`MaskCodec`    packed 1-bit masks + the 64-bit noise seed
                        (binary / signed, over the ``core/packing``
                        Pallas bitpack kernels).  Its server aggregation
                        optionally reduces mask COUNTS in the minimal
                        integer dtype holding ``⌈log2(K+1)⌉`` bits
                        (``count_dtype``) — on the pod mesh that lowers
                        the cross-client collective to an integer-dtype
                        all-reduce instead of f32.
  :class:`SignCodec`    1-bit signs + a 32-bit per-leaf scale (SIGNSGD).
  :class:`DenseCodec`   float32 passthrough (FedAvg; also the transport
                        for compressors whose quantization happens
                        in-body, with ``record`` reporting the quantized
                        wire cost the f32 simulation stands in for).
  :class:`SparseCodec`  top-k values + int32 indices (top-k /
                        FedSparsify).

Every :class:`~repro.fed.algorithms.Algorithm` declares a ``codec``
factory (``(cfg, params) -> UplinkCodec``); engines reach it through
:func:`repro.fed.algorithms.algorithm_codec`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import NoiseConfig, gen_noise, mix_add
from ..core.backend import resolve_backend
from ..core.comm import CommRecord
from ..core.compressors import (_KEY_SALT, stochastic_dequantize,
                                stochastic_quantize)
from ..core.masking import (tree_bernoulli_stacked, tree_mask_uplink,
                            tree_sample_mask_stacked)
from ..core.packing import (tree_flat_layout, tree_num_params, tree_pack,
                            tree_pack_stacked, tree_split_flat, tree_unpack,
                            tree_unpack_counts, tree_unpack_counts_apply,
                            tree_unpack_stacked)
from .privacy.dp import PrivacyConfig
from .privacy.mechanisms import dp_noise_tree

Pytree = Any


def template_of(params: Pytree) -> Pytree:
    """Shape/dtype specs of a param pytree (what codecs close over)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), params)


def mask_count_bits(clients: int, *, signed: bool = False) -> int:
    """Logical bit width of a K-client mask-count sum.

    Binary masks sum to [0, K] → ``⌈log2(K+1)⌉`` bits; signed masks sum
    to [-K, K] → one more for the sign.
    """
    if clients < 1:
        raise ValueError(f"need at least one client, got {clients}")
    span = 2 * clients + 1 if signed else clients + 1
    return max(1, math.ceil(math.log2(span)))


def min_count_dtype(clients: int):
    """Smallest machine integer dtype holding a ±K mask-count sum.

    The ``⌈log2(K+1)⌉``-bit wire format rounds up to the next machine
    width — what the pod-path all-reduce actually moves.
    """
    if clients <= 127:
        return jnp.int8
    if clients <= 32767:
        return jnp.int16
    return jnp.int32


# ---------------------------------------------------------------------------
# the wire message
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WireMsg:
    """One encoded uplink payload: named device buffers + codec tag.

    A pytree (buffers are the children, ``codec`` + key order the static
    aux data), so ``vmap``-ing a per-client ``encode`` yields ONE
    ``WireMsg`` whose buffers carry a leading client axis — the
    "stacked" message the server aggregates.
    """

    codec: str
    buffers: Dict[str, jax.Array]

    def tree_flatten(self):
        keys = tuple(sorted(self.buffers))
        return tuple(self.buffers[k] for k in keys), (self.codec, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codec, keys = aux
        return cls(codec, dict(zip(keys, children)))

    @property
    def bits(self) -> int:
        """Summed buffer size in bits (static under jit — shapes only).

        On a stacked message this is the K-client round total; divide by
        the leading axis for the per-client cost.
        """
        return sum(
            int(np.prod(jnp.shape(b)) or 1) * np.dtype(b.dtype).itemsize * 8
            for b in self.buffers.values())


def _weighted(wn: jax.Array, stacked: Pytree) -> Pytree:
    """Σ_k wn_k · leaf[k] over the leading client axis (wn pre-scaled)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.tensordot(wn, x.astype(jnp.float32), axes=1), stacked)


# ---------------------------------------------------------------------------
# the codec protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class UplinkCodec:
    """Base of every uplink wire format; subclasses implement the four
    methods below.  ``record`` (when set) overrides the cost report —
    used when the simulated transport (f32) stands in for a quantized
    wire format whose true cost the codec still reports."""

    template: Pytree
    name: str = "codec"
    record: Optional[CommRecord] = None

    # codecs whose encode needs a per-client PRNG key in the payload
    # (stochastic quantizers) set this to True; engines then thread the
    # client round key through as ``payload["key"]``
    needs_key = False

    # --- the protocol ---------------------------------------------------
    def encode(self, payload: Pytree) -> WireMsg:
        raise NotImplementedError

    def decode(self, msg: WireMsg) -> Pytree:
        raise NotImplementedError

    def aggregate(self, stacked: WireMsg, weights: jax.Array, *,
                  round_idx=None) -> Pytree:
        """Stacked client messages + round weights → the server update.

        ``round_idx`` only matters to privacy-enabled mask codecs (the
        round's DP noise draw is keyed on it); every other format
        ignores it, so engines can pass it unconditionally.
        """
        raise NotImplementedError

    def wire_bits(self, params: Pytree) -> CommRecord:
        """The codec's cost report: MEASURED uplink bits (summed encoded
        buffer sizes via ``eval_shape`` — no FLOPs), the paper-style
        figure, and the (uncompressed f32) downlink."""
        if self.record is not None:
            return self.record
        P = tree_num_params(params)
        return CommRecord(self.name, P, self.measured_bits(params),
                          self._paper_bits(params), 32 * P)

    # --- hierarchical (cohort) aggregation ------------------------------
    # The cohort engine never sees the whole client stack at once: each
    # cohort contributes a PARTIAL (an unnormalized weighted sum plus the
    # weight mass it covers), partials tree-reduce across cohorts, and
    # one finalize recovers exactly what ``aggregate`` over the full
    # stack would have produced:
    #
    #   finalize(merge(p_1, …, p_J)) == aggregate(concat(stacks), weights)
    #
    # up to f32 summation order.  ``valid`` masks padding slots (cohort
    # visits are padded to a common K for one compiled program).

    def _wsum(self, stacked: WireMsg, w: jax.Array) -> Pytree:
        """Unnormalized Σ_k w_k · decode_k over the leading client axis."""
        raise NotImplementedError

    def partial_aggregate(self, stacked: WireMsg, weights: jax.Array,
                          *, valid: Optional[jax.Array] = None,
                          round_idx=None) -> Dict:
        """One cohort's contribution: ``{"sum", "weight", "n"}``.

        ``round_idx`` is carried into the partial only by
        privacy-enabled mask codecs (first-wins on merge, like the
        shared-noise seed); the base protocol accepts and ignores it.
        """
        if valid is None:
            w = weights
            n = jnp.int32(jnp.shape(weights)[0])
        else:
            w = weights * valid.astype(weights.dtype)
            n = jnp.sum(valid.astype(jnp.int32))
        return {"sum": self._wsum(stacked, w), "weight": jnp.sum(w), "n": n}

    def merge_partials(self, acc: Dict, part: Dict) -> Dict:
        out = {}
        for k in acc:
            if k in ("seed", "round"):
                # shared noise seed / DP round tag: identical across the
                # round's partials by construction — first wins
                out[k] = acc[k]
            else:
                out[k] = jax.tree_util.tree_map(jnp.add, acc[k], part[k])
        return out

    def finalize_partial(self, partial: Dict) -> Pytree:
        """Merged partials → the server update ``aggregate`` would give."""
        return jax.tree_util.tree_map(
            lambda s: s / partial["weight"], partial["sum"])

    # --- shared machinery ----------------------------------------------
    def encode_stacked(self, payloads: Pytree) -> WireMsg:
        """Encode a client-stacked payload (leading K axis on every
        leaf) into one stacked message.  Default: ``vmap(encode)``;
        subclasses override with batch kernels (one launch per round)."""
        return jax.vmap(self.encode)(payloads)

    def measured_bits(self, params: Pytree) -> int:
        """Per-client wire bits measured from the encoded buffer shapes."""
        msg = jax.eval_shape(self.encode, self.template_payload(params))
        return msg.bits

    def round_bits(self, stacked: WireMsg) -> float:
        """K-client measured wire bits of one round's stacked message.

        With a ``record`` override the report is K × the record's exact
        bits (the f32 sim buffers are NOT the claimed wire format)."""
        if self.record is not None:
            k = jnp.shape(next(iter(stacked.buffers.values())))[0]
            return float(k * self.record.uplink_bits)
        return float(stacked.bits)

    def template_payload(self, params: Pytree) -> Pytree:
        """A spec-level payload for ``eval_shape`` measurements."""
        raise NotImplementedError

    def _paper_bits(self, params: Pytree) -> int:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# built-in codecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class MaskCodec(UplinkCodec):
    """Packed 1-bit masks (+ the 64-bit noise seed) — the paper's format.

    ``payload = {"mask": pytree}`` (plus ``"seed"``: the client's PRNG
    key, when ``noise`` is set).  ``aggregate`` semantics:

      noise=None              Σ_k w'_k m_k   (mask-frequency aggregate —
                              FedPM; ``normalize=False`` keeps raw
                              weighted counts)
      noise, shared_noise     G(s) ⊙ Σ_k w'_k m_k  (one regenerated
                              noise tensor scales the mask count)
      noise, per-client       Σ_k w'_k G(s_k) ⊙ m_k  (Eq. 5 — seeds come
                              off the wire, noise regenerated per client)

    ``count_dtype`` switches the count paths to an integer-dtype client
    sum (``packing.tree_unpack_counts``): on the pod mesh the
    cross-client collective then moves ``⌈log2(K+1)⌉``-bit integers, not
    f32.  Only valid under UNIFORM weights (engines enforce this) and a
    count-aggregatable format (``noise is None`` or ``shared_noise``).

    ``privacy`` routes the count-aggregatable formats through the
    distributed-DP release (``fed/privacy/``): aggregation ALWAYS runs
    the integer count path.  Per-client clipping is STRUCTURAL, not a
    runtime op — the 1-bit wire satisfies any ``clip ≥ 1`` identically,
    so the popcount partial IS the clipped sum; the invariant is
    enforced only by the property tests against the reference oracle
    ``privacy.mechanisms.clip_counts`` (a wire format change must
    either clip at runtime or fail them).  Partials carry the round tag,
    and ``finalize_partial`` adds ONE discrete noise draw keyed on
    ``fold_in(key(dp_seed), round)`` — so full-stack, cohort-split and
    service-pooled aggregation noise the same integers identically.
    """

    mode: str = "binary"
    noise: Optional[NoiseConfig] = None
    shared_noise: bool = False
    normalize: bool = True
    count_dtype: Optional[Any] = None
    backend: Optional[str] = None
    privacy: Optional[PrivacyConfig] = None

    @property
    def carries_seed(self) -> bool:
        return self.noise is not None

    @property
    def count_aggregatable(self) -> bool:
        """Whether the server sum is a pure mask count (→ integer
        all-reduce eligible): no noise, or one shared noise tensor."""
        return self.noise is None or self.shared_noise

    def encode(self, payload: Pytree) -> WireMsg:
        bufs = {"words": tree_pack(payload["mask"], mode=self.mode,
                                   backend=self.backend)}
        if self.carries_seed:
            bufs["seed"] = jax.random.key_data(payload["seed"])
        return WireMsg(self.name, bufs)

    def encode_stacked(self, payloads: Pytree) -> WireMsg:
        bufs = {"words": tree_pack_stacked(payloads["mask"], mode=self.mode,
                                           backend=self.backend)}
        if self.carries_seed:
            bufs["seed"] = jax.random.key_data(payloads["seed"])
        return WireMsg(self.name, bufs)

    def decode(self, msg: WireMsg) -> Pytree:
        out = {"mask": tree_unpack(msg.buffers["words"], self.template,
                                   mode=self.mode, backend=self.backend)}
        if "seed" in msg.buffers:
            out["seed"] = jax.random.wrap_key_data(msg.buffers["seed"])
        return out

    def aggregate(self, stacked: WireMsg, weights: jax.Array, *,
                  round_idx=None) -> Pytree:
        if self.privacy is not None:
            # DP routes through the partial protocol so the full stack,
            # a cohort split and a service pool all noise the SAME
            # merged integers with the SAME single draw per round
            return self.finalize_partial(self.partial_aggregate(
                stacked, weights, round_idx=round_idx))
        words = stacked.buffers["words"]
        wn = weights / jnp.sum(weights) if self.normalize else weights
        if self.noise is not None and not self.shared_noise:
            # Eq. (5) with per-client noise: decode every client, then
            # the weighted sum — counts alone cannot express this.
            masks = tree_unpack_stacked(words, self.template,
                                        mode=self.mode,
                                        backend=self.backend)
            keys = jax.random.wrap_key_data(stacked.buffers["seed"])

            def one(key, m_c):
                noise = gen_noise(key, self.template, self.noise)
                return jax.tree_util.tree_map(
                    lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_c)

            return _weighted(wn, jax.vmap(one)(keys, masks))

        # count-aggregatable: Σ w'_k m_k, integer dtype when requested
        if self.count_dtype is not None:
            counts = tree_unpack_counts(words, self.template,
                                        mode=self.mode,
                                        dtype=self.count_dtype,
                                        backend=self.backend)
            m_avg = jax.tree_util.tree_map(
                lambda c: c.astype(jnp.float32) * wn[0], counts)
        else:
            masks = tree_unpack_stacked(words, self.template,
                                        mode=self.mode,
                                        backend=self.backend)
            m_avg = _weighted(wn, masks)
        if self.noise is None:
            return m_avg
        key0 = jax.random.wrap_key_data(stacked.buffers["seed"])[0]
        noise = gen_noise(key0, self.template, self.noise)
        return jax.tree_util.tree_map(
            lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_avg)

    # --- hierarchical partials ------------------------------------------
    def partial_aggregate(self, stacked: WireMsg, weights: jax.Array,
                          *, valid: Optional[jax.Array] = None,
                          round_idx=None) -> Dict:
        if self.privacy is not None:
            if not self.count_aggregatable:
                raise ValueError(
                    "privacy-enabled MaskCodec needs a count-aggregatable "
                    "format (no noise, or shared_noise): per-client noise "
                    "sums Σ w'_k G(s_k)⊙m_k, which no count release can "
                    "express")
            if round_idx is None:
                raise ValueError(
                    "privacy-enabled MaskCodec needs round_idx= at "
                    "partial_aggregate — the round's single DP noise draw "
                    "is keyed on fold_in(dp_seed, round)")
        words = stacked.buffers["words"]
        K = jnp.shape(words)[0]
        if valid is None:
            w = weights
            n = jnp.int32(K)
        else:
            w = weights * valid.astype(weights.dtype)
            n = jnp.sum(valid.astype(jnp.int32))
        part: Dict[str, Any] = {"weight": jnp.sum(w), "n": n}
        if self.privacy is not None:
            part["round"] = jnp.asarray(round_idx, jnp.int32)
        if self.count_aggregatable and (self.count_dtype is not None
                                        or self.privacy is not None):
            # integer count partial: zero the padding rows' packed words,
            # popcount-sum in count_dtype.  In signed mode a zeroed row
            # still decodes as all −1 (2·0 − 1), so the raw masked sum is
            # 2c − K; adding (K − n) restores the true Σ±1 over the n
            # valid rows — an exact integer adjustment.
            # Under privacy the count path is mandatory even without an
            # explicit count_dtype: the DP release is defined on the
            # clipped integer counts.  No clip op runs here — the 1-bit
            # wire satisfies any clip ≥ 1 identically, so this popcount
            # sum IS the clipped per-client sum structurally; the
            # property tests in tests/test_privacy.py (vs the
            # clip_counts oracle) are what enforce that equivalence.
            cdt = (self.count_dtype if self.count_dtype is not None
                   else jnp.int32)
            if valid is not None:
                words = words * valid[:, None].astype(words.dtype)
            counts = tree_unpack_counts(words, self.template,
                                        mode=self.mode,
                                        dtype=cdt,
                                        backend=self.backend)
            if self.mode == "signed" and valid is not None:
                fix = (jnp.int32(K) - n).astype(cdt)
                counts = jax.tree_util.tree_map(
                    lambda c: (c + fix).astype(cdt), counts)
            part["counts"] = counts
        else:
            masks = tree_unpack_stacked(words, self.template,
                                        mode=self.mode,
                                        backend=self.backend)
            if self.noise is not None and not self.shared_noise:
                # Eq. (5): fold each client's regenerated noise in before
                # the weighted sum — the partial is already noise-scaled
                keys = jax.random.wrap_key_data(stacked.buffers["seed"])

                def one(key, m_c):
                    noise = gen_noise(key, self.template, self.noise)
                    return jax.tree_util.tree_map(
                        lambda nl, ml: nl * ml.astype(nl.dtype), noise, m_c)

                part["sum"] = _weighted(w, jax.vmap(one)(keys, masks))
            else:
                part["sum"] = _weighted(w, masks)
        if self.noise is not None and self.shared_noise:
            # one shared noise tensor scales the final count — carry the
            # seed (identical across clients; slot 0 is always valid)
            part["seed"] = stacked.buffers["seed"][0]
        return part

    def finalize_partial(self, partial: Dict) -> Pytree:
        per_client_noise = self.noise is not None and not self.shared_noise
        if "counts" in partial:
            counts = partial["counts"]
            if self.privacy is not None:
                # ONE discrete noise draw per round, added to the MERGED
                # integer counts — cohort splits and service pool order
                # cannot change the release (integers sum exactly, the
                # key depends only on (dp_seed, round))
                dp_key = jax.random.fold_in(
                    jax.random.key(self.privacy.dp_seed),
                    partial["round"])
                z = dp_noise_tree(dp_key, counts, self.privacy, self.mode)
                counts = jax.tree_util.tree_map(
                    lambda c, zi: c.astype(jnp.int32) + zi, counts, z)
            n = partial["n"].astype(jnp.float32)
            m = jax.tree_util.tree_map(
                lambda c: (c.astype(jnp.float32) / n if self.normalize
                           else c.astype(jnp.float32)),
                counts)
        else:
            m = partial["sum"]
            if self.normalize:
                m = jax.tree_util.tree_map(
                    lambda s: s / partial["weight"], m)
            if per_client_noise:
                return m                    # noise already folded in
        if self.noise is None:
            return m
        key0 = jax.random.wrap_key_data(partial["seed"])
        noise = gen_noise(key0, self.template, self.noise)
        return jax.tree_util.tree_map(
            lambda nl, ml: nl * ml.astype(nl.dtype), noise, m)

    def uplink_stacked(self, scores: Pytree, noise_keys, mask_keys,
                       weights: jax.Array, *, probs: bool = False,
                       round_idx=None):
        """The WHOLE mask uplink, client sampling through server sum.

        ``scores`` is the client-stacked trained ``u`` (FedMRN: the mask
        is drawn against noise regenerated from ``noise_keys``) or, with
        ``probs=True``, the Bernoulli probabilities themselves (FedPM;
        ``noise_keys`` ignored).  Returns ``(stacked WireMsg, aggregate)``
        with the aggregate equal to ``self.aggregate(msg, weights)``.

        On the pallas backend this runs the fused ``kernels/mask_uplink``
        pass — sample → bitpack → count/weighted-sum staged through VMEM,
        no f32 mask tree and no unpacked bit tensor in HBM.  On ref it IS
        the staged legacy composition (``tree_sample_mask_stacked`` →
        ``encode_stacked`` → ``aggregate``), so CPU trajectories are
        bit-identical to the pre-fusion path.
        """
        backend = resolve_backend(self.backend)
        if backend != "pallas" or self.privacy is not None:
            # DP always takes the staged composition: the aggregate must
            # route through partial/finalize so the noise draw lands on
            # the merged counts exactly once (the sampled masks are
            # bitwise identical either way — the fused kernel is
            # oracle-tested against this path)
            if probs:
                masks = tree_bernoulli_stacked(scores, mask_keys)
            else:
                noise = jax.vmap(
                    lambda k: gen_noise(k, self.template, self.noise)
                )(noise_keys)
                masks = tree_sample_mask_stacked(scores, noise, mask_keys,
                                                 mode=self.mode)
            payload = {"mask": masks}
            if self.carries_seed:
                payload["seed"] = noise_keys
            msg = self.encode_stacked(payload)
            return msg, self.aggregate(msg, weights, round_idx=round_idx)

        noise = None
        if not probs:
            noise = jax.vmap(
                lambda k: gen_noise(k, self.template, self.noise)
            )(noise_keys)
        wn = weights / jnp.sum(weights) if self.normalize else weights
        per_client = self.noise is not None and not self.shared_noise
        up = tree_mask_uplink(scores, noise, mask_keys, wn, mode=self.mode,
                              probs=probs, wsum_values=per_client,
                              backend=backend)
        bufs = {"words": up.words}
        if self.carries_seed:
            bufs["seed"] = jax.random.key_data(noise_keys)
        msg = WireMsg(self.name, bufs)
        if per_client:
            # Eq. (5): the kernel's Σ_k w'_k G(s_k)⊙m_k partials ARE it
            return msg, tree_split_flat(up.wsum, self.template)
        if self.count_dtype is not None:
            counts = tree_split_flat(up.counts, self.template)
            m_avg = jax.tree_util.tree_map(
                lambda c: c.astype(self.count_dtype).astype(jnp.float32)
                * wn[0], counts)
        else:
            m_avg = tree_split_flat(up.wsum, self.template)
        if self.noise is None:
            return msg, m_avg
        noise0 = jax.tree_util.tree_map(lambda x: x[0], noise)
        return msg, jax.tree_util.tree_map(
            lambda nl, ml: nl * ml.astype(nl.dtype), noise0, m_avg)

    def aggregate_apply(self, stacked: WireMsg, weights: jax.Array,
                        params: Pytree, *, round_idx=None) -> Pytree:
        """Server decode + model update in one: equal (leaf by leaf) to
        ``mix_add(params, self.aggregate(stacked, weights))``.

        For the count-aggregatable integer formats (shared noise +
        ``count_dtype``) on the pallas backend this is ONE fused
        ``unpack_counts_apply`` kernel — aggregated words → popcounts →
        ``w + G(s)⊙(w'·Σm)`` without an unpacked bit tensor or a
        materialized count tree; every other configuration composes the
        existing ``aggregate`` with ``mix_add`` unchanged.
        """
        fused = (resolve_backend(self.backend) == "pallas"
                 and self.noise is not None and self.shared_noise
                 and self.count_dtype is not None
                 and self.privacy is None)
        if not fused:
            agg = self.aggregate(stacked, weights, round_idx=round_idx)
            return jax.tree_util.tree_map(mix_add, params, agg)
        words = stacked.buffers["words"]
        wn = weights / jnp.sum(weights) if self.normalize else weights
        key0 = jax.random.wrap_key_data(stacked.buffers["seed"])[0]
        noise = gen_noise(key0, self.template, self.noise)
        return tree_unpack_counts_apply(words, noise, params, wn[0],
                                        mode=self.mode,
                                        backend=self.backend)

    def template_payload(self, params: Pytree) -> Pytree:
        payload = {"mask": template_of(params)}
        if self.carries_seed:
            payload["seed"] = jax.random.key(0)
        return payload

    def _paper_bits(self, params: Pytree) -> int:
        return tree_num_params(params)          # 1 bpp, headers ignored


@dataclasses.dataclass(frozen=True, eq=False)
class SignCodec(UplinkCodec):
    """1-bit signs + one 32-bit L1 scale per leaf (the SIGNSGD format).

    ``payload = {"value": pytree}`` — encode IS the compression (scale =
    mean |leaf|, bit = value > 0), so routing a raw update through
    encode → aggregate reproduces deterministic signSGD.  Exactly-zero
    entries encode as sign −1: the 1-bit wire format cannot represent 0
    (the old in-body roundtrip kept ``sign(0) == 0``, a value no 1-bit
    uplink could actually transmit), so a parameter whose update is
    identically zero now receives −scale like any negative entry.
    """

    backend: Optional[str] = None

    def encode(self, payload: Pytree) -> WireMsg:
        leaves = jax.tree_util.tree_leaves(payload["value"])
        scale = jnp.stack(
            [jnp.mean(jnp.abs(l.astype(jnp.float32))) for l in leaves])
        return WireMsg(self.name, {
            "words": tree_pack(payload["value"], mode="signed",
                               backend=self.backend),
            "scale": scale})

    def encode_stacked(self, payloads: Pytree) -> WireMsg:
        leaves = jax.tree_util.tree_leaves(payloads["value"])
        scale = jnp.stack(
            [jnp.mean(jnp.abs(l.astype(jnp.float32)),
                      axis=tuple(range(1, l.ndim))) for l in leaves],
            axis=1)                               # (K, L)
        return WireMsg(self.name, {
            "words": tree_pack_stacked(payloads["value"], mode="signed",
                                       backend=self.backend),
            "scale": scale})

    def decode(self, msg: WireMsg) -> Pytree:
        signs = tree_unpack(msg.buffers["words"], self.template,
                            mode="signed", backend=self.backend)
        leaves, treedef = jax.tree_util.tree_flatten(signs)
        scale = msg.buffers["scale"]
        value = jax.tree_util.tree_unflatten(treedef, [
            scale[i] * l.astype(jnp.float32) for i, l in enumerate(leaves)])
        return {"value": value}

    def _wsum(self, stacked: WireMsg, w: jax.Array) -> Pytree:
        signs = tree_unpack_stacked(stacked.buffers["words"], self.template,
                                    mode="signed", backend=self.backend)
        scale = stacked.buffers["scale"]          # (K, L)
        leaves, treedef = jax.tree_util.tree_flatten(signs)
        # Σ_k w_k s_{k,l} m_{k,l} — fold the scale into the weights
        out = [jnp.tensordot(w * scale[:, i], l.astype(jnp.float32),
                             axes=1) for i, l in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def aggregate(self, stacked: WireMsg, weights: jax.Array) -> Pytree:
        return self._wsum(stacked, weights / jnp.sum(weights))

    def template_payload(self, params: Pytree) -> Pytree:
        return {"value": template_of(params)}

    def _paper_bits(self, params: Pytree) -> int:
        return tree_num_params(params)          # 1 bpp, scales ignored


@dataclasses.dataclass(frozen=True, eq=False)
class DenseCodec(UplinkCodec):
    """Float32 passthrough — the 32 bpp FedAvg wire format.

    One flat ``(P,)`` f32 buffer; also the transport for compressor
    families whose quantization runs in the round body (``record`` then
    reports the quantized cost the f32 buffer stands in for).
    """

    def encode(self, payload: Pytree) -> WireMsg:
        flat = jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32)
             for l in jax.tree_util.tree_leaves(payload["value"])])
        return WireMsg(self.name, {"values": flat})

    def encode_stacked(self, payloads: Pytree) -> WireMsg:
        leaves = jax.tree_util.tree_leaves(payloads["value"])
        K = jnp.shape(leaves[0])[0]
        flat = jnp.concatenate(
            [l.reshape(K, -1).astype(jnp.float32) for l in leaves], axis=1)
        return WireMsg(self.name, {"values": flat})

    def decode(self, msg: WireMsg) -> Pytree:
        split = tree_split_flat(msg.buffers["values"], self.template)
        return {"value": jax.tree_util.tree_map(
            lambda piece, leaf: piece.astype(leaf.dtype),
            split, self.template)}

    def _wsum(self, stacked: WireMsg, w: jax.Array) -> Pytree:
        return tree_split_flat(
            jnp.tensordot(w, stacked.buffers["values"], axes=1),
            self.template)

    def aggregate(self, stacked: WireMsg, weights: jax.Array) -> Pytree:
        # f32, like _weighted
        return self._wsum(stacked, weights / jnp.sum(weights))

    def template_payload(self, params: Pytree) -> Pytree:
        return {"value": template_of(params)}

    def _paper_bits(self, params: Pytree) -> int:
        return 32 * tree_num_params(params)


@dataclasses.dataclass(frozen=True, eq=False)
class QuantCodec(UplinkCodec):
    """Stochastic uniform quantization over a REAL integer wire buffer —
    the qsgd / terngrad formats (qsgd: ``levels = 2^b − 1``; terngrad:
    ``levels = 1``, i.e. ternary).

    ``payload = {"value": pytree, "key": client PRNG key}``.  Encode
    replicates the in-body compressor exactly — fold ``_KEY_SALT`` then
    the leaf index into the key, ``stochastic_quantize`` each leaf — and
    tight-packs the biased integer levels at ``⌈log2(2·levels+1)⌉`` bits
    each (fields straddle uint32 word boundaries) plus one f32 scale per
    leaf, so ``msg.bits`` measures the true integer wire cost (``record``
    stays None; the paper-style figure keeps the entropy-coded bpp).
    ``aggregate`` dequantizes and weight-sums; trajectories are
    bit-identical to the old f32 roundtrip because dequantization
    reproduces ``_qsgd_leaf`` / ``_terngrad_leaf`` values bit-for-bit.
    """

    levels: int = 3
    paper_bpp: float = 2.0

    needs_key = True

    def _layout(self):
        _, _, sizes, offsets = tree_flat_layout(self.template)
        return sizes, offsets

    @property
    def field_bits(self) -> int:
        """Tight field width: a biased level lives in [0, 2·levels]."""
        return max(1, (2 * self.levels).bit_length())

    def _field_pos(self, P: int):
        nb = self.field_bits
        b0 = jnp.arange(P, dtype=jnp.uint32) * nb
        w0 = (b0 >> 5).astype(jnp.int32)
        off = b0 & jnp.uint32(31)
        # left-shift count for the next word's piece; off == 0 means the
        # field sits wholly in word w0 (shift guarded to stay < 32)
        rem = jnp.where(off == 0, jnp.uint32(1), jnp.uint32(32) - off)
        return w0, off, rem

    def _pack_flat(self, q_flat: jax.Array) -> jax.Array:
        """(P,) signed levels → tight-packed uint32 words."""
        P = q_flat.shape[0]
        W = -(-(P * self.field_bits) // 32)
        v = (q_flat + self.levels).astype(jnp.uint32)
        w0, off, rem = self._field_pos(P)
        lo = v << off
        hi = jnp.where(off == 0, jnp.uint32(0), v >> rem)
        # disjoint bit ranges → scatter-adds cannot carry
        words = jnp.zeros((W + 1,), jnp.uint32)
        return words.at[w0].add(lo).at[w0 + 1].add(hi)[:W]

    def _unpack_flat(self, words: jax.Array) -> jax.Array:
        """Tight-packed words → (P,) signed integer levels (int32)."""
        P = sum(self._layout()[0])
        ext = jnp.concatenate([words, jnp.zeros((1,), jnp.uint32)])
        w0, off, rem = self._field_pos(P)
        part = (ext[w0] >> off) | jnp.where(
            off == 0, jnp.uint32(0), ext[w0 + 1] << rem)
        fmask = jnp.uint32((1 << self.field_bits) - 1)
        return (part & fmask).astype(jnp.int32) - self.levels

    def encode(self, payload: Pytree) -> WireMsg:
        kq = jax.random.fold_in(payload["key"], _KEY_SALT)
        leaves = jax.tree_util.tree_leaves(payload["value"])
        qs, scales = [], []
        for i, leaf in enumerate(leaves):
            q, s = stochastic_quantize(leaf, jax.random.fold_in(kq, i),
                                       levels=self.levels)
            qs.append(q.reshape(-1))
            scales.append(s)
        return WireMsg(self.name, {
            "words": self._pack_flat(jnp.concatenate(qs)),
            "scale": jnp.stack(scales)})

    def _dequant_flat(self, words: jax.Array, scale: jax.Array) -> jax.Array:
        """One client's buffers → the dequantized flat (P,) f32 update."""
        q = self._unpack_flat(words)
        sizes, offsets = self._layout()
        parts = [stochastic_dequantize(q[off:off + n], scale[i],
                                       levels=self.levels)
                 for i, (n, off) in enumerate(zip(sizes, offsets))]
        return jnp.concatenate(parts)

    def decode(self, msg: WireMsg) -> Pytree:
        flat = self._dequant_flat(msg.buffers["words"],
                                  msg.buffers["scale"])
        split = tree_split_flat(flat, self.template)
        return {"value": jax.tree_util.tree_map(
            lambda piece, leaf: piece.astype(leaf.dtype),
            split, self.template)}

    def _wsum(self, stacked: WireMsg, w: jax.Array) -> Pytree:
        dense = jax.vmap(self._dequant_flat)(stacked.buffers["words"],
                                             stacked.buffers["scale"])
        return tree_split_flat(jnp.tensordot(w, dense, axes=1),
                               self.template)

    def aggregate(self, stacked: WireMsg, weights: jax.Array) -> Pytree:
        return self._wsum(stacked, weights / jnp.sum(weights))

    def template_payload(self, params: Pytree) -> Pytree:
        return {"value": template_of(params), "key": jax.random.key(0)}

    def _paper_bits(self, params: Pytree) -> int:
        return int(self.paper_bpp * tree_num_params(params))


@dataclasses.dataclass(frozen=True, eq=False)
class SparseCodec(UplinkCodec):
    """Top-k values + int32 indices per client (top-k / FedSparsify).

    ``k = max(1, ceil(frac · n))`` PER LEAF (matching the compressors'
    per-leaf thresholding); indices are global flat positions, so one
    ``(Σk,)`` int32 + one ``(Σk,)`` f32 buffer form the message.
    """

    frac: float = 0.03

    def _layout(self):
        leaves, _, sizes, offsets = tree_flat_layout(self.template)
        ks = [max(1, int(math.ceil(self.frac * n))) for n in sizes]
        return leaves, sizes, ks, offsets

    def encode(self, payload: Pytree) -> WireMsg:
        leaves, _, ks, offsets = self._layout()
        vals = jax.tree_util.tree_leaves(payload["value"])
        idx_parts, val_parts = [], []
        for leaf, k, off in zip(vals, ks, offsets):
            flat = leaf.reshape(-1).astype(jnp.float32)
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            idx_parts.append(idx.astype(jnp.int32) + np.int32(off))
            val_parts.append(jnp.take(flat, idx))
        return WireMsg(self.name, {
            "indices": jnp.concatenate(idx_parts),
            "values": jnp.concatenate(val_parts)})

    def _decode_flat(self, indices: jax.Array, values: jax.Array):
        P = sum(self._layout()[1])
        return jnp.zeros((P,), jnp.float32).at[indices].set(values)

    def decode(self, msg: WireMsg) -> Pytree:
        flat = self._decode_flat(msg.buffers["indices"],
                                 msg.buffers["values"])
        split = tree_split_flat(flat, self.template)
        return {"value": jax.tree_util.tree_map(
            lambda piece, leaf: piece.astype(leaf.dtype),
            split, self.template)}

    def _wsum(self, stacked: WireMsg, w: jax.Array) -> Pytree:
        dense = jax.vmap(self._decode_flat)(stacked.buffers["indices"],
                                            stacked.buffers["values"])
        return tree_split_flat(jnp.tensordot(w, dense, axes=1),
                               self.template)

    def aggregate(self, stacked: WireMsg, weights: jax.Array) -> Pytree:
        return self._wsum(stacked, weights / jnp.sum(weights))

    def template_payload(self, params: Pytree) -> Pytree:
        return {"value": template_of(params)}

    def _paper_bits(self, params: Pytree) -> int:
        return 32 * sum(self._layout()[2])       # values only, no indices

