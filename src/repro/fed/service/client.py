"""The service client: HTTP transport + the per-slot worker loop.

:class:`ServiceClient` is a minimal stdlib ``urllib`` transport with
per-request timeouts and bounded exponential-backoff retries (transient
connection errors happen on loopback too — the coordinator thread may
still be binding when the first worker wakes).

:func:`run_worker` is one client seat of the federation: poll status
until the coordinator reaches a new round, pull + deserialize the
global model, look up this slot's client id in the round's published
schedule, run the algorithm's jitted local step (gather batches →
uplink encode, identical key derivations to the scan engine), and POST
the framed ``WireMsg``.  A slot listed in
``ServiceConfig.straggler_slots`` computes its uplink on time but
withholds the POST until the coordinator has moved past the round — the
message then lands one round late and exercises the async staleness
path with a deterministic lag of 1.
"""
from __future__ import annotations

import time
import urllib.error
import urllib.request
from typing import Any, Callable, Dict, Optional, Tuple

import json

import numpy as np

from . import serde
from .server import ServiceConfig


class ServiceError(RuntimeError):
    """A request failed after exhausting its retries."""


class ServiceClient:
    """Typed loopback transport over the coordinator's HTTP plane."""

    def __init__(self, base_url: str, *, timeout_s: float = 30.0,
                 retries: int = 3, backoff_s: float = 0.05):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s
        self.retries = retries
        self.backoff_s = backoff_s

    # ---- transport with retry/backoff ---------------------------------

    def _request(self, path: str, data: Optional[bytes] = None,
                 method: str = "GET") -> Tuple[int, bytes]:
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method,
            headers={"Content-Type": "application/octet-stream"}
            if data is not None else {})
        delay = self.backoff_s
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            try:
                with urllib.request.urlopen(
                        req, timeout=self.timeout_s) as resp:
                    return resp.status, resp.read()
            except urllib.error.HTTPError as e:
                # an HTTP status is an ANSWER (409 stale round, 410
                # done...), not a transport failure — never retried
                return e.code, e.read()
            except (urllib.error.URLError, ConnectionError,
                    TimeoutError, OSError) as e:
                last = e
                if attempt == self.retries:
                    break
                time.sleep(delay)
                delay *= 2.0
        raise ServiceError(
            f"{method} {path} failed after {self.retries + 1} attempts: "
            f"{last}")

    # ---- endpoints -----------------------------------------------------

    def status(self) -> Dict[str, Any]:
        code, body = self._request("/v1/status")
        if code != 200:
            raise ServiceError(f"status -> {code}: {body[:200]!r}")
        return json.loads(body)

    def metrics(self) -> Dict[str, Any]:
        code, body = self._request("/v1/metrics")
        if code != 200:
            raise ServiceError(f"metrics -> {code}: {body[:200]!r}")
        return json.loads(body)

    def get_model(self, params_template: Any,
                  state_template: Any) -> Tuple[Any, Any, Dict[str, Any]]:
        code, body = self._request("/v1/model")
        if code != 200:
            raise ServiceError(f"model -> {code}: {body[:200]!r}")
        tree, meta = serde.loads_tree(
            body, {"params": params_template, "state": state_template})
        return tree["params"], tree["state"], meta

    def post_uplink(self, round_idx: int, body: bytes) -> Dict[str, Any]:
        code, resp = self._request(f"/v1/round/{round_idx}/uplink",
                                   data=body, method="POST")
        out = json.loads(resp) if resp else {}
        out["http_status"] = code
        return out


# ---------------------------------------------------------------------------
# the worker loop (one federation seat)
# ---------------------------------------------------------------------------

def run_worker(slot: int, client: ServiceClient, service: ServiceConfig,
               *, params_template: Any, state_template: Any,
               client_step: Callable[[Any, Any, int, int, float, int],
                                     Tuple[Any, float, float]],
               weights_all: np.ndarray,
               local_steps: Any,
               valid: Optional[np.ndarray] = None,
               faults: Optional[Any] = None) -> Dict[str, int]:
    """Participate until the coordinator reports ``done``.

    ``client_step(w, state, round_idx, cid, weight, steps)`` is the
    runner's jitted local program returning ``(msg, agg_weight,
    last_loss)`` — a ``(WireMsg, float, float)``; framing happens here
    so the transport layer owns every byte that crosses the wire.

    ``valid`` is an optional ``(R, K)`` availability mask — a seat whose
    ``valid[r, slot]`` is 0 sits the round out entirely.  ``faults`` is
    an optional :class:`repro.fed.FaultPlan`; injected drops / delays /
    corrupt frames / crashes / hangs are exercised here, each tallied in
    the returned stats dict (keys: ``posted``, ``skipped``, ``dropped``,
    ``delayed``, ``corrupted``, ``crashed``, ``hung``).

    Every POST goes through ONE response handler: 200 counts, 409/410
    are expected races (stale/finished), anything else raises — the
    deferred straggler path included (it used to swallow errors and
    consult a stale status snapshot).
    """
    stats = {"posted": 0, "skipped": 0, "dropped": 0, "delayed": 0,
             "corrupted": 0, "crashed": 0, "hung": 0}

    def post_now(r_msg: int, body: bytes) -> int:
        resp = client.post_uplink(r_msg, body)
        code = resp["http_status"]
        if code == 200:
            stats["posted"] += 1
        elif code not in (409, 410):
            raise ServiceError(
                f"uplink round {r_msg} slot {slot} -> {resp}")
        return code

    # (ready_round, sent_round, body): the POST is withheld until the
    # coordinator reaches ready_round
    deferred: list = []
    last_round = -1
    while True:
        st = client.status()
        if st["done"]:
            # still-deferred messages have nowhere to land: the run is
            # over, drop them (conservation: R*K − lag losses)
            return stats
        r = st["round"]
        if deferred and r >= deferred[0][0]:
            ready = [d for d in deferred if r >= d[0]]
            deferred = [d for d in deferred if r < d[0]]
            for _, r_sent, body in ready:
                post_now(r_sent, body)
            # the POST may itself close rounds (or the run) — RE-FETCH
            # status instead of trusting the pre-POST snapshot
            st = client.status()
            if st["done"]:
                return stats
            r = st["round"]
        if r <= last_round:
            time.sleep(service.poll_s)
            continue
        if faults is not None and faults.crashes(r, slot):
            stats["crashed"] = 1
            return stats
        if faults is not None and faults.hangs(r, slot):
            # the hung-seat scenario: sleep well past the runner's join
            # timeout, then resume (the run usually finished without us)
            stats["hung"] += 1
            last_round = r
            time.sleep(faults.hang_sleep_s)
            continue
        if valid is not None and not valid[r][slot]:
            stats["skipped"] += 1
            last_round = r
            continue
        w, state, meta = client.get_model(params_template, state_template)
        if meta["round"] != r or meta["done"]:
            continue                   # raced a round close — re-pull
        cid = int(meta["cids"][slot])
        steps = (int(local_steps[cid])
                 if isinstance(local_steps, np.ndarray)
                 else int(local_steps))
        msg, agg_weight, loss = client_step(w, state, r, cid,
                                            float(weights_all[cid]),
                                            steps)
        body = serde.dumps_msg(msg, round=r, cid=cid,
                               weight=float(agg_weight),
                               loss=float(loss))
        last_round = r
        if faults is not None and faults.corrupts(r, slot):
            # truncate the frame mid-buffer: serde must refuse it and
            # the coordinator must answer 400, never crash
            code = client.post_uplink(r, body[:max(8, len(body) // 2)]
                                      )["http_status"]
            if code != 400:
                raise ServiceError(
                    f"corrupt frame round {r} slot {slot} was not "
                    f"refused (got {code})")
            stats["corrupted"] += 1
            continue
        if faults is not None and faults.drops(r, slot):
            stats["dropped"] += 1
            continue
        lag = faults.delay(r, slot) if faults is not None else 0
        if slot in service.straggler_slots:
            lag = max(lag, 1)
        if lag > 0:
            stats["delayed"] += 1
            deferred.append((r + lag, r, body))
            continue
        post_now(r, body)
