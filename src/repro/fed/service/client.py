"""The service client: HTTP transport + the per-slot worker loop.

:class:`ServiceClient` is a minimal stdlib ``urllib`` transport with
per-request timeouts and bounded exponential-backoff retries (transient
connection errors happen on loopback too — the coordinator thread may
still be binding when the first worker wakes).

:func:`run_worker` is one client seat of the federation: poll status
until the coordinator reaches a new round, pull + deserialize the
global model, look up this slot's client id in the round's published
schedule, run the algorithm's jitted local step (gather batches →
uplink encode, identical key derivations to the scan engine), and POST
the framed ``WireMsg``.  A slot listed in
``ServiceConfig.straggler_slots`` computes its uplink on time but
withholds the POST until the coordinator has moved past the round — the
message then lands one round late and exercises the async staleness
path with a deterministic lag of 1.
"""
from __future__ import annotations

import time
import urllib.error
import urllib.request
from typing import Any, Callable, Dict, Optional, Tuple

import json

import numpy as np

from . import serde
from .server import ServiceConfig


class ServiceError(RuntimeError):
    """A request failed after exhausting its retries."""


class ServiceClient:
    """Typed loopback transport over the coordinator's HTTP plane."""

    def __init__(self, base_url: str, *, timeout_s: float = 30.0,
                 retries: int = 3, backoff_s: float = 0.05):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s
        self.retries = retries
        self.backoff_s = backoff_s

    # ---- transport with retry/backoff ---------------------------------

    def _request(self, path: str, data: Optional[bytes] = None,
                 method: str = "GET") -> Tuple[int, bytes]:
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method,
            headers={"Content-Type": "application/octet-stream"}
            if data is not None else {})
        delay = self.backoff_s
        last: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            try:
                with urllib.request.urlopen(
                        req, timeout=self.timeout_s) as resp:
                    return resp.status, resp.read()
            except urllib.error.HTTPError as e:
                # an HTTP status is an ANSWER (409 stale round, 410
                # done...), not a transport failure — never retried
                return e.code, e.read()
            except (urllib.error.URLError, ConnectionError,
                    TimeoutError, OSError) as e:
                last = e
                if attempt == self.retries:
                    break
                time.sleep(delay)
                delay *= 2.0
        raise ServiceError(
            f"{method} {path} failed after {self.retries + 1} attempts: "
            f"{last}")

    # ---- endpoints -----------------------------------------------------

    def status(self) -> Dict[str, Any]:
        code, body = self._request("/v1/status")
        if code != 200:
            raise ServiceError(f"status -> {code}: {body[:200]!r}")
        return json.loads(body)

    def metrics(self) -> Dict[str, Any]:
        code, body = self._request("/v1/metrics")
        if code != 200:
            raise ServiceError(f"metrics -> {code}: {body[:200]!r}")
        return json.loads(body)

    def get_model(self, params_template: Any,
                  state_template: Any) -> Tuple[Any, Any, Dict[str, Any]]:
        code, body = self._request("/v1/model")
        if code != 200:
            raise ServiceError(f"model -> {code}: {body[:200]!r}")
        tree, meta = serde.loads_tree(
            body, {"params": params_template, "state": state_template})
        return tree["params"], tree["state"], meta

    def post_uplink(self, round_idx: int, body: bytes) -> Dict[str, Any]:
        code, resp = self._request(f"/v1/round/{round_idx}/uplink",
                                   data=body, method="POST")
        out = json.loads(resp) if resp else {}
        out["http_status"] = code
        return out


# ---------------------------------------------------------------------------
# the worker loop (one federation seat)
# ---------------------------------------------------------------------------

def run_worker(slot: int, client: ServiceClient, service: ServiceConfig,
               *, params_template: Any, state_template: Any,
               client_step: Callable[[Any, Any, int, int, float],
                                     Tuple[Any, float, float]],
               weights_all: np.ndarray) -> int:
    """Participate until the coordinator reports ``done``.

    ``client_step(w, state, round_idx, cid, weight)`` is the runner's
    jitted local program returning ``(msg_bytes_payload, agg_weight,
    last_loss)`` — actually ``(WireMsg, float, float)``; framing happens
    here so the transport layer owns every byte that crosses the wire.
    Returns the number of uplinks this worker POSTed.
    """
    posted = 0
    deferred: Optional[Tuple[int, bytes]] = None
    last_round = -1
    while True:
        st = client.status()
        if st["done"]:
            # a still-deferred straggler message has nowhere to land:
            # the run is over, drop it (conservation: R*K - lag losses)
            return posted
        r = st["round"]
        if deferred is not None and r > deferred[0]:
            resp = client.post_uplink(*deferred)
            deferred = None
            if resp["http_status"] == 200:
                posted += 1
            if resp.get("round", r) != r or st["done"]:
                continue
        if r <= last_round:
            time.sleep(service.poll_s)
            continue
        w, state, meta = client.get_model(params_template, state_template)
        if meta["round"] != r or meta["done"]:
            continue                   # raced a round close — re-pull
        cid = int(meta["cids"][slot])
        msg, agg_weight, loss = client_step(w, state, r, cid,
                                            float(weights_all[cid]))
        body = serde.dumps_msg(msg, round=r, cid=cid,
                               weight=float(agg_weight),
                               loss=float(loss))
        last_round = r
        if slot in service.straggler_slots:
            deferred = (r, body)
            continue
        resp = client.post_uplink(r, body)
        if resp["http_status"] == 200:
            posted += 1
        elif resp["http_status"] not in (409, 410):
            raise ServiceError(f"uplink round {r} slot {slot} -> "
                               f"{resp}")
