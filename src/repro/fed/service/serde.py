"""Deterministic binary framing for the FL coordinator wire (ISSUE 8).

One frame format carries everything that crosses the service boundary:

``magic "RWF1" | u32 meta_len | meta JSON (sorted keys, compact) |
u32 n_buffers | buffer*``

and each buffer (emitted in sorted-name order) is

``u16 name_len | name utf-8 | u8 dtype_len | numpy dtype.str |
u8 ndim | ndim x u32 dims | u64 data_len | raw C-order bytes``

All integers are little-endian.  The payload bytes are the arrays'
exact memory images, so a round-trip is bit-identical and the framed
payload size of a :class:`~repro.fed.codecs.WireMsg` equals
``msg.bits / 8`` — the measured on-wire cost IS the codec's claimed
cost, with the framing overhead (`len(frame) - payload`) accounted
separately.

Two client/server payload shapes ride the frame:

* ``dumps_msg`` / ``loads_msg`` — one ``WireMsg`` (the codec tag
  travels in the meta dict, buffers by name);
* ``dumps_tree`` / ``loads_tree`` — an arbitrary pytree (the global
  model + algorithm state on downlink), leaves named by their
  ``jax.tree_util.keystr`` path and rebuilt against a template so the
  receiver recovers the exact structure and dtypes.

Stdlib + numpy only — no pickle (unsafe across trust boundaries), no
third-party serializers.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, Tuple

import jax
import numpy as np

from ..codecs import WireMsg

MAGIC = b"RWF1"

_U16_MAX = 0xFFFF
_U32_MAX = 0xFFFFFFFF


def _require(cond: bool, what: str) -> None:
    if not cond:
        raise ValueError(f"serde: malformed frame — {what}")


def _to_ndarray(name: str, value: Any) -> np.ndarray:
    if hasattr(value, "dtype") and jax.dtypes.issubdtype(
            value.dtype, jax.dtypes.prng_key):
        raise TypeError(
            f"serde: buffer {name!r} is a PRNG key array — frame its "
            "jax.random.key_data(...) uint32 image instead")
    arr = np.ascontiguousarray(np.asarray(value))
    if arr.dtype == object:
        raise TypeError(f"serde: buffer {name!r} is not a numeric array")
    return arr


def payload_bits(buffers: Dict[str, Any]) -> int:
    """Summed raw-array bits — the frame's payload (sans framing)."""
    return sum(int(_to_ndarray(k, v).nbytes) * 8 for k, v in buffers.items())


# ---------------------------------------------------------------------------
# the frame
# ---------------------------------------------------------------------------

def pack_frame(meta: Dict[str, Any], buffers: Dict[str, Any]) -> bytes:
    """Frame ``meta`` (JSON-able dict) + named arrays into bytes.

    Buffers are written in sorted-name order, so equal inputs produce
    byte-identical frames regardless of dict insertion order.
    """
    mb = json.dumps(meta, sort_keys=True,
                    separators=(",", ":")).encode("utf-8")
    parts = [MAGIC, struct.pack("<I", len(mb)), mb,
             struct.pack("<I", len(buffers))]
    for name in sorted(buffers):
        arr = _to_ndarray(name, buffers[name])
        nb = name.encode("utf-8")
        ds = arr.dtype.str.encode("ascii")
        if len(nb) > _U16_MAX or len(ds) > 255 or arr.ndim > 255:
            raise ValueError(f"serde: buffer {name!r} exceeds frame limits")
        if any(d > _U32_MAX for d in arr.shape):
            raise ValueError(f"serde: buffer {name!r} dim exceeds u32")
        parts.append(struct.pack("<H", len(nb)))
        parts.append(nb)
        parts.append(struct.pack("<B", len(ds)))
        parts.append(ds)
        parts.append(struct.pack("<B", arr.ndim))
        if arr.ndim:
            parts.append(struct.pack(f"<{arr.ndim}I", *arr.shape))
        parts.append(struct.pack("<Q", arr.nbytes))
        parts.append(arr.tobytes(order="C"))
    return b"".join(parts)


def unpack_frame(data: bytes) -> Tuple[Dict[str, Any],
                                       Dict[str, np.ndarray]]:
    """Inverse of :func:`pack_frame`; bit-exact array recovery."""
    _require(data[:4] == MAGIC, f"bad magic {data[:4]!r}")
    off = 4

    def take(n: int) -> bytes:
        nonlocal off
        _require(off + n <= len(data), "truncated frame")
        out = data[off:off + n]
        off += n
        return out

    (meta_len,) = struct.unpack("<I", take(4))
    meta = json.loads(take(meta_len).decode("utf-8"))
    (n_bufs,) = struct.unpack("<I", take(4))
    buffers: Dict[str, np.ndarray] = {}
    for _ in range(n_bufs):
        (name_len,) = struct.unpack("<H", take(2))
        name = take(name_len).decode("utf-8")
        (dtype_len,) = struct.unpack("<B", take(1))
        dtype = np.dtype(take(dtype_len).decode("ascii"))
        (ndim,) = struct.unpack("<B", take(1))
        shape = struct.unpack(f"<{ndim}I", take(4 * ndim)) if ndim else ()
        (nbytes,) = struct.unpack("<Q", take(8))
        expect = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        _require(nbytes == expect,
                 f"buffer {name!r} size/shape mismatch")
        arr = np.frombuffer(take(nbytes), dtype=dtype).reshape(shape)
        _require(name not in buffers, f"duplicate buffer {name!r}")
        buffers[name] = arr
    _require(off == len(data), f"{len(data) - off} trailing bytes")
    return meta, buffers


def framing_bits(frame: bytes, buffers: Dict[str, Any]) -> int:
    """Frame bytes NOT attributable to array payload, in bits."""
    return len(frame) * 8 - payload_bits(buffers)


# ---------------------------------------------------------------------------
# WireMsg <-> bytes
# ---------------------------------------------------------------------------

def dumps_msg(msg: WireMsg, **meta: Any) -> bytes:
    """Serialize one ``WireMsg``; extra keyword meta rides the frame
    (round index, client id, aggregation weight, last local loss)."""
    if "codec" in meta:
        raise ValueError("serde: 'codec' meta key is reserved")
    return pack_frame(dict(meta, codec=msg.codec), msg.buffers)


def loads_msg(data: bytes) -> Tuple[WireMsg, Dict[str, Any]]:
    meta, buffers = unpack_frame(data)
    _require("codec" in meta, "WireMsg frame missing 'codec' meta")
    meta = dict(meta)
    return WireMsg(meta.pop("codec"), dict(buffers)), meta


# ---------------------------------------------------------------------------
# pytree <-> bytes (downlink model + state)
# ---------------------------------------------------------------------------

def _tree_buffers(tree: Any) -> Dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}


def tree_payload_bits(tree: Any) -> int:
    """Raw bits the tree's leaves occupy inside a frame."""
    return payload_bits(_tree_buffers(tree))


def dumps_tree(tree: Any, **meta: Any) -> bytes:
    """Serialize any pytree of arrays; leaf names are keystr paths."""
    return pack_frame(dict(meta), _tree_buffers(tree))


def loads_tree(data: bytes, template: Any) -> Tuple[Any, Dict[str, Any]]:
    """Rebuild a pytree with ``template``'s structure from a frame.

    The sender and receiver derive leaf names from the SAME structure,
    so the name set must match exactly — a mismatch means the two sides
    disagree about the model and is an error, not a best-effort merge.
    """
    meta, buffers = unpack_frame(data)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    names = [jax.tree_util.keystr(path) for path, _ in paths]
    missing = [n for n in names if n not in buffers]
    extra = sorted(set(buffers) - set(names))
    _require(not missing and not extra,
             f"tree/template mismatch (missing={missing}, extra={extra})")
    leaves = []
    for name, (_, tmpl) in zip(names, paths):
        arr = buffers[name]
        _require(arr.dtype == np.dtype(tmpl.dtype)
                 and arr.shape == tuple(tmpl.shape),
                 f"leaf {name!r}: got {arr.dtype}{arr.shape}, template "
                 f"{np.dtype(tmpl.dtype)}{tuple(tmpl.shape)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
