"""The FL coordinator: rounds, pooling, aggregation, HTTP plane.

:class:`Coordinator` owns the global model and the round counter and
aggregates decoded uplinks through the codec's hierarchical partial
protocol (``partial_aggregate`` / ``merge_partials`` /
``finalize_partial`` — PR 7), so the server never materializes a
per-client dense update.  The HTTP layer (:func:`make_http_server`,
stdlib ``http.server`` on a loopback ``ThreadingHTTPServer``) is a thin
byte shuttle over it:

========================  =================================================
``GET  /v1/model``         current round's frame: global params +
                           algorithm state + meta (round, seed, this
                           round's client schedule, done flag)
``POST /v1/round/{r}/uplink``  one framed ``WireMsg`` (+ cid/weight/loss
                           meta); 409 on a round the server won't take
``GET  /v1/status``        tiny JSON: round, pool depth, done
``GET  /v1/metrics``       full JSON metrics incl. measured wire bytes
========================  =================================================

Round semantics
---------------

* **sync** — a barrier: the round closes when all K scheduled clients'
  uplinks for the CURRENT round have landed; an uplink tagged with any
  other round is refused (409), so the pool always aggregates exactly
  the scan engine's cohort and trajectories match to 1e-6.
* **async** — no barrier: an uplink for ANY round ``r' <= r`` is pooled
  and the round closes once ``min_fresh`` current-round uplinks have
  landed.  At close every pooled message is weighted by
  ``client_weight * staleness_beta ** (r - r')`` — stale gradients decay
  geometrically (weight proportional to beta^lag), folded into the same
  per-client weight vector the codec already takes.

Stale messages were encoded against an OLDER round's model and — for
the shared-noise mask formats — an older round's noise seed, so the
pool is aggregated per sending round: one partial chain per distinct
``r'`` (each finalized with its own seed), then combined across groups
by weight mass (or summed for non-normalizing codecs such as fedpm's
count aggregate).  With a single group this reduces to exactly the
synchronous path.
"""
from __future__ import annotations

import dataclasses
import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..codecs import MaskCodec, WireMsg
from . import serde


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of one service run (transport + round semantics only —
    everything the jitted programs depend on lives in ``FLConfig``, so
    one compiled runner serves any ``ServiceConfig``)."""

    mode: str = "sync"                  # "sync" | "async"
    staleness_beta: float = 0.5         # async: stale weight = beta**lag
    min_fresh: Optional[int] = None     # async: fresh uplinks closing a
                                        # round (default K - #stragglers)
    straggler_slots: Tuple[int, ...] = ()   # async: worker slots that
                                        # defer their POST one round
    quorum: Optional[int] = None        # sync: uplinks that close a round
                                        # (default: every expected client)
    host: str = "127.0.0.1"
    port: int = 0                       # 0 = ephemeral loopback port
    timeout_s: float = 30.0             # per-request client timeout
    retries: int = 3                    # client retry attempts
    backoff_s: float = 0.05             # first retry delay (doubles)
    poll_s: float = 0.002               # client round-poll interval
    run_timeout_s: Optional[float] = 600.0  # whole-run deadline; a run
                                        # that cannot finish RAISES
                                        # instead of waiting forever
    faults: Optional[Any] = None        # a repro.fed.FaultPlan to inject
    allow_hung_workers: bool = False    # record hung seats in the report
                                        # instead of raising

    def validate(self) -> None:
        if self.mode not in ("sync", "async"):
            raise ValueError(f"ServiceConfig.mode {self.mode!r} is not "
                             "'sync' or 'async'")
        if not 0.0 < self.staleness_beta <= 1.0:
            raise ValueError("staleness_beta must be in (0, 1]")
        if self.mode == "sync" and self.straggler_slots:
            raise ValueError("straggler_slots requires mode='async'")
        if self.quorum is not None:
            if self.mode == "async":
                raise ValueError(
                    "quorum is the sync barrier knob — async rounds "
                    "close on min_fresh")
            if self.quorum < 1:
                raise ValueError(f"quorum must be >= 1, got {self.quorum}")
        if self.run_timeout_s is not None and self.run_timeout_s <= 0:
            raise ValueError("run_timeout_s must be positive (or None)")


@dataclasses.dataclass
class _PoolEntry:
    cid: int
    msg_round: int
    msg: WireMsg
    weight: float              # the client's aggregation weight
    loss: float                # last local step's loss (metrics only)
    wire_bits: float           # codec.round_bits of this one message


class Coordinator:
    """Round state machine; thread-safe, transport-agnostic.

    The jitted callables come from the runner (built once per
    experiment): ``partial_fn(msg, weights)``, ``merge_fn(a, b)``,
    ``finalize_fn(partial)``, ``apply_fn(seed, w, state, agg, r)`` and
    optionally ``eval_fn(w)``.  Tests drive a ``Coordinator`` directly
    (scripted arrival orders make staleness deterministic); the HTTP
    layer only shuttles bytes into :meth:`handle_uplink`.
    """

    def __init__(self, *, codec, partial_fn, merge_fn, finalize_fn,
                 apply_fn, eval_fn=None, eval_rounds=(), params, state,
                 schedule: np.ndarray, seed: int, service: ServiceConfig,
                 algorithm: str = "",
                 expected: Optional[np.ndarray] = None,
                 num_clients: Optional[int] = None):
        service.validate()
        if service.mode == "async" and isinstance(codec, MaskCodec) \
                and codec.count_dtype is not None:
            raise ValueError(
                "async staleness weighting needs f32 per-client weights "
                "— integer count aggregation (count_dtype) cannot carry "
                "beta**lag scales")
        if service.mode == "async" \
                and getattr(codec, "privacy", None) is not None:
            raise ValueError(
                "async rounds cannot run under privacy=: the DP release "
                "is one noise draw on the round's merged integer counts, "
                "but async pools mix sending rounds with beta**lag f32 "
                "scales — run privacy experiments in mode='sync'")
        self.codec = codec
        self.service = service
        self.algorithm = algorithm
        self.num_clients = num_clients
        self._partial = partial_fn
        self._merge = merge_fn
        self._finalize = finalize_fn
        self._apply = apply_fn
        self._eval = eval_fn
        self._eval_rounds = set(eval_rounds)
        self.schedule = np.asarray(schedule, np.int32)
        self.rounds, self.clients_per_round = self.schedule.shape
        self.seed = int(seed)
        self._seed_dev = jnp.int32(seed)
        self.round = 0
        self.done = False
        self.w = params
        self.state = state
        self.dispatches = 0
        self._cv = threading.Condition()
        self._pool: List[_PoolEntry] = []
        fresh_needed = self.clients_per_round
        if service.mode == "async":
            fresh_needed = (service.min_fresh if service.min_fresh
                            is not None else self.clients_per_round
                            - len(service.straggler_slots))
        elif service.quorum is not None:
            if service.quorum > self.clients_per_round:
                raise ValueError(
                    f"quorum={service.quorum} exceeds K="
                    f"{self.clients_per_round}")
            fresh_needed = service.quorum
        if not 0 < fresh_needed <= self.clients_per_round:
            raise ValueError(
                f"min_fresh={fresh_needed} must be in 1..K="
                f"{self.clients_per_round}")
        self._fresh_needed = fresh_needed
        # per-round close thresholds: an availability trace lowers the
        # number of clients a round can ever hear from, so the barrier /
        # min_fresh caps at the expected survivor count
        if expected is None:
            self.expected = np.full((self.rounds,), self.clients_per_round,
                                    np.int64)
        else:
            self.expected = np.asarray(expected, np.int64)
            if self.expected.shape != (self.rounds,):
                raise ValueError(
                    f"expected must be ({self.rounds},), got "
                    f"{self.expected.shape}")
            if (self.expected < 1).any():
                raise ValueError(
                    "every round needs at least one expected client — "
                    "lower dropout or enable avail_resample")
        self._needed = np.minimum(self.expected, fresh_needed)
        # metrics (scan layout) + wire accounting
        R = self.rounds
        self.loss = np.full((R,), np.nan, np.float32)
        self.acc = np.full((R,), np.nan, np.float32)
        self.uplink_bits = np.zeros((R,), np.float32)
        self.staleness_log: List[List[Dict[str, Any]]] = [[] for _ in
                                                          range(R)]
        self.participation = np.zeros((R,), np.int64)
        self.n_uplinks = 0
        self.uplink_payload_bits = 0
        self.uplink_framing_bits = 0
        self.downlink_requests = 0
        self.downlink_bits_served = 0
        # every non-200 uplink answer, by reason — the fault-accounting
        # tests balance these against the injected plan
        self.rejected: Dict[str, int] = {"bad_frame": 0, "stale": 0,
                                         "future": 0, "done": 0}
        self._publish()

    # ---- downlink ------------------------------------------------------

    def _publish(self) -> None:
        """(Re)serialize the model blob this round serves."""
        r = min(self.round, self.rounds - 1)
        meta = {"round": self.round, "rounds": self.rounds,
                "seed": self.seed, "algorithm": self.algorithm,
                "done": self.done,
                "cids": [int(c) for c in self.schedule[r]]}
        blob = serde.dumps_tree({"params": self.w, "state": self.state},
                                **meta)
        self.model_blob = blob
        self.downlink_params_bits = serde.tree_payload_bits(self.w)
        self.downlink_total_bits = len(blob) * 8

    def get_model(self) -> bytes:
        with self._cv:
            self.downlink_requests += 1
            self.downlink_bits_served += self.downlink_total_bits
            return self.model_blob

    # ---- uplink --------------------------------------------------------

    def handle_uplink(self, r: int, body: bytes) -> Tuple[int,
                                                          Dict[str, Any]]:
        """Decode + pool one framed uplink; returns (http_status, json)."""
        try:
            msg, meta = serde.loads_msg(body)
        except (ValueError, TypeError, KeyError) as e:
            with self._cv:
                self.rejected["bad_frame"] += 1
            return 400, {"error": f"bad frame: {e}"}
        if int(meta.get("round", -1)) != r:
            with self._cv:
                self.rejected["bad_frame"] += 1
            return 400, {"error": "frame meta round does not match URL"}
        payload = msg.bits
        entry = _PoolEntry(
            cid=int(meta.get("cid", -1)), msg_round=r, msg=msg,
            weight=float(meta.get("weight", 1.0)),
            loss=float(meta.get("loss", np.nan)),
            wire_bits=self._entry_bits(msg))
        with self._cv:
            if self.done:
                self.rejected["done"] += 1
                return 410, {"error": "experiment finished"}
            if r > self.round:
                self.rejected["future"] += 1
                return 409, {"error": "future round", "round": self.round}
            if self.service.mode == "sync" and r < self.round:
                self.rejected["stale"] += 1
                return 409, {"error": "stale round (sync barrier)",
                             "round": self.round}
            self.n_uplinks += 1
            self.uplink_payload_bits += payload
            self.uplink_framing_bits += len(body) * 8 - payload
            self._pool.append(entry)
            if self._round_complete():
                self._close_round()
                self._cv.notify_all()
            return 200, {"accepted": True, "round": self.round}

    def _entry_bits(self, msg: WireMsg) -> float:
        # clients post stacked messages with a unit leading axis, so
        # round_bits counts K=1 (honouring record-override codecs)
        return float(self.codec.round_bits(msg))

    def _round_complete(self) -> bool:
        fresh = sum(1 for e in self._pool if e.msg_round == self.round)
        return fresh >= self._needed[self.round]

    # ---- round close ---------------------------------------------------

    def _stack(self, entries: List[_PoolEntry]) -> WireMsg:
        # each client posts a stacked message with a UNIT leading axis
        # (uplink_fn runs at K=1 on the client), so a pool concatenates
        keys = sorted(entries[0].msg.buffers)
        bufs = {k: jnp.concatenate([jnp.asarray(e.msg.buffers[k])
                                    for e in entries], axis=0)
                for k in keys}
        return WireMsg(entries[0].msg.codec, bufs)

    def _close_round(self) -> None:
        """Aggregate the pool and step the global model (lock held)."""
        r = self.round
        beta = self.service.staleness_beta
        entries = sorted(self._pool, key=lambda e: (e.msg_round, e.cid))
        self._pool = []
        # group by the round each message was computed against: shared
        # noise / seeds are per-round, so each group finalizes with its
        # own seed before groups combine by weight mass
        groups: List[List[_PoolEntry]] = []
        for e in entries:
            if groups and groups[-1][0].msg_round == e.msg_round:
                groups[-1].append(e)
            else:
                groups.append([e])
        updates, masses = [], []
        for group in groups:
            lag = r - group[0].msg_round
            scale = beta ** lag
            # one singleton partial per pooled message (K=1 — a single
            # compiled shape however the pool splits), tree-merged, one
            # finalize per sending round (its own shared-noise seed)
            part = None
            for e in group:
                w = jnp.asarray([e.weight * scale], jnp.float32)
                p = self._partial(self._stack([e]), w,
                                  jnp.int32(group[0].msg_round))
                part = p if part is None else self._merge(part, p)
                self.dispatches += 1
                self.staleness_log[r].append(
                    {"cid": e.cid, "round_sent": e.msg_round, "lag": lag,
                     "scale": scale})
            upd = self._finalize(part)
            self.dispatches += 1
            updates.append(upd)
            masses.append(float(np.sum([e.weight * scale
                                        for e in group])))
        if len(updates) == 1:
            agg = updates[0]
        elif getattr(self.codec, "normalize", True):
            total = sum(masses)
            agg = jax.tree_util.tree_map(
                lambda *us: sum(m / total * u
                                for m, u in zip(masses, us)), *updates)
        else:
            agg = jax.tree_util.tree_map(lambda *us: sum(us), *updates)
        # the pool's total weight mass rides along for bodies that need
        # the survivor count (fedpm's Beta smoothing)
        self.w, self.state = self._apply(self._seed_dev, self.w,
                                         self.state, agg, jnp.int32(r),
                                         jnp.float32(sum(masses)))
        self.dispatches += 1
        self.participation[r] = len(entries)
        self.loss[r] = np.nanmean([e.loss for e in entries])
        self.uplink_bits[r] = sum(e.wire_bits for e in entries)
        if self._eval is not None and r in self._eval_rounds:
            self.acc[r] = float(self._eval(self.w))
            self.dispatches += 1
        self.round += 1
        if self.round >= self.rounds:
            self.done = True
        self._publish()

    # ---- monitoring ----------------------------------------------------

    def status(self) -> Dict[str, Any]:
        with self._cv:
            return {"round": self.round, "rounds": self.rounds,
                    "done": self.done, "mode": self.service.mode,
                    "pool": len(self._pool)}

    def _dp_metrics(self) -> Dict[str, Any]:
        """Cumulative (ε, δ) after each CLOSED round (lock held).

        ``dp_epsilon_round[t]`` is the budget spent through round ``t``,
        accounted at the participation the coordinator actually
        aggregated (quorum-degraded rounds spend less); unclosed rounds
        are ``None``.  Both fields are ``None`` when the codec carries
        no privacy mechanism.
        """
        privacy = getattr(self.codec, "privacy", None)
        if privacy is None or self.num_clients is None:
            return {"dp_epsilon_round": None, "dp_delta": None}
        from ..privacy import round_epsilons
        from ...core import tree_num_params
        closed = min(self.round, self.rounds)
        eps = round_epsilons(privacy, [int(x) for x in
                                       self.participation[:closed]],
                             self.num_clients, self.codec.mode,
                             tree_num_params(self.w))
        col: List[Optional[float]] = [float(e) for e in eps]
        col += [None] * (self.rounds - closed)
        return {"dp_epsilon_round": col, "dp_delta": float(privacy.delta)}

    def metrics(self) -> Dict[str, Any]:
        with self._cv:
            return {
                **self._dp_metrics(),
                "round": self.round, "done": self.done,
                "mode": self.service.mode,
                "algorithm": self.algorithm,
                "n_uplinks": self.n_uplinks,
                "uplink_payload_bits": self.uplink_payload_bits,
                "uplink_framing_bits": self.uplink_framing_bits,
                "downlink_requests": self.downlink_requests,
                "downlink_bits_served": self.downlink_bits_served,
                "downlink_params_bits": self.downlink_params_bits,
                "downlink_total_bits": self.downlink_total_bits,
                "loss": [float(x) for x in self.loss],
                "acc": [float(x) for x in self.acc],
                "uplink_bits_round": [float(x) for x in self.uplink_bits],
                "participation_round": [int(x)
                                        for x in self.participation],
                "expected_round": [int(x) for x in self.expected],
                "rejected": dict(self.rejected),
                "staleness": self.staleness_log,
            }

    def wait_done(self, timeout: Optional[float] = None) -> bool:
        with self._cv:
            return self._cv.wait_for(lambda: self.done, timeout=timeout)


# ---------------------------------------------------------------------------
# the HTTP plane
# ---------------------------------------------------------------------------

_UPLINK_RE = re.compile(r"^/v1/round/(\d+)/uplink$")


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    @property
    def coord(self) -> Coordinator:
        return self.server.coordinator          # type: ignore[attr-defined]

    def log_message(self, fmt, *args):          # silence per-request spam
        pass

    def _send(self, code: int, body: bytes,
              ctype: str = "application/json") -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, obj: Dict[str, Any]) -> None:
        self._send(code, json.dumps(obj).encode("utf-8"))

    def do_GET(self) -> None:
        if self.path == "/v1/model":
            self._send(200, self.coord.get_model(),
                       ctype="application/octet-stream")
        elif self.path == "/v1/status":
            self._send_json(200, self.coord.status())
        elif self.path == "/v1/metrics":
            self._send_json(200, self.coord.metrics())
        else:
            self._send_json(404, {"error": f"no route {self.path}"})

    def do_POST(self) -> None:
        m = _UPLINK_RE.match(self.path)
        if not m:
            self._send_json(404, {"error": f"no route {self.path}"})
            return
        length = int(self.headers.get("Content-Length", 0))
        body = self.rfile.read(length)
        code, obj = self.coord.handle_uplink(int(m.group(1)), body)
        self._send_json(code, obj)


def make_http_server(coord: Coordinator) -> ThreadingHTTPServer:
    """Bind the coordinator on loopback; caller runs ``serve_forever``
    in a thread and ``shutdown()``s it when the run finishes."""
    httpd = ThreadingHTTPServer((coord.service.host, coord.service.port),
                                _Handler)
    httpd.daemon_threads = True
    httpd.coordinator = coord                   # type: ignore[attr-defined]
    return httpd
