"""The wire-true FL coordinator (ISSUE 8, ROADMAP direction 1).

``WireMsg`` over a REAL process boundary: a stdlib-HTTP coordinator
(:mod:`.server`) aggregates framed uplinks through the codec partial
protocol, client seats (:mod:`.client`) pull the serialized model and
POST encoded updates, and :mod:`.serde` frames every byte that crosses
the socket — deterministically and bit-exactly, so measured
bytes-on-wire equal ``WireMsg.bits / 8``.  ``Experiment.run(
engine="service")`` drives it over loopback (:mod:`.runner`); see
``README.md`` here for endpoints, frame layout, and the async
staleness-weighted round semantics.
"""
from .client import ServiceClient, ServiceError, run_worker
from .runner import ServiceReport, ServiceRunner, make_service_engine
from .serde import (dumps_msg, dumps_tree, framing_bits, loads_msg,
                    loads_tree, pack_frame, payload_bits,
                    tree_payload_bits, unpack_frame)
from .server import Coordinator, ServiceConfig, make_http_server

__all__ = [
    "Coordinator", "ServiceClient", "ServiceConfig", "ServiceError",
    "ServiceReport", "ServiceRunner", "dumps_msg", "dumps_tree",
    "framing_bits", "loads_msg", "loads_tree", "make_http_server",
    "make_service_engine", "pack_frame", "payload_bits", "run_worker",
    "tree_payload_bits", "unpack_frame",
]
