"""``engine="service"``: coordinator + K loopback client threads.

:class:`ServiceRunner` is the cohort tier's natural K=1 degenerate run
over a REAL process boundary: every client seat runs the algorithm's
``make_cohort_body`` uplink at cohort size 1 (identical per-client key
derivations to the scan engine), frames the resulting ``WireMsg``
through :mod:`repro.fed.service.serde`, and POSTs it over loopback
HTTP; the coordinator aggregates through the codec partial protocol and
steps the global model.  Synchronous trajectories therefore match
scan/cohort to 1e-6 at a fixed seed while every reported uplink bit has
actually crossed a socket.

The jitted pieces are compiled ONCE per experiment (the runner is
cached on the :class:`~repro.fed.api.Experiment` like the cohort
runner) and shared by all worker threads; ``ServiceConfig`` only
changes transport/round semantics, never compiled programs.
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.comm import CommRecord
from ..algorithms import FLConfig, get_algorithm
from ..codecs import MaskCodec
from ..engine import eval_round_indices, make_client_schedule
from . import serde
from .client import ServiceClient, ServiceError, run_worker
from .server import Coordinator, ServiceConfig, make_http_server

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServiceReport:
    """Measured wire accounting of one service run.

    ``comm`` is the codec's :class:`CommRecord` with ``downlink_bits``
    REPLACED by the measured per-request params payload (satellite:
    downlink is no longer the analytic ``32 * P`` claim — though the two
    agree exactly, which ``tests/test_service.py`` asserts).  Framing
    and non-params state ride ``downlink_overhead_bits``.
    """

    mode: str
    comm: CommRecord
    n_uplinks: int
    uplink_payload_bits: int        # Σ framed WireMsg buffer bits
    uplink_framing_bits: int        # Σ frame bytes beyond the buffers
    downlink_requests: int
    downlink_params_bits: int       # measured params payload per request
    downlink_total_bits: int        # whole model frame per request
    downlink_overhead_bits: int     # frame + algorithm state, per request
    staleness: Tuple[Tuple[Dict[str, Any], ...], ...]
    base_url: str
    # ---- distributed-DP accounting (fed/privacy) -----------------------
    dp_epsilon: Tuple[float, ...] = ()    # cumulative ε after each round
    #   at the participation actually aggregated; all-inf without privacy
    dp_delta: float = 0.0
    # ---- availability / fault accounting (PR 9) ------------------------
    participation: Tuple[int, ...] = ()   # uplinks aggregated per round
    expected: Tuple[int, ...] = ()        # survivors the trace promised
    rejected: Mapping[str, int] = dataclasses.field(default_factory=dict)
    #   coordinator-side non-200 answers by reason (bad_frame/stale/...)
    client_faults: Mapping[str, int] = dataclasses.field(
        default_factory=dict)             # summed worker stats (dropped,
    #   delayed, corrupted, crashed, hung, skipped, posted)
    hung_workers: int = 0                 # seats still alive after join


class ServiceRunner:
    """Build once per experiment; ``run()`` serves one federation."""

    def __init__(self, loss_fn, cfg: FLConfig, params: Pytree, data, *,
                 eval_program=None, eval_every: int = 1,
                 client_weights=None):
        from ...data.federated import FederatedDataset
        if not isinstance(data, FederatedDataset):
            raise ValueError(
                "engine='service' needs a FederatedDataset (client "
                "seats gather their batches from the shared population)")
        algo = get_algorithm(cfg.algorithm)
        if algo.make_cohort_body is None:
            raise ValueError(
                f"algorithm {cfg.algorithm!r} declares no cohort body "
                "(Algorithm.make_cohort_body) — the service client "
                "needs the uplink/apply split; run it on engine='scan'")
        cw = None if client_weights is None else list(client_weights)
        if cw is not None and len(cw) != cfg.num_clients:
            raise ValueError(
                f"client_weights has {len(cw)} entries, "
                f"cfg expects {cfg.num_clients}")
        codec, uplink_fn, apply_fn = algo.make_cohort_body(
            loss_fn, cfg, params)
        # NO count_dtype auto-upgrade here (unlike the cohort engine):
        # staleness weighting needs f32 per-client weights on every path
        self.cfg = cfg
        self.data = data
        self.codec = codec
        self._params = params
        self._state0 = algo.init_state(cfg, params)
        self._weights_all = np.asarray(
            [1.0] * cfg.num_clients if cw is None else cw, np.float32)
        self._eval = None if eval_program is None else jax.jit(eval_program)
        self._eval_every = eval_every
        self.report: Optional[ServiceReport] = None

        batch = cfg.batch_size

        # ``steps`` is static (batch shapes) — per-client heterogeneous
        # local_steps from an AvailabilityTrace compile once per distinct
        # value, warmed up before the worker threads race to call it
        @partial(jax.jit, static_argnames=("steps",))
        def client_step(seed, w, state, r, cid, weight, *,
                        steps=int(cfg.local_steps)):
            cids = jnp.reshape(cid, (1,)).astype(jnp.int32)
            wts = jnp.reshape(weight, (1,)).astype(jnp.float32)
            batches = data.gather_batches(r, cids, steps=steps,
                                          batch=batch)
            msg, agg_w, losses = uplink_fn(seed, w, state, batches, cids,
                                           wts, r)
            return msg, agg_w[0], losses[0, -1]

        @jax.jit
        def partial_fn(msg, weights, r):
            return codec.partial_aggregate(msg, weights, round_idx=r)

        @jax.jit
        def apply_fn_j(seed, w, state, agg, r, n_valid):
            return apply_fn(seed, w, state, agg, r, n_valid)

        self._client_step = client_step
        self._partial = partial_fn
        self._merge = jax.jit(codec.merge_partials)
        self._finalize = jax.jit(codec.finalize_partial)
        self._apply = apply_fn_j

    # ---- one federation -------------------------------------------------

    def run(self, *, seed: Optional[int] = None,
            schedule: Optional[np.ndarray] = None,
            service: Optional[ServiceConfig] = None,
            valid: Optional[np.ndarray] = None,
            local_steps: Optional[np.ndarray] = None
            ) -> Tuple[Dict[str, np.ndarray], np.ndarray, int]:
        """Serve the experiment over loopback HTTP; returns ``(metrics,
        schedule, num_dispatches)`` in scan metric layout.

        ``valid`` is an optional ``(R, K)`` availability mask aligned to
        the schedule (seat k sits round r out when ``valid[r, k]`` is 0
        — the coordinator's per-round close threshold caps at the
        survivor count); ``local_steps`` an optional per-client
        ``(num_clients,)`` heterogeneous step count.  Fault injection
        comes from ``service.faults`` (a :class:`repro.fed.FaultPlan`).
        """
        cfg = self.cfg
        service = service or ServiceConfig()
        if seed is None:
            seed = cfg.seed
        if schedule is None:
            schedule = make_client_schedule(cfg, seed)
        K = cfg.clients_per_round
        bad = [s for s in service.straggler_slots if not 0 <= s < K]
        if bad:
            raise ValueError(f"straggler_slots {bad} out of range 0..{K-1}")
        faults = service.faults
        if faults is not None:
            faults.validate(cfg.rounds, K)
        expected = None
        if valid is not None:
            valid = np.asarray(valid)
            if valid.shape != tuple(schedule.shape):
                raise ValueError(
                    f"valid mask shape {valid.shape} does not match "
                    f"schedule shape {tuple(schedule.shape)}")
            expected = valid.sum(axis=1).astype(np.int64)
        if local_steps is not None:
            local_steps = np.asarray(local_steps, np.int32)
            if local_steps.shape != (cfg.num_clients,):
                raise ValueError(
                    f"local_steps must be ({cfg.num_clients},), got "
                    f"{local_steps.shape}")

        # compile the shared client program BEFORE the worker threads
        # race to call it — once per DISTINCT steps value (results
        # discarded)
        seed_dev = jnp.int32(seed)
        distinct_steps = ({int(cfg.local_steps)} if local_steps is None
                          else {int(s) for s in local_steps})
        for steps_val in sorted(distinct_steps):
            warm = self._client_step(
                seed_dev, self._params, self._state0, jnp.int32(0),
                jnp.int32(int(schedule[0][0])),
                jnp.float32(self._weights_all[int(schedule[0][0])]),
                steps=steps_val)
            jax.block_until_ready(warm[1])

        coord = Coordinator(
            codec=self.codec, partial_fn=self._partial,
            merge_fn=self._merge, finalize_fn=self._finalize,
            apply_fn=self._apply, eval_fn=self._eval,
            eval_rounds=eval_round_indices(cfg, self._eval_every),
            params=self._params, state=self._state0, schedule=schedule,
            seed=seed, service=service, algorithm=cfg.algorithm,
            expected=expected, num_clients=cfg.num_clients)
        httpd = make_http_server(coord)
        base_url = "http://%s:%d" % httpd.server_address[:2]
        server_thread = threading.Thread(target=httpd.serve_forever,
                                         name="fl-coordinator",
                                         daemon=True)
        server_thread.start()

        def client_step_host(w, state, r, cid, weight, steps):
            msg, agg_w, loss = self._client_step(
                seed_dev, w, state, jnp.int32(r), jnp.int32(cid),
                jnp.float32(weight), steps=int(steps))
            return msg, float(agg_w), float(loss)

        errors: List[BaseException] = []
        stats_all: List[Optional[Dict[str, int]]] = [None] * K

        def seat(slot: int) -> None:
            try:
                client = ServiceClient(base_url,
                                       timeout_s=service.timeout_s,
                                       retries=service.retries,
                                       backoff_s=service.backoff_s)
                stats_all[slot] = run_worker(
                    slot, client, service,
                    params_template=self._params,
                    state_template=self._state0,
                    client_step=client_step_host,
                    weights_all=self._weights_all,
                    local_steps=(cfg.local_steps if local_steps is None
                                 else local_steps),
                    valid=valid, faults=faults)
            except BaseException as e:          # surfaced to the caller
                errors.append(e)
                with coord._cv:
                    coord.done = True
                    coord._cv.notify_all()

        workers = [threading.Thread(target=seat, args=(k,),
                                    name=f"fl-client-{k}", daemon=True)
                   for k in range(K)]
        finished = False
        hung: List[str] = []
        try:
            for t in workers:
                t.start()
            finished = coord.wait_done(timeout=service.run_timeout_s)
            if not finished:
                # force the seats out of their poll loops so join below
                # collects every thread that CAN exit
                with coord._cv:
                    coord.done = True
                    coord._cv.notify_all()
            for t in workers:
                t.join(timeout=service.timeout_s)
            # the satellite fix: join(timeout=) returning says NOTHING
            # about the thread — a seat still alive is a hung worker and
            # must never read as silent success
            hung = [t.name for t in workers if t.is_alive()]
        finally:
            httpd.shutdown()
            httpd.server_close()
            server_thread.join(timeout=5.0)
        if errors:
            raise errors[0]
        if not finished:
            raise ServiceError(
                f"service run timed out after {service.run_timeout_s}s "
                f"at round {coord.round}/{coord.rounds} (pool depth "
                f"{len(coord._pool)}) — the fault plan / dropouts left "
                "a round unable to close; set quorum/min_fresh below "
                "the loss count")
        if hung and not service.allow_hung_workers:
            raise ServiceError(
                f"{len(hung)} worker thread(s) still alive after "
                f"join(timeout={service.timeout_s}s): {hung} — a hung "
                "seat is an error, not a silent success (set "
                "allow_hung_workers=True to record it in the report "
                "instead)")

        client_faults: Dict[str, int] = {}
        for stats in stats_all:
            for k, v in (stats or {}).items():
                client_faults[k] = client_faults.get(k, 0) + int(v)
        privacy = getattr(self.codec, "privacy", None)
        if privacy is not None:
            from ..privacy import round_epsilons
            from ...core import tree_num_params
            dp_eps = tuple(float(e) for e in round_epsilons(
                privacy, [int(x) for x in coord.participation],
                cfg.num_clients, self.codec.mode,
                tree_num_params(self._params)))
            dp_delta = float(privacy.delta)
        else:
            dp_eps = (float("inf"),) * cfg.rounds
            dp_delta = 0.0
        # satellite: the comm record carries the MEASURED wire overheads
        # (serde framing per uplink, downlink response beyond the params
        # payload) and the run's final (ε, δ) — not just the payload
        comm = dataclasses.replace(
            self.codec.wire_bits(self._params),
            downlink_bits=coord.downlink_params_bits,
            framing_bits=int(coord.uplink_framing_bits),
            downlink_overhead_bits=(coord.downlink_total_bits
                                    - coord.downlink_params_bits),
            dp_epsilon=dp_eps[-1] if dp_eps else float("inf"),
            dp_delta=dp_delta)
        self.report = ServiceReport(
            mode=service.mode, comm=comm, n_uplinks=coord.n_uplinks,
            uplink_payload_bits=coord.uplink_payload_bits,
            uplink_framing_bits=coord.uplink_framing_bits,
            downlink_requests=coord.downlink_requests,
            downlink_params_bits=coord.downlink_params_bits,
            downlink_total_bits=coord.downlink_total_bits,
            downlink_overhead_bits=(coord.downlink_total_bits
                                    - coord.downlink_params_bits),
            staleness=tuple(tuple(dict(s) for s in row)
                            for row in coord.staleness_log),
            base_url=base_url,
            participation=tuple(int(x) for x in coord.participation),
            expected=tuple(int(x) for x in coord.expected),
            rejected=dict(coord.rejected),
            client_faults=client_faults,
            hung_workers=len(hung),
            dp_epsilon=dp_eps, dp_delta=dp_delta)
        self.final_params = coord.w
        self.final_state = coord.state
        metrics = {
            "loss": np.asarray(coord.loss, np.float32),
            "acc": np.asarray(coord.acc, np.float32),
            "uplink_bits": np.asarray(coord.uplink_bits, np.float32),
        }
        # per-seat client_step dispatches + the coordinator's own
        dispatches = coord.dispatches + client_faults.get("posted", 0)
        return metrics, schedule, dispatches


def make_service_engine(loss_fn, cfg: FLConfig, params: Pytree, data, *,
                        eval_program=None, eval_every: int = 1,
                        client_weights=None) -> ServiceRunner:
    """Build the wire-true service engine (see :class:`ServiceRunner`)."""
    return ServiceRunner(loss_fn, cfg, params, data,
                         eval_program=eval_program,
                         eval_every=eval_every,
                         client_weights=client_weights)
