"""Kernel-backend selection for the masking/packing hot paths.

``core.masking`` and ``core.packing`` accept ``backend="ref" | "pallas" |
None``.  ``None`` auto-selects: the fused Pallas kernels on TPU, the pure
jnp reference elsewhere (Pallas interpret mode is correct on CPU but runs
the kernel body through the interpreter — fine for validation, wrong as a
default).  Explicit ``backend="pallas"`` off-TPU transparently enables
interpret mode, which is what the bitwise ref-vs-pallas tests rely on.

Override order (most local wins): explicit argument > ``use_backend()``
context > ``REPRO_BACKEND`` env var > platform auto-detect.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

import jax

BACKENDS = ("ref", "pallas")

_override: list = []


def _check(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of {BACKENDS}")
    return name


def default_backend() -> str:
    if _override:             # scoped context beats the process-wide env
        return _override[-1]
    env = os.environ.get("REPRO_BACKEND")
    if env:
        return _check(env)
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def resolve_backend(backend: str | None) -> str:
    if backend is None:
        return default_backend()
    return _check(backend)


def pallas_interpret() -> bool:
    """Whether pallas_call must run in interpret mode (non-TPU hosts)."""
    return jax.default_backend() != "tpu"


@contextmanager
def use_backend(name: str):
    """Scoped default-backend override (tests, benchmarks)."""
    _override.append(_check(name))
    try:
        yield
    finally:
        _override.pop()
