"""Device-resident batched evaluation — the scan engine's eval layer.

The host-loop engines call a Python ``eval_fn(params) -> float`` every
``eval_every`` rounds: a blocking device→host read per eval.  The
multi-round experiment program instead folds eval in on-device: an
``eval_program`` is a pure jax function ``params -> accuracy`` built once
over a device-resident test set, traceable inside ``lax.cond`` /
``lax.scan``.

The test set is evaluated in fixed-size minibatches via ``lax.scan`` (not
one giant batch) so eval memory is bounded by ``batch_size`` activations
regardless of test-set size.  The remainder batch is wrap-padded and the
pad positions masked out of the correct-count, so the returned accuracy
equals the full-batch mean exactly (0/1 counts are exact in f32 up to
2^24 examples).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def make_eval_program(
    apply_fn: Callable[[Pytree, jax.Array], jax.Array],
    x: jax.Array,
    y: jax.Array,
    *,
    batch_size: int = 256,
) -> Callable[[Pytree], jax.Array]:
    """Build ``params -> accuracy`` over a device-resident test set.

    ``apply_fn(params, x_batch) -> (B, n_classes) logits``.  The returned
    program is pure and jit/scan/cond-safe; accuracy is the exact mean of
    argmax-correctness over the ``len(y)`` true examples.
    """
    n = int(y.shape[0])
    if n == 0:
        raise ValueError("empty test set")
    bs = min(batch_size, n)
    nb = -(-n // bs)                     # ceil
    # wrap-pad to a rectangular (nb, bs, ...) stack; valid-mask kills pads
    take = jnp.asarray(np.resize(np.arange(n), nb * bs), jnp.int32)
    xb = jnp.asarray(x)[take].reshape((nb, bs) + tuple(x.shape[1:]))
    yb = jnp.asarray(y)[take].reshape(nb, bs)
    valid = (jnp.arange(nb * bs) < n).reshape(nb, bs)

    def program(params: Pytree) -> jax.Array:
        def body(correct, inp):
            xi, yi, vi = inp
            pred = jnp.argmax(apply_fn(params, xi), axis=-1)
            hits = ((pred == yi) & vi).astype(jnp.float32)
            return correct + jnp.sum(hits), None

        correct, _ = jax.lax.scan(body, jnp.float32(0.0), (xb, yb, valid))
        return correct / n

    return program


def make_negloss_eval_program(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    batch: Any,
) -> Callable[[Pytree], jax.Array]:
    """Build ``params -> -loss(params, batch)`` over a fixed eval batch.

    The generative-task counterpart of :func:`make_eval_program`: when
    there is no argmax accuracy to report (LM fine-tuning), the scan
    engine's eval slot takes negative loss on a held-out device-resident
    batch — pure, jit/scan/cond-safe, higher-is-better like accuracy.
    """
    batch = jax.tree_util.tree_map(jnp.asarray, batch)

    def program(params: Pytree) -> jax.Array:
        return -jnp.float32(loss_fn(params, batch))

    return program
