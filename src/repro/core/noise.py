"""Seeded random-noise generation G(s) — the paper's noise generator.

The paper's clients draw noise from a generator ``G`` seeded with a scalar
``s`` that is later shipped to the server (8 bytes).  We realise ``s`` as a
``jax.random`` key derived deterministically from ``(base_seed, round,
client_id)`` via ``fold_in``; server-side regeneration is then *exact* by
construction (same fold chain), which is the property the paper relies on.

Supported distributions (paper §5.5): Uniform[-a, a], Gaussian N(0, a),
Bernoulli {-a, +a}.  Defaults follow the paper: uniform, a=1e-2 for binary
masks (FedMRN) and a=5e-3 for signed masks (FedMRNS).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

DISTRIBUTIONS = ("uniform", "gauss", "bernoulli")


@dataclasses.dataclass(frozen=True)
class NoiseConfig:
    """Static description of G."""

    dist: str = "uniform"          # one of DISTRIBUTIONS
    alpha: float = 1e-2            # magnitude (paper tunes in {6.25e-4..2e-2})
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.dist not in DISTRIBUTIONS:
            raise ValueError(f"unknown noise dist {self.dist!r}")


def client_round_key(base_seed: int, round_idx, client_id) -> jax.Array:
    """The 'random seed s_k^t' of the paper, as a reproducible PRNG key.

    Only (base_seed, round_idx, client_id) — 3 small ints — determine the
    whole noise tensor pytree, so the uplink cost of 's' is O(1) as claimed.
    """
    key = jax.random.key(base_seed)
    key = jax.random.fold_in(key, round_idx)
    key = jax.random.fold_in(key, client_id)
    return key


def _leaf_noise(key: jax.Array, shape, cfg: NoiseConfig) -> jax.Array:
    if cfg.dist == "uniform":
        return jax.random.uniform(
            key, shape, cfg.dtype, minval=-cfg.alpha, maxval=cfg.alpha
        )
    if cfg.dist == "gauss":
        return cfg.alpha * jax.random.normal(key, shape, cfg.dtype)
    # bernoulli {-a, +a}
    bits = jax.random.bernoulli(key, 0.5, shape)
    return jnp.where(bits, cfg.alpha, -cfg.alpha).astype(cfg.dtype)


def gen_noise(key: jax.Array, tree: Pytree, cfg: NoiseConfig) -> Pytree:
    """Generate a noise pytree matching ``tree``'s shapes/dtypes.

    Each leaf gets an independent stream via ``fold_in(key, leaf_index)`` so
    the result is invariant to leaf sizes (no global offset bookkeeping) and
    identical between client and server.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    noises = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        noises.append(_leaf_noise(lk, jnp.shape(leaf), cfg).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, noises)


def gen_noise_like_specs(key: jax.Array, specs: Pytree, cfg: NoiseConfig) -> Pytree:
    """Same as :func:`gen_noise` but from ShapeDtypeStructs (dry-run safe)."""
    return gen_noise(key, specs, cfg)
