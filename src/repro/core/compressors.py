"""Post-training model-update compressors — the paper's baseline zoo (§5.1.3).

All baselines here compress the *final* local update ``u`` after local
training (the "post-training manner" the paper contrasts FedMRN against).
Each compressor maps a pytree ``u`` → (payload pytree, wire bits) and back;
the round-trip ``decompress(compress(u))`` is what the server aggregates.

Implemented:
  none        FedAvg (32 bpp float32)
  signsgd     deterministic sign + per-leaf L1 scale (1 bpp)
  stochsign   stochastic (unbiased) binarization (1 bpp)         [3, 15]
  terngrad    ternary stochastic quantization (log2(3) bpp)      [39]
  topk        magnitude sparsification, default 3% kept           [1]
  qsgd        b-bit stochastic uniform quantization               [31]
  drive       randomized-Hadamard rotation + sign, L2-opt scale   [38]
  eden        as drive, unbiased scale                            [37]
  post_sm     the paper's [FedAvg w. SM] ablation: apply the SM
              estimator post-training with seeded noise (1 bpp)

Everything is pure jnp and jit-safe.  Bit accounting is exact (headers of
per-leaf scales counted at 32 bits each; top-k indices counted, with the
paper's "ignore index overhead" figure also reported by the comm model).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from . import masking
from .noise import NoiseConfig, gen_noise

Pytree = Any
_EPS = 1e-12


# Salt folded into every compressor key: without it, fold_in(key, i) can
# collide with split(key) streams used by the caller to *generate* the data
# being compressed, correlating e.g. DRIVE's rademacher diagonal with the
# input's sign bits (observed: rotated kurtosis 682 instead of 3).
_KEY_SALT = 0x0C0317E5


def _tree_keyed(fn, key, u, *rest):
    key = jax.random.fold_in(key, _KEY_SALT)
    leaves, treedef = jax.tree_util.tree_flatten(u)
    rest_leaves = [jax.tree_util.tree_flatten(r)[0] for r in rest]
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        out.append(fn(leaf, *(r[i] for r in rest_leaves), k))
    return jax.tree_util.tree_unflatten(treedef, out)


def _nelem(tree) -> int:
    return sum(math.prod(jnp.shape(l)) or 1 for l in jax.tree_util.tree_leaves(tree))


def _nleaves(tree) -> int:
    return len(jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# fast Walsh–Hadamard transform (for DRIVE / EDEN's structured rotation)
# ---------------------------------------------------------------------------

def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def fwht(x: jax.Array) -> jax.Array:
    """In-place-style iterative WHT; len(x) must be a power of two.

    Orthonormalised (H/√n), so ``fwht(fwht(x)) == x``.
    """
    n = x.shape[0]
    assert n & (n - 1) == 0, "fwht needs power-of-two length"
    h = 1
    while h < n:
        x = x.reshape(-1, 2, h)
        a, b = x[:, 0], x[:, 1]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        h *= 2
    return x.reshape(-1) / jnp.sqrt(jnp.asarray(n, x.dtype))


def _rotate(x: jax.Array, key) -> Tuple[jax.Array, jax.Array]:
    """R x = H D x with D = diag(rademacher).  Returns (Rx, diag)."""
    n = x.shape[0]
    d = jax.random.rademacher(key, (n,), x.dtype)
    return fwht(x * d), d


def _unrotate(y: jax.Array, d: jax.Array) -> jax.Array:
    """R⁻¹ y = D H y (H orthonormal ⇒ H⁻¹ = H; D² = I)."""
    return fwht(y) * d


# ---------------------------------------------------------------------------
# per-leaf kernels: each returns the reconstructed (lossy) leaf
# ---------------------------------------------------------------------------

def _signsgd_leaf(u, key):
    del key
    a = jnp.mean(jnp.abs(u))
    return a * jnp.sign(u)


def _stochsign_leaf(u, key):
    a = jnp.max(jnp.abs(u)) + _EPS
    p = jnp.clip((u + a) / (2 * a), 0.0, 1.0)
    b = jax.random.bernoulli(key, p)
    return a * jnp.where(b, 1.0, -1.0).astype(u.dtype)


def _terngrad_leaf(u, key):
    s = jnp.max(jnp.abs(u)) + _EPS
    b = jax.random.bernoulli(key, jnp.abs(u) / s)
    return s * jnp.sign(u) * b.astype(u.dtype)


# ---------------------------------------------------------------------------
# stochastic uniform quantization, split into quantize/dequantize halves
# so the integer wire codec (fed.codecs.QuantCodec) ships the SAME levels
# the in-body roundtrip used to simulate in f32
# ---------------------------------------------------------------------------

def stochastic_quantize(u, key, *, levels: int):
    """One leaf → (signed integer levels, scale).

    ``q ∈ [-levels, levels]`` int32 and the f32 scale ``s = max|u| + eps``;
    :func:`stochastic_dequantize` reproduces ``_qsgd_leaf`` (and, at
    ``levels=1``, ``_terngrad_leaf``) bit-for-bit — folding ``sign(u)``
    into the integer is exact, and ``s > |u|`` keeps the floor at 0 for
    the ternary case so the Bernoulli draw matches terngrad's.
    """
    s = jnp.max(jnp.abs(u)) + _EPS
    y = jnp.abs(u) / s * levels
    lo = jnp.floor(y)
    q = lo + jax.random.bernoulli(key, y - lo).astype(u.dtype)
    return (jnp.sign(u) * q).astype(jnp.int32), s.astype(jnp.float32)


def stochastic_dequantize(q, s, *, levels: int):
    """Integer levels + scale → the reconstructed f32 leaf values."""
    return (s / levels) * q.astype(jnp.float32)


def _topk_leaf(u, key, *, frac: float):
    del key
    flat = u.reshape(-1)
    k = max(1, int(math.ceil(frac * flat.shape[0])))
    thresh_vals, _ = jax.lax.top_k(jnp.abs(flat), k)
    thresh = thresh_vals[-1]
    return jnp.where(jnp.abs(u) >= thresh, u, 0.0).astype(u.dtype)


def _qsgd_leaf(u, key, *, bits: int):
    levels = (1 << bits) - 1
    s = jnp.max(jnp.abs(u)) + _EPS
    y = jnp.abs(u) / s * levels
    lo = jnp.floor(y)
    prob = y - lo
    q = lo + jax.random.bernoulli(key, prob).astype(u.dtype)
    return (s / levels) * jnp.sign(u) * q


def _drive_like_leaf(u, key, *, unbiased: bool):
    shape = u.shape
    flat = u.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    m = next_pow2(n)
    pad = jnp.zeros((m - n,), flat.dtype)
    x = jnp.concatenate([flat, pad])
    rx, diag = _rotate(x, key)
    sgn = jnp.sign(rx)
    sgn = jnp.where(sgn == 0, 1.0, sgn)
    l1 = jnp.sum(jnp.abs(rx))
    l2sq = jnp.sum(x * x)
    if unbiased:
        # EDEN-style scale: E[x̂] = x      (α = ||x||² / <Rx, sign(Rx)>)
        alpha = l2sq / (l1 + _EPS)
    else:
        # DRIVE scale minimising ||x − x̂||² (α = ||Rx||₁ / m)
        alpha = l1 / m
    xhat = alpha * _unrotate(sgn, diag)
    return xhat[:n].reshape(shape).astype(u.dtype)


def _post_sm_leaf(u, n_leaf, key, *, mode):
    m = masking.sample_mask(u, n_leaf, key, mode=mode)
    return masking.masked_noise_from_mask(n_leaf, m)


# ---------------------------------------------------------------------------
# compressor registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compressor:
    """A post-training update compressor with exact wire-size accounting."""

    name: str
    roundtrip: Callable[[Pytree, jax.Array], Pytree]
    # bits on the wire per round for a pytree with P params and L leaves
    wire_bits: Callable[[int, int], int]

    def __call__(self, u: Pytree, key: jax.Array) -> Pytree:
        return self.roundtrip(u, key)

    def bits_for(self, tree: Pytree) -> int:
        return self.wire_bits(_nelem(tree), _nleaves(tree))


def _mk(name, leaf_fn, bpp, per_leaf_overhead_bits=32):
    def roundtrip(u, key):
        return _tree_keyed(leaf_fn, key, u)

    def wire(P, L):
        return int(P * bpp + L * per_leaf_overhead_bits)

    return Compressor(name, roundtrip, wire)


def make_compressor(
    name: str,
    *,
    topk_frac: float = 0.03,
    qsgd_bits: int = 2,
    noise: NoiseConfig | None = None,
    mask_mode: str = "binary",
) -> Compressor:
    name = name.lower()
    if name in ("none", "fedavg", "identity"):
        return Compressor("none", lambda u, k: u, lambda P, L: 32 * P)
    if name == "signsgd":
        return _mk("signsgd", _signsgd_leaf, 1)
    if name == "stochsign":
        return _mk("stochsign", _stochsign_leaf, 1)
    if name == "terngrad":
        return _mk("terngrad", _terngrad_leaf, math.log2(3))
    if name == "topk":
        # 32-bit value + ceil(log2 P) index per kept element (exact
        # accounting; the paper ignores index bits — comm.py reports both)
        def wire(P, L):
            idx_bits = max(1, math.ceil(math.log2(max(P, 2))))
            return int(topk_frac * P * (32 + idx_bits)) + 32 * L
        return Compressor(
            "topk",
            lambda u, k: _tree_keyed(partial(_topk_leaf, frac=topk_frac), k, u),
            wire,
        )
    if name == "qsgd":
        return _mk(f"qsgd{qsgd_bits}",
                   partial(_qsgd_leaf, bits=qsgd_bits), qsgd_bits)
    if name == "drive":
        return _mk("drive", partial(_drive_like_leaf, unbiased=False), 1)
    if name == "eden":
        return _mk("eden", partial(_drive_like_leaf, unbiased=True), 1)
    if name == "post_sm":
        cfg = noise or NoiseConfig()

        def roundtrip(u, key):
            k_noise, k_mask = jax.random.split(key)
            n = gen_noise(k_noise, u, cfg)
            return _tree_keyed(
                partial(_post_sm_leaf, mode=mask_mode), k_mask, u, n
            )

        return Compressor("post_sm", roundtrip,
                          lambda P, L: P + 64)  # masks + seed
    raise ValueError(f"unknown compressor {name!r}")


REGISTRY = (
    "none", "signsgd", "stochsign", "terngrad", "topk", "qsgd",
    "drive", "eden", "post_sm",
)
