"""FedMRN client/server core — Algorithm 1 of the paper.

The client keeps the received global params ``w`` frozen, trains only the
update copy ``u`` (init 0), runs PSM in every forward pass, and finally ships
``(packed mask, seed)``.  The server regenerates each client's noise from its
seed and applies Eq.(5).

Everything is functional and jit-safe; the local loop is a ``lax.scan`` over
the (fixed-shape) stack of local batches, so a whole client update is one
XLA program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import masking, packing
from .noise import NoiseConfig, client_round_key, gen_noise

Pytree = Any
LossFn = Callable[[Pytree, Any], jax.Array]  # (params, batch) -> scalar


@dataclasses.dataclass(frozen=True)
class FedMRNConfig:
    """Static hyper-parameters of the FedMRN mechanism."""

    mask_mode: str = "binary"        # "binary" (FedMRN) | "signed" (FedMRNS)
    noise: NoiseConfig = NoiseConfig()
    use_sm: bool = True              # ablation: False → deterministic masking
    use_pm: bool = True              # ablation: False → progress ≡ 1
    error_feedback: bool = False     # beyond-paper: carry u − û residual
    lr: float = 0.1
    backend: str | None = None       # masking/packing kernels: ref | pallas

    def __post_init__(self):
        if self.mask_mode not in masking.MASK_MODES:
            raise ValueError(f"bad mask_mode {self.mask_mode!r}")


class ClientResult(NamedTuple):
    """What a FedMRN client sends (plus local diagnostics)."""

    packed_mask: jax.Array   # uint32 payload, 1 bit / param
    seed_key: jax.Array      # the PRNG key standing in for the scalar seed
    losses: jax.Array        # (S,) per-step local losses
    residual: Pytree         # u − û (zeros unless error_feedback)


def _tree_zeros_like(t: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def mix_add(p, u_hat):
    """w + û leaf-mix with the model's param dtype preserved (bf16-safe).

    The ONE definition of how updates meet params — engine aggregation and
    the pod program reuse it, so precision rules change in one place.
    """
    return (p.astype(jnp.float32) + u_hat).astype(p.dtype)


_mix_add = mix_add  # internal alias


def _masked_update(u, noise, key, *, progress, cfg: FedMRNConfig) -> Pytree:
    """The û actually used in the forward pass (Alg. 1 lines 15-18)."""
    if cfg.use_sm and cfg.use_pm:
        return masking.tree_psm(
            u, noise, key, progress=progress, mode=cfg.mask_mode,
            backend=cfg.backend,
        )
    if cfg.use_sm:  # SM only: every element masked every step
        return masking.tree_sm(u, noise, key, mode=cfg.mask_mode)
    # DM in place of SM (w.o. SM ablation); PM still gates if enabled
    def dm_leaf(ul, nl, k):
        m = masking.deterministic_mask(ul, nl, mode=cfg.mask_mode)
        hat = ul + jax.lax.stop_gradient(
            masking.masked_noise_from_mask(nl, m) - ul
        )
        if not cfg.use_pm:
            return hat
        P = jax.random.bernoulli(k, progress, jnp.shape(ul))
        bar = masking.clip_to_noise(ul, nl, mode=cfg.mask_mode)
        return jnp.where(P, hat, bar)

    return masking._tree_keyed_map(dm_leaf, key, u, noise)


def psm_local_train(
    loss_fn: LossFn,
    w_global: Pytree,
    batches: Pytree,           # leaves stacked along leading axis S
    noise: Pytree,
    train_key: jax.Array,
    *,
    cfg: FedMRNConfig,
    u0: Pytree | None = None,
) -> Tuple[Pytree, jax.Array]:
    """S local SGD steps on ``u`` with PSM forward (Alg. 1 lines 12-18).

    The shared local-training body of every FedMRN round program: the
    simulation engine vmaps it over a stacked client axis, the pod program
    runs it per mesh-client slice.  Returns (u_final, per-step losses).
    """
    num_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
    if u0 is None:
        u0 = _tree_zeros_like(w_global)

    def step(u, inp):
        tau, batch = inp
        progress = (tau + 1.0) / num_steps
        k = jax.random.fold_in(train_key, tau)

        def fwd(u_):
            u_hat = _masked_update(u_, noise, k, progress=progress, cfg=cfg)
            return loss_fn(jax.tree_util.tree_map(_mix_add, w_global, u_hat),
                           batch)

        loss, grads = jax.value_and_grad(fwd)(u)
        u = jax.tree_util.tree_map(lambda a, g: a - cfg.lr * g, u, grads)
        return u, loss

    taus = jnp.arange(num_steps, dtype=jnp.float32)
    return jax.lax.scan(step, u0, (taus, batches))


def sample_final_mask(
    u_final: Pytree,
    noise: Pytree,
    mask_key: jax.Array,
    *,
    cfg: FedMRNConfig,
) -> Pytree:
    """Final uplink masks M(u^{S+1}, G(s)) (Alg. 1 line 19)."""
    if cfg.use_sm:
        return masking.tree_sample_mask(u_final, noise, mask_key,
                                        mode=cfg.mask_mode)
    return jax.tree_util.tree_map(
        lambda ul, nl: masking.deterministic_mask(ul, nl,
                                                  mode=cfg.mask_mode),
        u_final, noise)


def final_mask_key(train_key: jax.Array, num_steps: int) -> jax.Array:
    """Key convention for the post-training mask draw."""
    return jax.random.fold_in(train_key, num_steps + 1)


def client_local_update(
    loss_fn: LossFn,
    w_global: Pytree,
    batches: Pytree,           # leaves stacked along leading axis S
    *,
    cfg: FedMRNConfig,
    base_seed: int,
    round_idx,
    client_id,
    train_key: jax.Array,
    init_residual: Pytree | None = None,
) -> ClientResult:
    """One ClientLocalUpdate (Alg. 1 lines 10-19)."""
    seed_key = client_round_key(base_seed, round_idx, client_id)
    noise = gen_noise(seed_key, w_global, cfg.noise)
    num_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]

    u0 = None
    if cfg.error_feedback and init_residual is not None:
        # beyond-paper: warm-start u at last round's compression residual
        u0 = init_residual

    u_final, losses = psm_local_train(loss_fn, w_global, batches, noise,
                                      train_key, cfg=cfg, u0=u0)
    m = sample_final_mask(u_final, noise,
                          final_mask_key(train_key, num_steps), cfg=cfg)
    packed = packing.tree_pack(m, mode=cfg.mask_mode, backend=cfg.backend)

    u_hat = masking.tree_masked_noise(noise, m)
    residual = (jax.tree_util.tree_map(jnp.subtract, u_final, u_hat)
                if cfg.error_feedback else _tree_zeros_like(w_global))
    return ClientResult(packed, seed_key, losses, residual)


# ---------------------------------------------------------------------------
# plain FedAvg-style local training (for every post-training baseline)
# ---------------------------------------------------------------------------

def sgd_local_update(
    loss_fn: LossFn,
    w_global: Pytree,
    batches: Pytree,
    *,
    lr: float,
) -> Tuple[Pytree, jax.Array]:
    """Vanilla local SGD; returns (u = w_local − w_global, per-step losses)."""

    def step(w, batch):
        loss, grads = jax.value_and_grad(loss_fn)(w, batch)
        w = jax.tree_util.tree_map(lambda a, g: a - lr * g, w, grads)
        return w, loss

    w_final, losses = jax.lax.scan(step, w_global, batches)
    u = jax.tree_util.tree_map(jnp.subtract, w_final, w_global)
    return u, losses


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------

def server_decode_update(
    packed_mask: jax.Array,
    seed_key: jax.Array,
    like: Pytree,
    *,
    cfg: FedMRNConfig,
) -> Pytree:
    """Recover û = G(s) ⊙ m from the wire payload."""
    noise = gen_noise(seed_key, like, cfg.noise)
    m = packing.tree_unpack(packed_mask, like, mode=cfg.mask_mode,
                            backend=cfg.backend)
    return masking.tree_masked_noise(noise, m)


def server_aggregate(
    w_global: Pytree,
    results: Sequence[ClientResult],
    weights: Sequence[float] | jax.Array,
    *,
    cfg: FedMRNConfig,
) -> Pytree:
    """Eq.(5): w ← w + Σ p'_k G(s_k) ⊙ m_k (weights pre-normalised)."""
    weights = jnp.asarray(weights)
    weights = weights / jnp.sum(weights)
    agg = _tree_zeros_like(w_global)
    for wk, res in zip(weights, results):
        u_hat = server_decode_update(res.packed_mask, res.seed_key,
                                     w_global, cfg=cfg)
        agg = jax.tree_util.tree_map(lambda a, b: a + wk * b, agg, u_hat)
    # mix_add (not plain add): preserves param dtype, so bf16 models don't
    # drift to f32 round-over-round — same rule as the batched/scan engines
    return jax.tree_util.tree_map(_mix_add, w_global, agg)


def server_aggregate_updates(
    w_global: Pytree,
    updates: Sequence[Pytree],
    weights: Sequence[float] | jax.Array,
) -> Pytree:
    """FedAvg aggregation of float updates (Eq. 3)."""
    weights = jnp.asarray(weights)
    weights = weights / jnp.sum(weights)
    agg = _tree_zeros_like(w_global)
    for wk, u in zip(weights, updates):
        agg = jax.tree_util.tree_map(lambda a, b: a + wk * b, agg, u)
    return jax.tree_util.tree_map(_mix_add, w_global, agg)
