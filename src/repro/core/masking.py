"""Masking strategies: DM, SM, PM, PSM (paper §3.2) with STE backprop.

All functions are elementwise over arrays; pytree variants map them over
leaves with per-leaf folded keys.  Conventions:

- ``u``  — trainable copy of the model update (the only trainable variable).
- ``n``  — the predefined random noise G(s) (same shape as ``u``).
- binary mode: mask m ∈ {0,1}, masked noise û = n·m          (Eq. 6)
- signed mode: mask m ∈ {-1,1}, masked noise û = n·m         (Eq. 7)

Zero-noise guard: with continuous noise P(n=0)=0, but we still guard the
division so Bernoulli probabilities are always well defined.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .backend import pallas_interpret, resolve_backend

Pytree = Any
_EPS = 1e-30

MASK_MODES = ("binary", "signed")


def _safe_div(a, b):
    return a / jnp.where(jnp.abs(b) < _EPS, jnp.where(b < 0, -_EPS, _EPS), b)


# ---------------------------------------------------------------------------
# probabilities (Eq. 6 / Eq. 7)
# ---------------------------------------------------------------------------

def mask_prob_binary(u: jax.Array, n: jax.Array) -> jax.Array:
    """P[m=1] = clip(u/n, 0, 1).  Unbiased when u/n ∈ [0, 1]."""
    return jnp.clip(_safe_div(u, n), 0.0, 1.0)


def mask_prob_signed(u: jax.Array, n: jax.Array) -> jax.Array:
    """P[m=+1] = clip((u+n)/(2n), 0, 1).  Unbiased when u/n ∈ [-1, 1]."""
    return jnp.clip(_safe_div(u + n, 2.0 * n), 0.0, 1.0)


# ---------------------------------------------------------------------------
# mask sampling
# ---------------------------------------------------------------------------

def sample_mask(u, n, key, *, mode: str = "binary") -> jax.Array:
    """Bernoulli-sample the mask (SM); returns {0,1} or {-1,+1} as int8."""
    if mode == "binary":
        p = mask_prob_binary(u, n)
        return jax.random.bernoulli(key, p).astype(jnp.int8)
    elif mode == "signed":
        p = mask_prob_signed(u, n)
        b = jax.random.bernoulli(key, p)
        return jnp.where(b, jnp.int8(1), jnp.int8(-1))
    raise ValueError(f"unknown mask mode {mode!r}")


def deterministic_mask(u, n, *, mode: str = "binary") -> jax.Array:
    """DM baseline (paper §3.2.1): sign agreement, no sampling — biased."""
    if mode == "binary":
        return (jnp.sign(u) == jnp.sign(n)).astype(jnp.int8)
    elif mode == "signed":
        same = jnp.sign(u) * jnp.sign(n) >= 0
        return jnp.where(same, jnp.int8(1), jnp.int8(-1))
    raise ValueError(f"unknown mask mode {mode!r}")


# ---------------------------------------------------------------------------
# SM: stochastic masking with straight-through estimator (Eq. 8/9)
# ---------------------------------------------------------------------------

@jax.custom_jvp
def _ste(u, hat):
    """Forward = ``hat`` EXACTLY; gradient flows to ``u`` as identity.

    The textbook ``u + stop_gradient(hat - u)`` form re-derives ``hat``
    through two float additions and lands 1 ULP off for some elements —
    which breaks bitwise parity with the fused Pallas kernel (and the
    server-side n·m reconstruction).  A custom_jvp keeps the forward value
    untouched and the Eq.(9) straight-through gradient; the tangent rule
    is linear, so both forward- and reverse-mode autodiff work.
    """
    return hat


@_ste.defjvp
def _ste_jvp(primals, tangents):
    u, hat = primals
    t_u, _t_hat = tangents
    return hat, t_u


def stochastic_masking(u, n, key, *, mode: str = "binary") -> jax.Array:
    """û = S(u, n) = n ⊙ M(u, n) with ∂û/∂u = 1 (STE).

    Forward value is the masked random noise; the gradient flows to ``u``
    unchanged, per Eq.(9).
    """
    m = sample_mask(u, n, key, mode=mode)
    hat = n * m.astype(u.dtype)
    return _ste(u, hat)


def clip_to_noise(u, n, *, mode: str = "binary") -> jax.Array:
    """ū = clip(u, G(s)) (Eq. 10 text): binary → interval [0, n] (or [n, 0]);
    signed → [-|n|, |n|]."""
    if mode == "binary":
        lo = jnp.minimum(n, 0.0)
        hi = jnp.maximum(n, 0.0)
    else:
        hi = jnp.abs(n)
        lo = -hi
    return jnp.clip(u, lo, hi)


# ---------------------------------------------------------------------------
# PSM: progressive stochastic masking (Eq. 10, Algorithm 1 lines 15-18)
# ---------------------------------------------------------------------------

def progressive_stochastic_masking(
    u, n, key, *, progress, mode: str = "binary"
) -> jax.Array:
    """û = (1-P)⊙ū + P⊙S(u, n), P ~ Bern(progress); STE throughout.

    ``progress`` = τ/S ∈ [0,1]; at 1.0 every element is masked noise, which is
    what the final uplink transmits.
    """
    k_sm, k_pm = jax.random.split(key)
    hat_sm = stochastic_masking(u, n, k_sm, mode=mode)  # carries its own STE
    bar = clip_to_noise(u, n, mode=mode)                 # differentiable clip
    P = jax.random.bernoulli(k_pm, progress, jnp.shape(u))
    return jnp.where(P, hat_sm, bar)


def masked_noise_from_mask(n, m):
    """Reconstruct û = n ⊙ m given a {0,1}/{-1,1} mask (server side)."""
    return n * m.astype(n.dtype)


# ---------------------------------------------------------------------------
# pytree variants — one folded key per leaf
# ---------------------------------------------------------------------------

def _tree_keyed_map(fn, key: jax.Array, tree: Pytree, *rest: Pytree) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    rests = [jax.tree_util.tree_flatten(r)[0] for r in rest]
    out = []
    for i, leaf in enumerate(leaves):
        lk = jax.random.fold_in(key, i)
        out.append(fn(leaf, *(r[i] for r in rests), lk))
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_sample_mask(u: Pytree, n: Pytree, key, *, mode="binary") -> Pytree:
    return _tree_keyed_map(
        lambda ul, nl, k: sample_mask(ul, nl, k, mode=mode), key, u, n
    )


def tree_psm(u: Pytree, n: Pytree, key, *, progress, mode="binary",
             backend: str | None = None) -> Pytree:
    """PSM over a pytree, dispatched to the selected kernel backend.

    ``backend="pallas"`` routes each leaf through the fused Pallas kernel
    (``kernels/psm_mask``) — one HBM read/write instead of ~6 elementwise
    passes — with STE gradients identical to the reference path.  Both
    backends consume the same per-leaf folded key streams, so outputs are
    equal (and the pallas path is validated bitwise in interpret mode).
    """
    backend = resolve_backend(backend)
    if backend == "pallas":
        from ..kernels.psm_mask.ops import psm_ste
        interp = pallas_interpret()
        return _tree_keyed_map(
            lambda ul, nl, k: psm_ste(ul, nl, k, progress, mode=mode,
                                      interpret=interp),
            key, u, n,
        )
    return _tree_keyed_map(
        lambda ul, nl, k: progressive_stochastic_masking(
            ul, nl, k, progress=progress, mode=mode
        ),
        key, u, n,
    )


def tree_sample_mask_stacked(u: Pytree, n: Pytree, keys, *,
                             mode="binary") -> Pytree:
    """Client-stacked final-mask draw: row k of every leaf is exactly
    ``tree_sample_mask(u_k, n_k, keys[k])``.  The per-client ``fold_in``
    /uniform streams are counter-based, so vmapping them over the client
    axis reproduces the per-client calls bit for bit — this is the
    staged sampler the fused uplink is verified against.
    """
    return jax.vmap(
        lambda ul, nl, k: tree_sample_mask(ul, nl, k, mode=mode)
    )(u, n, keys)


def tree_bernoulli_stacked(probs: Pytree, keys) -> Pytree:
    """Client-stacked per-leaf Bernoulli draw (the FedPM uplink): row k of
    leaf i is ``bernoulli(fold_in(keys[k], i), probs_k_i)``."""
    return jax.vmap(
        lambda pt, k: _tree_keyed_map(
            lambda pl, lk: jax.random.bernoulli(lk, pl), k, pt)
    )(probs, keys)


class TreeUplink(NamedTuple):
    """One round's fused mask uplink over a client-stacked param tree.

    ``counts``/``wsum`` are FLAT ``(P,)`` buffers in ``tree_flat_layout``
    leaf order (split with ``packing.tree_split_flat``); ``words`` is the
    same ``(K, ceil(P/32))`` payload ``tree_pack_stacked`` produces.
    """

    words: jax.Array    # (K, ceil(P/32)) uint32 wire rows
    counts: jax.Array   # (P,) int32 Σ_k m_k (signed: Σ ±1)
    wsum: jax.Array     # (P,) f32 Σ_k w_k · v_k


def tree_mask_uplink(u: Pytree, n: Pytree, keys, weights, *, mode="binary",
                     wsum_values=True, probs=False,
                     backend: str | None = None) -> TreeUplink:
    """The whole uplink hot path in one pass: sample the final masks,
    bitpack them, and reduce the server-side count/weighted sums.

    Draws the SAME per-(client, leaf) uniform streams as
    :func:`tree_sample_mask_stacked` (``bernoulli(k, p)`` ≡
    ``uniform(k) < p``), so the packed words match the staged
    ``tree_sample_mask → tree_pack_stacked`` composition bit for bit.
    ``probs=True`` treats ``u`` as Bernoulli probabilities directly
    (FedPM; ``n`` ignored) and matches :func:`tree_bernoulli_stacked`.
    ``backend="pallas"`` runs the fused Pallas kernel (interpret mode
    off-TPU); ``"ref"`` the single-program jnp oracle — neither ever
    materializes the f32 mask tree or an unpacked bit tensor.
    """
    from ..kernels.mask_uplink.ops import mask_uplink_fused

    backend = resolve_backend(backend)
    leaves_u = jax.tree_util.tree_leaves(u)
    leaves_n = None if probs else jax.tree_util.tree_leaves(n)
    K = leaves_u[0].shape[0]
    flats_u, flats_n, flats_r = [], [], []
    for i, ul in enumerate(leaves_u):
        shape = ul.shape[1:]
        lk = jax.vmap(lambda k: jax.random.fold_in(k, i))(keys)
        r = jax.vmap(
            lambda k: jax.random.uniform(k, shape, jnp.float32))(lk)
        flats_r.append(r.reshape(K, -1))
        flats_u.append(ul.reshape(K, -1))
        if not probs:
            flats_n.append(leaves_n[i].reshape(K, -1))
    uf = jnp.concatenate(flats_u, axis=1)
    rf = jnp.concatenate(flats_r, axis=1)
    nf = None if probs else jnp.concatenate(flats_n, axis=1)
    out = mask_uplink_fused(uf, nf, rf, None, None, weights,
                            mode=("prob" if probs else mode),
                            wsum_values=wsum_values,
                            use_pallas=(backend == "pallas"),
                            interpret=pallas_interpret())
    return TreeUplink(out.words, out.counts, out.wsum)


def tree_sm(u: Pytree, n: Pytree, key, *, mode="binary") -> Pytree:
    return _tree_keyed_map(
        lambda ul, nl, k: stochastic_masking(ul, nl, k, mode=mode), key, u, n
    )


def tree_masked_noise(n: Pytree, m: Pytree) -> Pytree:
    return jax.tree_util.tree_map(masked_noise_from_mask, n, m)
