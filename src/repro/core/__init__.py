"""FedMRN core: noise, masking (SM/PM/PSM), packing, compressors, protocol."""
from .noise import NoiseConfig, client_round_key, gen_noise  # noqa: F401
from .masking import (  # noqa: F401
    MASK_MODES,
    clip_to_noise,
    deterministic_mask,
    mask_prob_binary,
    mask_prob_signed,
    masked_noise_from_mask,
    progressive_stochastic_masking,
    sample_mask,
    stochastic_masking,
    tree_masked_noise,
    tree_psm,
    tree_sample_mask,
    tree_sm,
)
from .packing import (  # noqa: F401
    pack_bits,
    pack_mask,
    payload_bits,
    tree_num_params,
    tree_pack,
    tree_unpack,
    unpack_bits,
    unpack_mask,
)
from .compressors import REGISTRY, Compressor, make_compressor  # noqa: F401
from .fedmrn import (  # noqa: F401
    ClientResult,
    FedMRNConfig,
    client_local_update,
    server_aggregate,
    server_aggregate_updates,
    server_decode_update,
    sgd_local_update,
)
from .comm import CommRecord, baseline_record, fedmrn_record  # noqa: F401
