"""FedMRN core: noise, masking (SM/PM/PSM), packing, compressors, protocol."""
from .backend import (  # noqa: F401
    BACKENDS,
    default_backend,
    pallas_interpret,
    resolve_backend,
    use_backend,
)
from .noise import NoiseConfig, client_round_key, gen_noise  # noqa: F401
from .masking import (  # noqa: F401
    MASK_MODES,
    clip_to_noise,
    deterministic_mask,
    mask_prob_binary,
    mask_prob_signed,
    masked_noise_from_mask,
    progressive_stochastic_masking,
    sample_mask,
    stochastic_masking,
    TreeUplink,
    tree_bernoulli_stacked,
    tree_mask_uplink,
    tree_masked_noise,
    tree_psm,
    tree_sample_mask,
    tree_sample_mask_stacked,
    tree_sm,
)
from .packing import (  # noqa: F401
    pack_bits,
    pack_mask,
    pack_rows,
    payload_bits,
    tree_num_params,
    tree_pack,
    tree_pack_stacked,
    tree_unpack,
    tree_unpack_counts,
    tree_unpack_counts_apply,
    tree_unpack_stacked,
    unpack_bits,
    unpack_mask,
    unpack_rows,
)
from .compressors import REGISTRY, Compressor, make_compressor  # noqa: F401
from .fedmrn import (  # noqa: F401
    ClientResult,
    FedMRNConfig,
    client_local_update,
    final_mask_key,
    mix_add,
    psm_local_train,
    sample_final_mask,
    server_aggregate,
    server_aggregate_updates,
    server_decode_update,
    sgd_local_update,
)
from .comm import CommRecord, baseline_record, fedmrn_record  # noqa: F401
from .evaluation import (  # noqa: F401
    make_eval_program,
    make_negloss_eval_program,
)
