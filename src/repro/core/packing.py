"""1-bit mask packing — the wire format of the FedMRN uplink.

A mask tensor (values {0,1} or {-1,+1}) is flattened, padded to a multiple of
32, and packed little-endian into ``uint32`` words.  Signed masks map
-1 → bit 0, +1 → bit 1 (the paper's identity G⊙m_s = 2G⊙m − G makes the two
formats interconvertible).  Packing is what makes the collective/uplink cost
literally 1 bit per parameter — these arrays are what we all-gather across
the client axis in the sharded round and what the comm model counts.

Pure ``jnp`` (no host round-trip) so it stays inside jit/pjit programs.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .backend import pallas_interpret, resolve_backend

Pytree = Any
WORD = 32


def packed_len(n_bits: int) -> int:
    return (n_bits + WORD - 1) // WORD


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a {0,1}-valued array (any shape) into a 1-D uint32 array.

    bit i of word w corresponds to flat element w*32+i (little-endian).
    """
    flat = bits.reshape(-1).astype(jnp.uint32)
    n = flat.shape[0]
    pad = (-n) % WORD
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint32)])
    words = flat.reshape(-1, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.bitwise_or.reduce(words << shifts, axis=1)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns {0,1} int8 of length ``n_bits``."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n_bits].astype(jnp.int8)


def pack_mask(mask: jax.Array, *, mode: str = "binary") -> jax.Array:
    """Pack a mask tensor ({0,1} binary or {-1,1} signed) to uint32 words."""
    if mode == "binary":
        bits = (mask > 0)
    elif mode == "signed":
        bits = (mask > 0)  # -1 → 0, +1 → 1
    else:
        raise ValueError(f"unknown mask mode {mode!r}")
    return pack_bits(bits)


def unpack_mask(words: jax.Array, n_bits: int, *, mode: str = "binary") -> jax.Array:
    bits = unpack_bits(words, n_bits)
    if mode == "binary":
        return bits
    return (2 * bits - 1).astype(jnp.int8)


# ---------------------------------------------------------------------------
# pytree wire format: one packed uint32 vector for the whole parameter pytree
# ---------------------------------------------------------------------------

def tree_bit_sizes(tree: Pytree):
    """Per-leaf element counts (static)."""
    return [math.prod(jnp.shape(l)) or 1 for l in jax.tree_util.tree_leaves(tree)]


def tree_flat_layout(tree: Pytree):
    """``(leaves, treedef, sizes, offsets)`` of a pytree's flat layout.

    THE one definition of how leaf data maps into a flat wire buffer
    (leaf order, per-leaf element counts, start offsets) — every
    unpack/split path below and in ``fed/codecs.py`` derives from it, so
    a layout change cannot silently fork the wire formats.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [math.prod(jnp.shape(l)) or 1 for l in leaves]
    offsets, off = [], 0
    for sz in sizes:
        offsets.append(off)
        off += sz
    return leaves, treedef, sizes, offsets


def tree_split_flat(flat: jax.Array, like: Pytree, *,
                    leading: Tuple[int, ...] = ()) -> Pytree:
    """Split a flat ``(..., P)`` buffer back into ``like``-shaped leaves.

    ``leading`` names extra leading axes to preserve (e.g. ``(K,)`` for
    a client-stacked buffer); leaf dtypes are NOT cast — callers decide.
    """
    leaves, treedef, sizes, offsets = tree_flat_layout(like)
    out = [flat[..., off: off + sz].reshape(leading + tuple(jnp.shape(l)))
           for l, sz, off in zip(leaves, sizes, offsets)]
    return jax.tree_util.tree_unflatten(treedef, out)


# --- backend-dispatched row packing (the wire hot path) --------------------
#
# ``pack_rows``/``unpack_rows`` operate on a (rows, n_bits) {0,1} matrix —
# one row per client in the batched round — and dispatch to the Pallas
# bitpack kernel (``kernels/bitpack``) or the jnp reference.  Both produce
# the same little-endian uint32 words, so dispatch is value-transparent.

def pack_rows(bits: jax.Array, *, backend: str | None = None) -> jax.Array:
    """(R, n_bits) {0,1} → (R, ceil(n_bits/32)) uint32, little-endian."""
    backend = resolve_backend(backend)
    n_bits = bits.shape[-1]
    if backend == "pallas":
        from ..kernels.bitpack.ops import pack
        pad = (-n_bits) % WORD
        x = bits.astype(jnp.int8)
        if pad:
            x = jnp.pad(x, [(0, 0), (0, pad)])
        return pack(x, use_pallas=True, interpret=pallas_interpret())
    return pack_lastdim(bits)


def unpack_rows(words: jax.Array, n_bits: int,
                *, backend: str | None = None) -> jax.Array:
    """(R, W) uint32 → (R, n_bits) {0,1} int8; inverse of :func:`pack_rows`."""
    backend = resolve_backend(backend)
    if backend == "pallas":
        from ..kernels.bitpack.ops import unpack
        bits = unpack(words, use_pallas=True, interpret=pallas_interpret())
        return bits[..., :n_bits]
    return unpack_lastdim(words, n_bits)


def _tree_bits(mask_tree: Pytree) -> jax.Array:
    """Flatten a mask pytree to one {0,1} bool vector (leaf order)."""
    leaves = jax.tree_util.tree_leaves(mask_tree)
    return jnp.concatenate([(l > 0).reshape(-1) for l in leaves])


def tree_pack(mask_tree: Pytree, *, mode: str = "binary",
              backend: str | None = None) -> jax.Array:
    """Concatenate all leaves' bits into one padded uint32 payload."""
    del mode  # both modes store sign bit identically
    flat = _tree_bits(mask_tree)
    backend = resolve_backend(backend)
    if backend == "pallas":
        return pack_rows(flat[None, :], backend=backend).reshape(-1)
    return pack_bits(flat)


def tree_unpack(words: jax.Array, like: Pytree, *, mode: str = "binary",
                backend: str | None = None) -> Pytree:
    """Unpack one payload into a mask pytree shaped like ``like``."""
    total = sum(tree_flat_layout(like)[2])
    backend = resolve_backend(backend)
    if backend == "pallas":
        bits = unpack_rows(words[None, :], total, backend=backend)[0]
    else:
        bits = unpack_bits(words, total)
    if mode == "signed":
        bits = (2 * bits - 1).astype(jnp.int8)
    return tree_split_flat(bits, like)


def tree_pack_stacked(mask_tree: Pytree, *, mode: str = "binary",
                      backend: str | None = None) -> jax.Array:
    """Pack a client-stacked mask pytree (leading axis K on every leaf).

    Returns the (K, ceil(P/32)) uint32 payload matrix — row k is exactly
    ``tree_pack`` of client k's mask, but the whole batch is packed in one
    kernel launch, which is the uplink hot path of the batched round.
    """
    del mode
    leaves = jax.tree_util.tree_leaves(mask_tree)
    K = leaves[0].shape[0]
    flat = jnp.concatenate(
        [(l > 0).reshape(K, -1) for l in leaves], axis=1)
    return pack_rows(flat, backend=backend)


def tree_unpack_stacked(words: jax.Array, like: Pytree, *,
                        mode: str = "binary",
                        backend: str | None = None) -> Pytree:
    """Inverse of :func:`tree_pack_stacked`: (K, W) → stacked mask pytree."""
    total = sum(tree_flat_layout(like)[2])
    K = words.shape[0]
    bits = unpack_rows(words, total, backend=backend)
    if mode == "signed":
        bits = (2 * bits - 1).astype(jnp.int8)
    return tree_split_flat(bits, like, leading=(K,))


def tree_unpack_counts(words: jax.Array, like: Pytree, *,
                       mode: str = "binary",
                       dtype=jnp.int8,
                       backend: str | None = None) -> Pytree:
    """(K, W) packed rows → per-leaf integer mask-count sums ``Σ_k m_k``.

    The server side of the ``⌈log2(K+1)⌉``-bit mask wire format: unpack
    the K clients' rows and reduce over the client axis in the *integer*
    ``dtype`` (which must hold ±K), so that when the client axis is
    partitioned over a mesh the cross-client all-reduce moves integer
    words instead of f32.  Signed mode sums {-1,+1} values (range ±K).

    On the pallas backend the fused ``kernels/mask_uplink`` counts kernel
    reduces per word-block inside VMEM — the 32×-larger unpacked bit
    tensor never reaches HBM (ref unpacks then sums, same integers).
    """
    total = sum(tree_flat_layout(like)[2])
    backend = resolve_backend(backend)
    if backend == "pallas":
        from ..kernels.mask_uplink.ops import unpack_counts
        c = unpack_counts(words, use_pallas=True,
                          interpret=pallas_interpret())[:total]
        if mode == "signed":
            c = 2 * c - words.shape[0]
        return tree_split_flat(c.astype(dtype), like)
    bits = unpack_rows(words, total, backend=backend)
    if mode == "signed":
        bits = (2 * bits - 1).astype(jnp.int8)
    return tree_split_flat(jnp.sum(bits, axis=0, dtype=dtype), like)


def tree_unpack_counts_apply(words: jax.Array, noise: Pytree, params: Pytree,
                             scale, *, mode: str = "binary",
                             backend: str | None = None) -> Pytree:
    """Aggregated count words → the updated global model, in one op:

        p  ←  (p + n ⊙ (scale · Σ_k m_k)).astype(p.dtype)

    with ``Σ_k m_k`` the per-element client count read straight off the
    (K, W) packed rows (signed mode: Σ ±1 via the 2c − K identity).  On
    the pallas backend this is one ``kernels/mask_uplink`` kernel pass —
    no unpacked bit tensor, no materialized count tree, no separate
    elementwise update sweep.  Equal to ``mix_add(params,
    noise ⊙ (scale · tree_unpack_counts(...)))`` leaf by leaf.
    """
    backend = resolve_backend(backend)
    from ..kernels.mask_uplink.ops import unpack_counts_apply
    K = words.shape[0]
    a, b = (2.0, float(-K)) if mode == "signed" else (1.0, 0.0)
    noise_flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32)
         for l in jax.tree_util.tree_leaves(noise)])
    base_flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32)
         for l in jax.tree_util.tree_leaves(params)])
    out = unpack_counts_apply(words, noise_flat, base_flat, scale, a, b,
                              use_pallas=(backend == "pallas"),
                              interpret=pallas_interpret())
    upd = tree_split_flat(out, params)
    return jax.tree_util.tree_map(lambda p, o: o.astype(p.dtype),
                                  params, upd)


def pack_lastdim(bits: jax.Array) -> jax.Array:
    """Pack {0,1} bits along the LAST dim into uint32 words: (..., D) →
    (..., ceil(D/32)).

    Unlike :func:`pack_bits` this preserves leading dims — and therefore
    their shardings — which is what the sharded pod round needs: each model
    shard packs its own slice, so the packed payload stays model-sharded
    and the client-axis all-gather moves exactly 1 bit per parameter.
    """
    D = bits.shape[-1]
    pad = (-D) % WORD
    x = bits.astype(jnp.uint32)
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x = x.reshape(x.shape[:-1] + (-1, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(x << shifts, axis=-1, dtype=jnp.uint32)


def unpack_lastdim(words: jax.Array, D: int) -> jax.Array:
    """Inverse of :func:`pack_lastdim`; returns {0,1} int8 (..., D)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))
    return flat[..., :D].astype(jnp.int8)


def payload_bits(words: jax.Array) -> int:
    """Wire size of a packed payload in bits."""
    return int(words.size) * WORD


def tree_num_params(tree: Pytree) -> int:
    return sum(tree_bit_sizes(tree))
