"""1-bit mask packing — the wire format of the FedMRN uplink.

A mask tensor (values {0,1} or {-1,+1}) is flattened, padded to a multiple of
32, and packed little-endian into ``uint32`` words.  Signed masks map
-1 → bit 0, +1 → bit 1 (the paper's identity G⊙m_s = 2G⊙m − G makes the two
formats interconvertible).  Packing is what makes the collective/uplink cost
literally 1 bit per parameter — these arrays are what we all-gather across
the client axis in the sharded round and what the comm model counts.

Pure ``jnp`` (no host round-trip) so it stays inside jit/pjit programs.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
WORD = 32


def packed_len(n_bits: int) -> int:
    return (n_bits + WORD - 1) // WORD


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a {0,1}-valued array (any shape) into a 1-D uint32 array.

    bit i of word w corresponds to flat element w*32+i (little-endian).
    """
    flat = bits.reshape(-1).astype(jnp.uint32)
    n = flat.shape[0]
    pad = (-n) % WORD
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint32)])
    words = flat.reshape(-1, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.bitwise_or.reduce(words << shifts, axis=1)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns {0,1} int8 of length ``n_bits``."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n_bits].astype(jnp.int8)


def pack_mask(mask: jax.Array, *, mode: str = "binary") -> jax.Array:
    """Pack a mask tensor ({0,1} binary or {-1,1} signed) to uint32 words."""
    if mode == "binary":
        bits = (mask > 0)
    elif mode == "signed":
        bits = (mask > 0)  # -1 → 0, +1 → 1
    else:
        raise ValueError(f"unknown mask mode {mode!r}")
    return pack_bits(bits)


def unpack_mask(words: jax.Array, n_bits: int, *, mode: str = "binary") -> jax.Array:
    bits = unpack_bits(words, n_bits)
    if mode == "binary":
        return bits
    return (2 * bits - 1).astype(jnp.int8)


# ---------------------------------------------------------------------------
# pytree wire format: one packed uint32 vector for the whole parameter pytree
# ---------------------------------------------------------------------------

def tree_bit_sizes(tree: Pytree):
    """Per-leaf element counts (static)."""
    return [math.prod(jnp.shape(l)) or 1 for l in jax.tree_util.tree_leaves(tree)]


def tree_pack(mask_tree: Pytree, *, mode: str = "binary") -> jax.Array:
    """Concatenate all leaves' bits into one padded uint32 payload."""
    leaves = jax.tree_util.tree_leaves(mask_tree)
    flat = jnp.concatenate(
        [(l > 0).reshape(-1) for l in leaves]
    )
    del mode  # both modes store sign bit identically
    return pack_bits(flat)


def tree_unpack(words: jax.Array, like: Pytree, *, mode: str = "binary") -> Pytree:
    """Unpack one payload into a mask pytree shaped like ``like``."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    sizes = [math.prod(jnp.shape(l)) or 1 for l in leaves]
    total = sum(sizes)
    bits = unpack_bits(words, total)
    if mode == "signed":
        bits = (2 * bits - 1).astype(jnp.int8)
    out, off = [], 0
    for leaf, sz in zip(leaves, sizes):
        out.append(bits[off: off + sz].reshape(jnp.shape(leaf)))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


def pack_lastdim(bits: jax.Array) -> jax.Array:
    """Pack {0,1} bits along the LAST dim into uint32 words: (..., D) →
    (..., ceil(D/32)).

    Unlike :func:`pack_bits` this preserves leading dims — and therefore
    their shardings — which is what the sharded pod round needs: each model
    shard packs its own slice, so the packed payload stays model-sharded
    and the client-axis all-gather moves exactly 1 bit per parameter.
    """
    D = bits.shape[-1]
    pad = (-D) % WORD
    x = bits.astype(jnp.uint32)
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x = x.reshape(x.shape[:-1] + (-1, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(x << shifts, axis=-1, dtype=jnp.uint32)


def unpack_lastdim(words: jax.Array, D: int) -> jax.Array:
    """Inverse of :func:`pack_lastdim`; returns {0,1} int8 (..., D)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))
    return flat[..., :D].astype(jnp.int8)


def payload_bits(words: jax.Array) -> int:
    """Wire size of a packed payload in bits."""
    return int(words.size) * WORD


def tree_num_params(tree: Pytree) -> int:
    return sum(tree_bit_sizes(tree))
