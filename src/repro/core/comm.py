"""Communication-cost accounting (uplink/downlink, bits per parameter).

Mirrors the paper's §5.1.3 accounting: FedMRN/FedPM/SignSGD/EDEN/DRIVE are
1 bpp uplink; TernGrad log2(3); Top-k/FedSparsify 32·density (paper ignores
index overhead — we report both exact and paper-style figures).
Downlink is uncompressed float32 for every method, as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CommRecord:
    method: str
    params: int
    uplink_bits: int          # exact, incl. headers/seeds/indices
    uplink_bits_paper: int    # paper-style (ignores index/header overhead)
    downlink_bits: int
    # distributed-DP accounting (fed/privacy): ε after the planned/run
    # rounds at dp_delta; inf/0.0 mean "no privacy mechanism was applied"
    dp_epsilon: float = math.inf
    dp_delta: float = 0.0
    # service-tier MEASURED wire overheads (0 for simulation engines):
    # serde frame bytes beyond payload, and downlink response framing
    framing_bits: int = 0
    downlink_overhead_bits: int = 0

    @property
    def uplink_bpp(self) -> float:
        return self.uplink_bits / self.params

    @property
    def uplink_bpp_paper(self) -> float:
        return self.uplink_bits_paper / self.params

    @property
    def compression_x(self) -> float:
        return 32.0 * self.params / self.uplink_bits

    def row(self) -> Dict[str, Any]:
        """One table row: exact AND paper-style uplink, plus downlink."""
        return dict(
            method=self.method, params=self.params,
            uplink_bpp=round(self.uplink_bpp, 4),
            uplink_bpp_paper=round(self.uplink_bpp_paper, 4),
            uplink_MB=round(self.uplink_bits / 8e6, 4),
            downlink_bits=self.downlink_bits,
            compression_x=round(self.compression_x, 2),
            framing_bits=self.framing_bits,
            downlink_overhead_bits=self.downlink_overhead_bits,
            dp_epsilon=(round(self.dp_epsilon, 4)
                        if math.isfinite(self.dp_epsilon) else math.inf),
            dp_delta=self.dp_delta,
        )


def fedmrn_record(params: int) -> CommRecord:
    """Packed masks (padded to 32-bit words) + ONE 64-bit seed per
    client-round.

    The seed is per-CLIENT, not per-leaf: the server regenerates every
    leaf's noise from the one key via the ``fold_in`` chain
    (``core/noise.py``), so no per-leaf headers exist — this matches
    exactly what ``repro.fed.codecs.MaskCodec.wire_bits`` measures from
    the encoded buffers (asserted in ``tests/test_codecs.py``).  The old
    ``n_leaves`` kwarg was dead and is gone.
    """
    words = (params + 31) // 32
    exact = words * 32 + 64
    return CommRecord("fedmrn", params, exact, params, 32 * params)


def baseline_record(method: str, params: int, n_leaves: int,
                    *, topk_frac: float = 0.03,
                    qsgd_bits: int = 2) -> CommRecord:
    m = method.lower()
    if m in ("none", "fedavg"):
        bits = 32 * params
        return CommRecord("fedavg", params, bits, bits, bits)
    if m in ("signsgd", "stochsign", "drive", "eden", "fedpm", "post_sm"):
        exact = params + 32 * max(n_leaves, 1)
        return CommRecord(m, params, exact, params, 32 * params)
    if m == "terngrad":
        bpp = math.log2(3)
        exact = int(params * bpp) + 32 * max(n_leaves, 1)
        return CommRecord(m, params, exact, int(params * bpp), 32 * params)
    if m in ("topk", "fedsparsify"):
        kept = int(math.ceil(topk_frac * params))
        idx = max(1, math.ceil(math.log2(max(params, 2))))
        exact = kept * (32 + idx)
        return CommRecord(m, params, exact, kept * 32, 32 * params)
    if m == "qsgd":
        exact = params * qsgd_bits + 32 * max(n_leaves, 1)
        return CommRecord(m, params, exact, params * qsgd_bits, 32 * params)
    raise ValueError(f"unknown method {method!r}")
