"""Arch-id → config lookup for ``--arch <id>``."""
from __future__ import annotations

import importlib

ARCH_IDS = {
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama3.2-1b": "llama3_2_1b",
    "granite-3-2b": "granite_3_2b",
}


def list_archs():
    return sorted(ARCH_IDS)


def get_config(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch_id]}")
    return mod.CONFIG
