"""RWKV6-3B "Finch" [ssm]: attention-free, data-dependent decay
[arXiv:2404.05892]. 32L d=2560 d_ff=8960 V=65536. O(1) decode state."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", arch_type="ssm",
    num_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
    num_heads=0, num_kv_heads=0,   # attention-free (RWKV6 mixer)
)
