"""Granite-3.0-2B [dense]: GQA, tied embeddings
[hf:ibm-granite/granite-3.0-2b-base].
40L d=2048 32H (GQA kv=8) d_ff=8192 V=49155."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", arch_type="dense",
    num_layers=40, d_model=2048, d_ff=8192, vocab_size=49155,
    num_heads=32, num_kv_heads=8,
    tie_embeddings=True,
)
