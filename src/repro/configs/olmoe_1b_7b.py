"""OLMoE-1B-7B [moe]: 64 experts top-8 [arXiv:2409.02060].
16L d=2048 16H (kv=16) expert d_ff=1024 V=50304."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", arch_type="moe",
    num_layers=16, d_model=2048, d_ff=1024, vocab_size=50304,
    num_heads=16, num_kv_heads=16,
    num_experts=64, top_k=8,
)
