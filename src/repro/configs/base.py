"""Model/architecture configuration and the assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object covers every assigned architecture family."""

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # --- attention (0 heads ⇒ attention-free) -----------------------------
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # default d_model // num_heads
    qkv_bias: bool = False
    sliding_window: int = 0           # 0 ⇒ full attention
    rope_theta: float = 1e4
    mrope: bool = False               # qwen2-vl 3-section M-RoPE
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    # --- hybrid (zamba2): shared attention block every N mamba blocks -------
    attn_every: int = 0
    # --- enc-dec (seamless) ---------------------------------------------------
    encoder_layers: int = 0
    # --- modality frontends (STUBS per spec: embeddings provided) ------------
    modality: str = "text"            # text | vision | audio
    frontend_tokens: int = 0          # patches/frames consumed per sample
    # --- numerics -------------------------------------------------------------
    dtype: Any = jnp.float32
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.arch_type not in ARCH_TYPES:
            raise ValueError(f"bad arch_type {self.arch_type}")
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k decode (bounded per-token state)."""
        return self.arch_type in ("ssm", "hybrid") or (
            self.sliding_window > 0 and self.arch_type == "dense")

    def reduced(self, *, layers: int = 2, d_model: int = 256,
                experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """CPU-smoke-test variant of the same family (spec: ≤2L, d≤512)."""
        heads = 0 if self.attention_free else max(2, min(4, self.num_heads))
        kv = 0 if self.attention_free else max(
            1, heads * max(1, self.num_kv_heads) // max(1, self.num_heads))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            encoder_layers=min(self.encoder_layers, layers),
            d_model=d_model,
            d_ff=2 * d_model,
            vocab_size=vocab,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads if heads else 0,
            num_experts=min(self.num_experts, experts) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            frontend_tokens=min(self.frontend_tokens, 16),
            dtype=jnp.float32,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runs?, reason) — the skip policy documented in DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k dense KV cache is the "
                       "quadratic-memory regime the spec says to skip")
    return True, ""
