"""SeamlessM4T-medium [audio]: enc-dec, multimodal [arXiv:2308.11596].
12L enc + 12L dec, d=1024 16H (kv=16) d_ff=4096 V=256206.
Audio frontend is a STUB: input_specs provides frame embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", arch_type="audio",
    num_layers=12, encoder_layers=12,
    d_model=1024, d_ff=4096, vocab_size=256206,
    num_heads=16, num_kv_heads=16,
    modality="audio",
)
