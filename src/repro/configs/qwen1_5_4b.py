"""Qwen1.5-4B [dense]: QKV bias [hf:Qwen/Qwen1.5-0.5B].
40L d=2560 20H (kv=20, head_dim=128) d_ff=6912 V=151936."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", arch_type="dense",
    num_layers=40, d_model=2560, d_ff=6912, vocab_size=151936,
    num_heads=20, num_kv_heads=20,
    qkv_bias=True,
)
