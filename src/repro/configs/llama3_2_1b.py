"""Llama-3.2-1B [dense] [hf:meta-llama/Llama-3.2-1B].
16L d=2048 32H (GQA kv=8) d_ff=8192 V=128256."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", arch_type="dense",
    num_layers=16, d_model=2048, d_ff=8192, vocab_size=128256,
    num_heads=32, num_kv_heads=8, rope_theta=5e5,
)
