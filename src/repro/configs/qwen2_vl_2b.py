"""Qwen2-VL-2B [vlm]: M-RoPE + dynamic resolution [arXiv:2409.12191].
28L d=1536 12H (GQA kv=2, head_dim=128) d_ff=8960 V=151936.
Vision frontend is a STUB: input_specs provides patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", arch_type="vlm",
    num_layers=28, d_model=1536, d_ff=8960, vocab_size=151936,
    num_heads=12, num_kv_heads=2,
    mrope=True, modality="vision", frontend_tokens=256, rope_theta=1e6,
)
