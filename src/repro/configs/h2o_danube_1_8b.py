"""H2O-Danube-1.8B [dense]: llama+mistral mix with sliding-window attention
[arXiv:2401.16818]. 24L d=2560 32H (GQA kv=8) d_ff=6912 V=32000, SWA=4096.
The SWA window bounds the decode KV to O(window) — long_500k eligible."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", arch_type="dense",
    num_layers=24, d_model=2560, d_ff=6912, vocab_size=32000,
    num_heads=32, num_kv_heads=8,
    sliding_window=4096,
)
