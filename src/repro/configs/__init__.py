"""Architecture configs: one module per assigned arch (+ paper models)."""
from .base import INPUT_SHAPES, InputShape, ModelConfig, shape_applicable  # noqa: F401
from .registry import ARCH_IDS, get_config, list_archs  # noqa: F401
