"""Zamba2-1.2B [hybrid]: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. 38L d=2048 32H (kv=32) d_ff=8192 V=32000 ssm_state=64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    num_layers=38, d_model=2048, d_ff=8192, vocab_size=32000,
    num_heads=32, num_kv_heads=32,
    ssm_state=64, attn_every=6,
)
