"""Qwen3-MoE-235B-A22B [moe]: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
94L d=4096 64H (GQA kv=4, head_dim=128) expert d_ff=1536 V=151936."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    num_layers=94, d_model=4096, d_ff=1536, vocab_size=151936,
    num_heads=64, num_kv_heads=4, head_dim=128,
    num_experts=128, top_k=8, rope_theta=1e6,
)
