"""Pytree checkpointing: npz payload + structure manifest, no extra deps.

Saves any pytree of arrays (params, optimizer state, masks, RNG keys) with
path-derived keys; restore rebuilds the exact pytree (shapes, dtypes,
structure validated).  Atomic on POSIX (write-temp + rename).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

Pytree = Any
_MANIFEST = "__manifest__"


def _flatten_with_paths(tree: Pytree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append((key, leaf))
    return leaves, flat[1]


def save(path: str, tree: Pytree) -> None:
    leaves, treedef = _flatten_with_paths(tree)
    payload = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == np.dtype("bfloat16"):
            payload[f"{i:05d}|bf16|{key}"] = arr.astype(np.float32)
        else:
            payload[f"{i:05d}|raw|{key}"] = arr
    manifest = json.dumps({"treedef": str(treedef),
                           "n_leaves": len(leaves)})
    payload[_MANIFEST] = np.frombuffer(manifest.encode(), dtype=np.uint8)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (validates leaf count/shape)."""
    with np.load(path) as z:
        keys = sorted(k for k in z.files if k != _MANIFEST)
        arrs = []
        for k in keys:
            a = z[k]
            if "|bf16|" in k:
                a = a.astype(jax.numpy.bfloat16)
            arrs.append(a)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(arrs) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(arrs)} leaves; target has {len(leaves)}")
    out = []
    for a, l in zip(arrs, leaves):
        if tuple(a.shape) != tuple(jax.numpy.shape(l)):
            raise ValueError(f"shape mismatch {a.shape} vs {jax.numpy.shape(l)}")
        out.append(jax.numpy.asarray(a, dtype=l.dtype if hasattr(l, "dtype")
                                     else a.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
