"""Minimal optimizer library: SGD(+momentum), AdamW, LR schedules.

(init, update) pairs over pytrees; no external deps.  ``update`` returns
(new_params, new_state).  Used by the centralized train driver and as the
server optimizer in federated mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jax.Array], Tuple[Pytree, Pytree]]
    # update(params, grads, state, step) -> (params, state)


def sgd(lr: float | Callable[[jax.Array], jax.Array],
        momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(params, grads, state, step):
        eta = lr_fn(step)
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new_p = jax.tree_util.tree_map(
                lambda p, g: p - eta * g, params, grads)
            return new_p, state
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, state, grads)
        new_p = jax.tree_util.tree_map(
            lambda p, m: p - eta * m, params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adamw(lr: float | Callable[[jax.Array], jax.Array],
          b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return (z, z)

    def update(params, grads, state, step):
        m, v = state
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree_util.tree_map(
            lambda a, g: b1 * a + (1 - b1) * g.astype(jnp.float32), m, grads)
        v = jax.tree_util.tree_map(
            lambda a, g: b2 * a + (1 - b2) *
            jnp.square(g.astype(jnp.float32)), v, grads)
        eta = lr_fn(step)

        def upd(p, mi, vi):
            mh = mi / (1 - b1 ** t)
            vh = vi / (1 - b2 ** t)
            step_ = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * step_).astype(p.dtype)

        new_p = jax.tree_util.tree_map(upd, params, m, v)
        return new_p, (m, v)

    return Optimizer(init, update)


def cosine_schedule(base_lr: float, total_steps: int,
                    warmup: int = 0, final_frac: float = 0.1):
    def lr_fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) /
                        jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return base_lr * warm * cos
    return lr_fn
