"""Parameter / activation sharding rules → PartitionSpecs.

Strategy (DESIGN.md §5):
  - tensor-parallel orientation for the big matmuls over the 'model' axis
    (col-parallel in-projections, row-parallel out-projections, experts
    expert-parallel over 'model');
  - ZeRO/FSDP: remaining large params additionally sharded over the data
    axes on their largest divisible dimension in *train* mode;
  - everything else replicated (norms, small biases);
  - activations: batch over ('pod','data'); long_500k (batch=1) decode
    shards the cache over 'model' instead.

Rules are path-pattern based so every arch family (attn/moe/mamba/rwkv/
enc-dec) is covered by one table; the fallback shards the largest
divisible axis.  Any leaf can be overridden by an explicit entry.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# (path regex, dim → axis name) — dim indexes AFTER the stacked-layer axis
# is skipped (we detect the leading L axis by rank mismatch).
_RULES: Sequence[Tuple[str, Dict[int, str]]] = (
    # --- attention ---------------------------------------------------------
    (r"attn/w[qkv]$|self/w[qkv]$|cross/w[qkv]$", {1: "model"}),
    (r"attn/wo$|self/wo$|cross/wo$", {0: "model"}),
    (r"attn/b[qkv]$|self/b[qkv]$|cross/b[qkv]$", {0: "model"}),
    # --- dense mlp ----------------------------------------------------------
    (r"mlp/(gate|up|in)$", {1: "model"}),
    (r"mlp/(down|out)$", {0: "model"}),
    # --- moe: expert-parallel over 'model' ----------------------------------
    (r"moe/router$", {}),
    (r"moe/(gate|up|down)$", {0: "model"}),
    # --- mamba2 --------------------------------------------------------------
    (r"mixer/in_proj$", {0: "model"}),
    (r"mixer/out_proj$", {1: "model"}),
    (r"mixer/(conv_w|conv_b|dt_bias|A_log|D)$", {}),
    # --- rwkv6 ---------------------------------------------------------------
    (r"mixer/w[rkvg]$", {1: "model"}),
    (r"mixer/wo$", {0: "model"}),
    (r"mixer/(w_lora_a|w_lora_b|w0|u|mu)$", {}),
    (r"mlp/w[kr]$", {1: "model"}),
    (r"mlp/wv$", {0: "model"}),
    # --- zamba2 shared block --------------------------------------------------
    (r"shared/pre_proj$", {1: "model"}),
    # --- embeddings / head ----------------------------------------------------
    (r"embed/tok$", {0: "model"}),
    (r"^head$", {1: "model"}),
    # --- norms & everything small ----------------------------------------------
    (r"ln|norm|scale|bias|gamma|beta", {}),
)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape.keys())


def param_spec(path, leaf, mesh: Mesh, *, stacked: bool, zero: bool,
               min_zero_size: int = 1 << 16,
               fsdp_axes: Optional[Tuple[str, ...]] = None) -> P:
    """PartitionSpec for one param leaf.

    stacked: leaf has a leading layer axis (dim 0) that stays unsharded.
    zero: additionally shard over the data axes (train mode).
    fsdp_axes: override which mesh axes ZeRO-shards use (default: all of
    ('pod','data') present in the mesh; the fedmrn round excludes its
    client axis).
    """
    shape = jax.numpy.shape(leaf)
    rank = len(shape)
    off = 1 if stacked and rank >= 2 else 0
    spec = [None] * rank
    pstr = _path_str(path)
    matched = False
    for pat, dims in _RULES:
        if re.search(pat, pstr):
            matched = True
            for dim, axis in dims.items():
                d = dim + off
                if d < rank and shape[d] % _axis_size(mesh, axis) == 0:
                    spec[d] = axis
            break
    if not matched:
        # fallback: largest divisible dim over 'model'
        order = sorted(range(off, rank), key=lambda d: -shape[d])
        for d in order:
            if shape[d] % _axis_size(mesh, "model") == 0:
                spec[d] = "model"
                break
    if zero and sum(1 for s in shape) and _nelem(shape) >= min_zero_size:
        fs = _fsdp_axes(mesh) if fsdp_axes is None else fsdp_axes
        if fs:
            need = 1
            for a in fs:
                need *= _axis_size(mesh, a)
            # largest still-free dim divisible by the full fsdp extent
            order = sorted((d for d in range(off, rank) if spec[d] is None),
                           key=lambda d: -shape[d])
            for d in order:
                if shape[d] % need == 0:
                    spec[d] = fs if len(fs) > 1 else fs[0]
                    break
    return P(*spec)


def _nelem(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def _is_stacked(path, leaf, num_layers: int) -> bool:
    pstr = _path_str(path)
    shape = jax.numpy.shape(leaf)
    under = re.search(r"layers|mamba|^enc/|^dec/|/enc/|/dec/", pstr)
    return bool(under) and len(shape) >= 1 and shape[0] == num_layers


def param_shardings(param_tree: Pytree, mesh: Mesh, *, num_layers: int,
                    encoder_layers: int = 0, zero: bool = False,
                    fsdp_axes: Optional[Tuple[str, ...]] = None) -> Pytree:
    """NamedSharding pytree matching ``param_tree`` (specs or arrays)."""

    def one(path, leaf):
        pstr = _path_str(path)
        L = num_layers
        if re.search(r"^enc/|/enc/", pstr) and encoder_layers:
            L = encoder_layers
        stacked = _is_stacked(path, leaf, L)
        return NamedSharding(mesh, param_spec(path, leaf, mesh,
                                              stacked=stacked, zero=zero,
                                              fsdp_axes=fsdp_axes))

    return jax.tree_util.tree_map_with_path(one, param_tree)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh) -> Any:
    fs = _fsdp_axes(mesh)
    return fs if len(fs) > 1 else (fs[0] if fs else None)


def batch_shardings(batch: Pytree, mesh: Mesh, *, batch_dividable: bool = True
                    ) -> Pytree:
    """Shard dim 0 (batch) over the data axes; positions3 dim 1."""
    ba = _batch_axes(mesh)

    def one(path, leaf):
        pstr = _path_str(path)
        shape = jax.numpy.shape(leaf)
        need = 1
        fs = _fsdp_axes(mesh)
        for a in fs:
            need *= _axis_size(mesh, a)
        spec = [None] * len(shape)
        bdim = 1 if pstr.endswith("positions3") else 0
        if (batch_dividable and len(shape) > bdim
                and shape[bdim] % max(need, 1) == 0 and need > 1):
            spec[bdim] = ba
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(cache: Pytree, mesh: Mesh, *, batch: int) -> Pytree:
    """Decode caches: batch over data axes when divisible, else shard the
    largest head/feature dim over 'model' (long_500k, batch=1)."""
    fs = _fsdp_axes(mesh)
    need = 1
    for a in fs:
        need *= _axis_size(mesh, a)
    ba = _batch_axes(mesh)
    msize = _axis_size(mesh, "model")

    def one(leaf):
        shape = jax.numpy.shape(leaf)
        rank = len(shape)
        spec = [None] * rank
        # cache leaves are stacked (L, B, ...) or scalar steps
        if rank >= 2 and shape[1] == batch and batch % max(need, 1) == 0 \
                and need > 1:
            spec[1] = ba
        if rank == 5:
            # attention KV cache (L, B, T, KV, hd): shard KV heads when
            # divisible, else the time dim (sequence-parallel decode) —
            # sharding hd conflicts with the decode dot's preferred
            # sharding and triggers per-layer full rematerialisation
            if shape[3] % msize == 0:
                spec[3] = "model"
            elif shape[2] % msize == 0:
                spec[2] = "model"
            elif shape[4] % msize == 0:
                spec[4] = "model"
        else:
            for d in range(rank - 1, 1, -1):
                if spec[d] is None and shape[d] % msize == 0 \
                        and shape[d] >= msize:
                    spec[d] = "model"
                    break
        if rank == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache)
