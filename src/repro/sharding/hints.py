"""Activation-sharding hints.

Model code is mesh-agnostic; the launcher installs the active mesh here and
the models call :func:`hint_batch` on the tensors whose sharding XLA's SPMD
propagation otherwise gets wrong (observed: scan-stacked checkpoint saves
and xent chunks materialising with GLOBAL batch — 8.6 GB/device buffers —
because nothing constrained their batch dim to the data axes).

No-ops when no mesh is installed (CPU smoke tests, simulation engine).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _get() -> Tuple[Optional[Mesh], Tuple[str, ...]]:
    return (getattr(_STATE, "mesh", None), getattr(_STATE, "batch_axes", ()))


@contextmanager
def mesh_context(mesh: Optional[Mesh], batch_axes: Optional[tuple] = None):
    """Install the active mesh.  ``batch_axes`` overrides which mesh axes
    activation batch dims shard over — the FedMRN pod round must EXCLUDE
    its client axis (clients train independently; constraining activations
    over the client axis drags them across the slow inter-client links)."""
    old = _get()
    if mesh is None:
        _STATE.mesh, _STATE.batch_axes = None, ()
    else:
        _STATE.mesh = mesh
        _STATE.batch_axes = (tuple(batch_axes) if batch_axes is not None
                             else tuple(a for a in ("pod", "data")
                                        if a in mesh.shape))
    try:
        yield
    finally:
        _STATE.mesh, _STATE.batch_axes = old


def hint_batch(x: jax.Array, bdim: int = 0) -> jax.Array:
    """Constrain dim ``bdim`` to the data axes (if divisible)."""
    mesh, axes = _get()
    if mesh is None or not axes or x.ndim <= bdim:
        return x
    need = 1
    for a in axes:
        need *= mesh.shape[a]
    if x.shape[bdim] % need:
        return x
    spec = [None] * x.ndim
    spec[bdim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def current_mesh():
    """(mesh, batch_axes) — (None, ()) when nothing installed."""
    return _get()


def model_axis_size() -> int:
    """Size of the 'model' mesh axis (1 when no mesh installed)."""
    mesh, _ = _get()
    if mesh is None or "model" not in mesh.shape:
        return 1
    return mesh.shape["model"]


def batch_axes_size() -> int:
    mesh, axes = _get()
    if mesh is None:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def hint_spec(x: jax.Array, spec_dims: dict) -> jax.Array:
    """Constrain selected dims: {dim: 'model'|'batch'}; others replicated.

    Skips the constraint entirely if any requested dim is not divisible.
    """
    mesh, axes = _get()
    if mesh is None:
        return x
    spec = [None] * x.ndim
    for d, kind in spec_dims.items():
        if kind == "batch":
            need = batch_axes_size()
            if need <= 1 or x.shape[d] % need:
                continue
            spec[d] = axes if len(axes) > 1 else axes[0]
        else:
            if "model" not in mesh.shape or x.shape[d] % mesh.shape["model"]:
                continue
            spec[d] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def hint(x: jax.Array, *spec) -> jax.Array:
    """Raw constraint with explicit per-dim axis names (None = replicated)."""
    mesh, _ = _get()
    if mesh is None:
        return x
    clean = tuple(s if (s is None or
                        all(a in mesh.shape for a in
                            (s if isinstance(s, tuple) else (s,))))
                  else None for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))
