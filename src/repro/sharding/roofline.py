"""Roofline synthesis: dry-run records → three-term analysis (§Roofline).

  compute    = HLO_FLOPs(per chip)        / peak_FLOP/s      (197e12 bf16)
  memory     = HLO_bytes(per chip)        / HBM_bw           (819e9)
  collective = collective_bytes(per chip) / ICI link bw      (50e9)

HLO terms come from ``hlo_analysis`` (loop-trip-count-aware walk of the
compiled module — XLA's aggregate cost_analysis drops loop trip counts).
MODEL_FLOPS is the analytic 6·N·D / 2·N·D / 2·N_active·B reference; the
ratio MODEL_FLOPS / HLO_FLOPs is the "useful compute" fraction that makes
remat/causal-rectangle/replication waste visible.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Dict, Optional

from ..configs.base import INPUT_SHAPES, InputShape, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg: ModelConfig, total: int) -> int:
    """Per-token active params (MoE: top_k of E experts)."""
    if not cfg.num_experts:
        return total
    expert_params = (cfg.num_layers * cfg.num_experts *
                     3 * cfg.d_model * cfg.d_ff)
    dense_part = total - expert_params
    return dense_part + expert_params * cfg.top_k // cfg.num_experts


def model_flops(cfg: ModelConfig, shape: InputShape, total_params: int
                ) -> float:
    """Analytic global FLOPs per step (matmul-only reference)."""
    n_act = active_params(cfg, total_params)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence (+ attention cache reads are
    # bandwidth, not FLOPs, at B·T·d_kv scale)
    return 2.0 * n_act * shape.global_batch


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops: float
    model_flops_per_chip: float
    useful_ratio: float
    fits: bool
    by_collective: Dict[str, float]

    def row(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "dominant": self.dominant,
            "useful_ratio": round(self.useful_ratio, 3),
            "fits_v5e": self.fits,
        }


def from_record(rec: Dict[str, Any], cfg: Optional[ModelConfig] = None
                ) -> Optional[Roofline]:
    if rec.get("status") != "ok":
        return None
    h = rec["hlo_analysis"]
    n_chips = rec["n_chips"]
    shape = INPUT_SHAPES[rec["shape"]]
    mf = (model_flops(cfg, shape, rec["params"]) / n_chips
          if cfg is not None else 0.0)
    compute_s = h["flops"] / PEAK_FLOPS
    memory_s = h["hbm_bytes"] / HBM_BW
    collective_s = h["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dom = max(terms, key=terms.get)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom, hlo_flops=h["flops"], model_flops_per_chip=mf,
        useful_ratio=(mf / h["flops"]) if h["flops"] else 0.0,
        fits=rec["memory"]["fits_v5e"],
        by_collective=h.get("by_collective", {}),
    )


def load_all(dryrun_dir: str):
    from ..configs import get_config
    out = []
    for fn in sorted(os.listdir(dryrun_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, fn)) as f:
            rec = json.load(f)
        cfg = None
        try:
            cfg = get_config(rec["arch"])
        except Exception:
            pass
        r = from_record(rec, cfg)
        if r is not None:
            out.append((rec, r))
    return out
