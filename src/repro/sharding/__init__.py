"""Sharding rules + HLO static cost analysis."""
