"""Static analyzer for optimized HLO text → roofline terms.

Why not ``compiled.cost_analysis()``?  XLA's aggregate cost analysis counts
a ``while`` body ONCE — but our production programs are scan-over-layers
(and scan-over-chunks inside attention), so virtually all FLOPs live inside
nested loops whose trip counts the aggregate numbers drop (verified
empirically: an 8-layer scanned MLP reports exactly 1 layer of FLOPs).

This module re-derives per-device costs by walking the HLO call graph and
multiplying every computation's cost by the trip counts of its enclosing
loops:

  flops        — dot ops (2·|out|·|contraction|), including inside fusions
  hbm bytes    — operands+outputs of *materializing* top-level ops
                 (fusion internals excluded: fused ops don't touch HBM)
  collective   — per-type byte totals with ring-model per-device traffic:
                   all-gather       out·(g-1)/g
                   reduce-scatter   in·(g-1)/g
                   all-reduce       2·in·(g-1)/g
                   all-to-all       in·(g-1)/g
                   collective-permute  in
Trip counts come from the loop-condition comparison constant (scan lowers
to a while with a 0..N counter; we take the max s32/u32 constant compared
in the condition — exact for scan-generated loops).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 1
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shape: str          # result shape string
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    by_name: Dict[str, Op]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    cross_pod_bytes: float = 0.0   # collectives whose groups span pods

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_count += int(other.collective_count * mult)
        self.cross_pod_bytes += other.cross_pod_bytes * mult
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + v * mult

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_count": self.collective_count,
            "cross_pod_bytes": self.cross_pod_bytes,
            "by_collective": {k: round(v) for k, v in
                              sorted(self.by_collective.items())},
        }


# group 2 (result shape) is matched lazily: tuple shapes can contain
# /*index=N*/ comments (with '='!) and layout braces, so we accept anything
# up to the first `opname(` — no parens occur inside shape strings, so the
# first word-followed-by-( is always the op kind.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s*([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                if line.lstrip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, shape, kind, rest = m.groups()
        operands = re.findall(r"%([\w.\-]+)", rest)
        op = Op(name, kind, shape, line, operands)
        cur.ops.append(op)
        cur.by_name[name] = op
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Max integer constant compared in the loop condition (exact for scan)."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(line: str, default: int = 1) -> int:
    # v2 format: replica_groups=[ngroups,gsize]<=[total]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+", line)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _spans_pods(line: str, pod_size: int = 256) -> bool:
    """True when the collective's replica groups contain devices from
    different pods (device id // pod_size differs within a group)."""
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?",
        line)
    if m:
        ng, gs, dims_s, perm_s = m.groups()
        import numpy as _np
        dims = [int(d) for d in dims_s.split(",")]
        total = 1
        for d in dims:
            total *= d
        if total <= pod_size:
            return False
        devs = _np.arange(total).reshape(dims)
        if perm_s:
            devs = devs.transpose([int(p) for p in perm_s.split(",")])
        groups = devs.reshape(int(ng), int(gs))
        return bool((_np.ptp(groups // pod_size, axis=1) > 0).any())
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        return len({i // pod_size for i in ids}) > 1
    return False


def _operand_shapes(op: Op, comp: Computation) -> List[str]:
    """Inline shapes if printed, else look up defs in the computation."""
    inline = re.findall(r"((?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+%[\w.\-]+",
                        op.line.split("(", 1)[1] if "(" in op.line else "")
    if inline:
        return inline
    out = []
    for name in op.operands:
        d = comp.by_name.get(name)
        if d is not None:
            out.append(d.shape)
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = shape_elems(op.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    ops_shapes = _operand_shapes(op, comp)
    if not m or not ops_shapes:
        return 2.0 * out_elems  # degenerate
    lhs_dims_m = _SHAPE_RE.search(ops_shapes[0])
    if not lhs_dims_m:
        return 2.0 * out_elems
    dims = ([int(d) for d in lhs_dims_m.group(2).split(",")]
            if lhs_dims_m.group(2) else [])
    k = 1
    for ci in (int(c) for c in m.group(1).split(",") if c):
        if ci < len(dims):
            k *= dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = shape_elems(op.shape)
    shapes = _operand_shapes(op, comp)
    if len(shapes) >= 2:
        kernel = shape_elems(shapes[1])
        m = _SHAPE_RE.search(shapes[1])
        # 2 * out * (kernel spatial*in_ch) = 2*out*kernel_elems/out_ch
        if m and m.group(2):
            out_ch = int(m.group(2).split(",")[-1])
            return 2.0 * out_elems * kernel / max(out_ch, 1)
    return 2.0 * out_elems


_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations|"
    r"true_computation|false_computation)=\{?%?([\w.\-, %]+)\}?")

_SKIP_BYTES_KINDS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _cost_of(comp: Computation, comps: Dict[str, Computation],
             memo: Dict[Tuple[str, bool], Cost], *,
             inside_fusion: bool) -> Cost:
    key = (comp.name, inside_fusion)
    if key in memo:
        return memo[key]
    total = Cost()
    memo[key] = total  # guard (HLO call graphs are acyclic; safe placeholder)
    for op in comp.ops:
        if op.kind == "dot":
            total.flops += _dot_flops(op, comp)
        elif op.kind == "convolution":
            total.flops += _conv_flops(op, comp)
        elif op.kind in _COLLECTIVES:
            g = _group_size(op.line)
            opshapes = _operand_shapes(op, comp)
            in_b = sum(shape_bytes(s) for s in opshapes) or shape_bytes(
                op.shape)
            out_b = shape_bytes(op.shape)
            frac = (g - 1) / g if g > 1 else 0.0
            if op.kind == "all-gather":
                b = out_b * frac
            elif op.kind == "reduce-scatter":
                b = in_b * frac
            elif op.kind == "all-reduce":
                b = 2.0 * in_b * frac
            elif op.kind == "all-to-all":
                b = in_b * frac
            else:  # collective-permute
                b = in_b
            total.collective_bytes += b
            total.collective_count += 1
            total.by_collective[op.kind] = (
                total.by_collective.get(op.kind, 0.0) + b)
            if _spans_pods(op.line):
                total.cross_pod_bytes += b
            if not inside_fusion:
                total.hbm_bytes += in_b + out_b
        if op.kind == "while":
            body_name = re.search(r"body=%?([\w.\-]+)", op.line)
            cond_name = re.search(r"condition=%?([\w.\-]+)", op.line)
            # XLA annotates scan-derived loops with the exact trip count
            m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
            if m:
                trips = int(m.group(1))
            else:
                trips = 1
                if cond_name and cond_name.group(1) in comps:
                    trips = _trip_count(comps[cond_name.group(1)])
            if body_name and body_name.group(1) in comps:
                total.add(_cost_of(comps[body_name.group(1)], comps, memo,
                                   inside_fusion=inside_fusion), trips)
            continue
        if op.kind == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.line)
            if m and m.group(1) in comps:
                total.add(_cost_of(comps[m.group(1)], comps, memo,
                                   inside_fusion=True))
            if not inside_fusion:
                opshapes = _operand_shapes(op, comp)
                total.hbm_bytes += (sum(shape_bytes(s) for s in opshapes)
                                    + shape_bytes(op.shape))
            continue
        if op.kind == "conditional":
            branches = re.findall(
                r"(?:branch_computations=\{([^}]*)\}|"
                r"true_computation=%?([\w.\-]+)|"
                r"false_computation=%?([\w.\-]+))", op.line)
            names: List[str] = []
            for tup in branches:
                for t in tup:
                    if t:
                        names.extend(n.strip().lstrip("%")
                                     for n in t.split(","))
            if names:
                # runtime executes ONE branch: take the max-cost branch
                sub = [_cost_of(comps[n], comps, memo,
                                inside_fusion=inside_fusion)
                       for n in names if n in comps]
                if sub:
                    best = max(sub, key=lambda c: c.flops + c.hbm_bytes)
                    total.add(best)
            continue
        if op.kind in ("call", "custom-call"):
            m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
            if m and m.group(1) in comps:
                total.add(_cost_of(comps[m.group(1)], comps, memo,
                                   inside_fusion=inside_fusion))
        # ---- HBM bytes for materializing ops --------------------------------
        if (not inside_fusion and op.kind not in _SKIP_BYTES_KINDS
                and op.kind not in _COLLECTIVES and op.kind != "fusion"):
            opshapes = _operand_shapes(op, comp)
            total.hbm_bytes += (sum(shape_bytes(s) for s in opshapes)
                                + shape_bytes(op.shape))
    memo[key] = total
    return total


def analyze(hlo_text: str) -> Cost:
    """Per-device cost of the compiled module (SPMD: one partition)."""
    comps, entry = parse_hlo(hlo_text)
    if entry is None or entry not in comps:
        # fall back: largest computation
        if not comps:
            return Cost()
        entry = max(comps, key=lambda c: len(comps[c].ops))
    memo: Dict[Tuple[str, bool], Cost] = {}
    total = Cost()
    total.add(_cost_of(comps[entry], comps, memo, inside_fusion=False))
    return total
