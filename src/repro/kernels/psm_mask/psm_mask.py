"""Fused PSM (progressive stochastic masking) Pallas TPU kernel.

The PSM forward chain (Eq. 6/10 of the paper) is six elementwise ops —
prob = clip(u/n) → SM-Bernoulli → masked noise → clip(u, n) → PM-Bernoulli
→ select.  Executed as separate XLA ops this makes ~6 HBM round-trips over
tensors the size of the model; fused in one Pallas pass each element is
read once (u, n, two pre-drawn uniforms) and written once (û, mask).

Uniform randoms are generated OUTSIDE the kernel (jax.random, seeded — the
server must reproduce G(s) exactly, so RNG stays in the seeded-stream
world) and streamed in; the kernel fuses the arithmetic.

Layout: inputs are flattened to (R, 128·K) tiles; BlockSpec keeps
(BLOCK_R, BLOCK_C) tiles in VMEM — lane-dim multiples of 128 and sublane
multiples of 8, MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 64
BLOCK_C = 512
_EPS = 1e-30


def _psm_kernel(u_ref, n_ref, r_sm_ref, r_pm_ref, prog_ref,
                uhat_ref, mask_ref, *, mode: str):
    u = u_ref[...].astype(jnp.float32)
    n = n_ref[...].astype(jnp.float32)
    r_sm = r_sm_ref[...]
    r_pm = r_pm_ref[...]
    prog = prog_ref[0]

    safe_n = jnp.where(jnp.abs(n) < _EPS, _EPS, n)
    if mode == "binary":
        p = jnp.clip(u / safe_n, 0.0, 1.0)
        m = (r_sm < p)
        hat_sm = jnp.where(m, n, 0.0)
        lo = jnp.minimum(n, 0.0)
        hi = jnp.maximum(n, 0.0)
    else:  # signed
        p = jnp.clip((u + n) / (2.0 * safe_n), 0.0, 1.0)
        m = (r_sm < p)
        hat_sm = jnp.where(m, n, -n)
        hi = jnp.abs(n)
        lo = -hi
    bar = jnp.clip(u, lo, hi)
    gate = (r_pm < prog)
    uhat_ref[...] = jnp.where(gate, hat_sm, bar).astype(uhat_ref.dtype)
    mask_ref[...] = m.astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret", "block_r",
                                    "block_c"))
def psm_fused(u: jax.Array, n: jax.Array, r_sm: jax.Array, r_pm: jax.Array,
              progress: jax.Array, *, mode: str = "binary",
              interpret: bool = True, block_r: int = BLOCK_R,
              block_c: int = BLOCK_C):
    """Fused PSM over 2-D tiles. All of u/n/r_sm/r_pm shaped (R, C).

    Returns (û, mask int8).  ``interpret=True`` runs the kernel body in
    Python on CPU (validation); on TPU pass interpret=False.
    """
    R, C = u.shape
    br, bc = min(block_r, R), min(block_c, C)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    prog_arr = jnp.asarray(progress, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_psm_kernel, mode=mode),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((R, C), u.dtype),
                   jax.ShapeDtypeStruct((R, C), jnp.int8)],
        interpret=interpret,
    )(u, n, r_sm, r_pm, prog_arr)
