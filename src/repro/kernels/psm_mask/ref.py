"""Pure-jnp oracle for the fused PSM kernel (same pre-drawn uniforms)."""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-30


def psm_ref(u, n, r_sm, r_pm, progress, *, mode: str = "binary"):
    u32 = u.astype(jnp.float32)
    n32 = n.astype(jnp.float32)
    safe_n = jnp.where(jnp.abs(n32) < _EPS, _EPS, n32)
    if mode == "binary":
        p = jnp.clip(u32 / safe_n, 0.0, 1.0)
        m = r_sm < p
        hat_sm = jnp.where(m, n32, 0.0)
        lo = jnp.minimum(n32, 0.0)
        hi = jnp.maximum(n32, 0.0)
    else:
        p = jnp.clip((u32 + n32) / (2.0 * safe_n), 0.0, 1.0)
        m = r_sm < p
        hat_sm = jnp.where(m, n32, -n32)
        hi = jnp.abs(n32)
        lo = -hi
    bar = jnp.clip(u32, lo, hi)
    gate = r_pm < progress
    uhat = jnp.where(gate, hat_sm, bar).astype(u.dtype)
    return uhat, m.astype(jnp.int8)
