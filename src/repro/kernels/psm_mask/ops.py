"""Public op: fused PSM on arbitrary-shaped tensors (+ pytree variant).

``use_pallas=False`` (or non-TPU backends without interpret) falls back to
the jnp oracle — bitwise-identical by construction (same uniforms).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..tiling import to_lane_tiles as _to_tiles
from .psm_mask import psm_fused
from .ref import psm_ref


def _draw_uniforms(key: jax.Array, shape):
    k_sm, k_pm = jax.random.split(key)
    r_sm = jax.random.uniform(k_sm, shape, jnp.float32)
    r_pm = jax.random.uniform(k_pm, shape, jnp.float32)
    return r_sm, r_pm


def _psm_from_uniforms(u, n, r_sm, r_pm, progress, *, mode, use_pallas,
                       interpret):
    if not use_pallas:
        return psm_ref(u, n, r_sm, r_pm, progress, mode=mode)
    shape = u.shape
    ut, nelem = _to_tiles(u)
    nt, _ = _to_tiles(n)
    rs, _ = _to_tiles(r_sm)
    rp, _ = _to_tiles(r_pm)
    uhat, mask = psm_fused(ut, nt, rs, rp, progress, mode=mode,
                           interpret=interpret)
    return (uhat.reshape(-1)[:nelem].reshape(shape),
            mask.reshape(-1)[:nelem].reshape(shape))


def psm_apply(u: jax.Array, n: jax.Array, key: jax.Array, progress,
              *, mode: str = "binary", use_pallas: bool = True,
              interpret: bool = True):
    """PSM on a tensor of any shape → (û, mask int8) with u's shape."""
    r_sm, r_pm = _draw_uniforms(key, u.shape)
    return _psm_from_uniforms(u, n, r_sm, r_pm, progress, mode=mode,
                              use_pallas=use_pallas, interpret=interpret)


# ---------------------------------------------------------------------------
# STE-differentiable wrapper — what core.masking's backend dispatch calls.
#
# The fused kernel computes forward values only; local training
# differentiates through PSM, so we attach the exact VJP of the reference
# formula:
#   out = where(gate, hat_sm, bar),  hat_sm = u + stop_grad(n·m − u) (∂ = 1)
#   bar = clip(u, lo, hi)                               (∂ = clip's vjp)
# making backend="pallas" gradient-identical to backend="ref".
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _psm_ste_core(u, n, r_sm, r_pm, progress, mode, interpret):
    uhat, _ = _psm_from_uniforms(u, n, r_sm, r_pm, progress, mode=mode,
                                 use_pallas=True, interpret=interpret)
    return uhat


def _psm_ste_fwd(u, n, r_sm, r_pm, progress, mode, interpret):
    uhat = _psm_ste_core(u, n, r_sm, r_pm, progress, mode, interpret)
    gate = r_pm < jnp.asarray(progress, jnp.float32)
    return uhat, (u, n, gate)


def ste_clip_bwd(mode, u, n, gate, g):
    """Cotangent to ``u`` of ``where(gate, hat_sm, clip(u, lo(n), hi(n)))``.

    ``hat_sm`` carries the Eq.(9) straight-through ∂/∂u = 1; the ungated
    branch is the clip's exact VJP.  ``gate=None`` means progress ≡ 1
    (every element masked) → the cotangent is ``g`` unchanged.  Shared by
    the psm_mask and mask_uplink fused ops so their STE rules cannot
    drift apart.
    """
    if gate is None:
        return g
    if mode == "binary":
        lo = jnp.minimum(n, 0.0)
        hi = jnp.maximum(n, 0.0)
    else:
        hi = jnp.abs(n)
        lo = -hi
    _, clip_vjp = jax.vjp(lambda uu: jnp.clip(uu, lo, hi), u)
    zero = jnp.zeros_like(g)
    return jnp.where(gate, g, zero) + clip_vjp(jnp.where(gate, zero, g))[0]


def _psm_ste_bwd(mode, interpret, res, g):
    u, n, gate = res
    ct_u = ste_clip_bwd(mode, u, n, gate, g)
    return (ct_u, jnp.zeros_like(n), jnp.zeros_like(g), jnp.zeros_like(g),
            jnp.zeros((), jnp.float32))


_psm_ste_core.defvjp(_psm_ste_fwd, _psm_ste_bwd)


def psm_ste(u: jax.Array, n: jax.Array, key: jax.Array, progress,
            *, mode: str = "binary", interpret: bool = True) -> jax.Array:
    """Differentiable PSM û via the fused kernel (STE gradients as ref)."""
    r_sm, r_pm = _draw_uniforms(key, u.shape)
    return _psm_ste_core(u, n, r_sm, r_pm,
                         jnp.asarray(progress, jnp.float32), mode, interpret)


def psm_apply_tree(u: Any, n: Any, key: jax.Array, progress,
                   *, mode: str = "binary", **kw):
    leaves_u, treedef = jax.tree_util.tree_flatten(u)
    leaves_n = jax.tree_util.tree_leaves(n)
    outs = []
    for i, (ul, nl) in enumerate(zip(leaves_u, leaves_n)):
        outs.append(psm_apply(ul, nl, jax.random.fold_in(key, i),
                              progress, mode=mode, **kw))
    uhat = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    mask = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return uhat, mask
