"""Public op: fused PSM on arbitrary-shaped tensors (+ pytree variant).

``use_pallas=False`` (or non-TPU backends without interpret) falls back to
the jnp oracle — bitwise-identical by construction (same uniforms).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .psm_mask import psm_fused
from .ref import psm_ref

_LANE = 128


def _to_tiles(x: jax.Array):
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = _LANE
    rows = -(-n // cols)
    pad = rows * cols - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, cols), n


def psm_apply(u: jax.Array, n: jax.Array, key: jax.Array, progress,
              *, mode: str = "binary", use_pallas: bool = True,
              interpret: bool = True):
    """PSM on a tensor of any shape → (û, mask int8) with u's shape."""
    shape = u.shape
    k_sm, k_pm = jax.random.split(key)
    r_sm = jax.random.uniform(k_sm, shape, jnp.float32)
    r_pm = jax.random.uniform(k_pm, shape, jnp.float32)
    if not use_pallas:
        return psm_ref(u, n, r_sm, r_pm, progress, mode=mode)
    ut, nelem = _to_tiles(u)
    nt, _ = _to_tiles(n)
    rs, _ = _to_tiles(r_sm)
    rp, _ = _to_tiles(r_pm)
    uhat, mask = psm_fused(ut, nt, rs, rp, progress, mode=mode,
                           interpret=interpret)
    return (uhat.reshape(-1)[:nelem].reshape(shape),
            mask.reshape(-1)[:nelem].reshape(shape))


def psm_apply_tree(u: Any, n: Any, key: jax.Array, progress,
                   *, mode: str = "binary", **kw):
    leaves_u, treedef = jax.tree_util.tree_flatten(u)
    leaves_n = jax.tree_util.tree_leaves(n)
    outs = []
    for i, (ul, nl) in enumerate(zip(leaves_u, leaves_n)):
        outs.append(psm_apply(ul, nl, jax.random.fold_in(key, i),
                              progress, mode=mode, **kw))
    uhat = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    mask = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return uhat, mask
