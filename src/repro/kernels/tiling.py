"""Shared tile-shaping helpers for the Pallas kernel wrappers.

Every kernel op pads its operands up to a block multiple before the
``pallas_call`` and slices the padding back off afterwards.  These
helpers are THE one implementation of that shaping (one ``jnp.pad``, no
concatenate-then-reshape double copy), so the psm_mask and mask_uplink
ops cannot drift apart on layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = -1) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to the next multiple (no-op copy
    avoided entirely when already aligned)."""
    size = x.shape[axis]
    pad = (-size) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis % x.ndim] = (0, pad)
    return jnp.pad(x, widths)


def to_lane_tiles(x: jax.Array, lane: int = LANE):
    """Flatten any-shaped ``x`` to lane-aligned (rows, lane) tiles.

    Returns ``(tiles, n)`` with ``n`` the true element count; the inverse
    is ``tiles.reshape(-1)[:n].reshape(orig_shape)``.
    """
    flat = pad_to_multiple(x.reshape(-1), lane)
    return flat.reshape(-1, lane), x.size
