"""Pure-jnp oracle for the wkv kernel (re-exports the model's scan)."""
from ...models.rwkv6 import _wkv_scan


def wkv_ref(r, k, v, w, u, s0):
    import jax.numpy as jnp
    return _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), w.astype(jnp.float32), u, s0)
