"""Public wkv op with pallas/jnp dispatch."""
from .ref import wkv_ref
from .rwkv6_scan import wkv_pallas


def wkv(r, k, v, w, u, s0, *, use_pallas=True, interpret=True):
    if use_pallas:
        return wkv_pallas(r, k, v, w, u, s0, interpret=interpret)
    return wkv_ref(r, k, v, w, u, s0)
