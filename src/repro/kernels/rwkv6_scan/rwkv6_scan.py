"""RWKV6 wkv recurrence Pallas kernel.

Per (batch, head): state S ∈ R^{hd×hd} lives in VMEM for the whole
sequence; time steps stream through in registers:

    out_t = r_t · (S + u ⊙ (k_tᵀ v_t))
    S    ← w_t ⊙ S + k_tᵀ v_t

The HBM-resident time dimension is processed in one grid step per (b, h)
pair — each r/k/v/w element is read exactly once and S never leaves VMEM
(hd=64 ⇒ 16 KB fp32 state, far under the ~16 MB VMEM budget; block shapes
keep the (T, hd) panels lane-aligned at 64 ≤ 128 which Mosaic pads).

This is the TPU-native adaptation of RWKV's CUDA kernel: instead of one
thread per channel with warp-local state, one grid cell per (b, h) with
the state as a VMEM-resident matrix and the t-loop as a fori_loop of
rank-1 updates (outer products hit the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, out_ref,
                s_out_ref):
    """Blocks: r/k/v/w/out (1,T,1,hd); u (1,hd); s0/s_out (1,1,hd,hd)."""
    T = r_ref.shape[1]
    u = u_ref[0, :].astype(jnp.float32)          # (hd,)
    s0 = s0_ref[0, 0].astype(jnp.float32)        # (hd, hd)

    def step(t, s):
        r = r_ref[0, t, 0, :].astype(jnp.float32)  # (hd,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]               # (hd, hd) rank-1
        out = r @ (s + u[:, None] * kv)            # (hd,)
        out_ref[0, t, 0, :] = out.astype(out_ref.dtype)
        return w[:, None] * s + kv

    s = jax.lax.fori_loop(0, T, step, s0)
    s_out_ref[0, 0] = s.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_pallas(r, k, v, w, u, s0, *, interpret: bool = True):
    """r,k,v,w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (out (B, T, H, hd) f32, s_final (B, H, hd, hd) f32).
    Grid = (B, H); each cell owns its head's full sequence.
    """
    B, T, H, hd = r.shape
    seq_spec = pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0))
    u_spec = pl.BlockSpec((1, hd), lambda b, h: (h, 0))
    s_spec = pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0))

    out, s_fin = pl.pallas_call(
        _wkv_kernel,
        grid=(B, H),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec, s_spec],
        out_specs=[seq_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct((B, T, H, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, s_fin
