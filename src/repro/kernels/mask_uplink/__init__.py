"""Fused mask-uplink kernels: PSM sample → bitpack → popcount in one pass."""
from .ops import (UplinkOut, mask_uplink_fused, mask_uplink_ste,  # noqa: F401
                  unpack_counts, unpack_counts_apply)
