"""Pure-jnp oracle for the fused mask-uplink kernel (same uniforms).

Mirrors the kernel contract exactly — binary popcounts (the signed
Σ(±1) = 2c − K fix lives in ``ops``), little-endian word packing — but
returns FULL reductions instead of per-row-block partials, on the true
unpadded (K, P) shapes.  This is also the single-program jnp fast path
the ``ref`` backend runs: one fused XLA program with no pack→unpack
round trip, versus the three-dispatch staged pipeline it replaces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32
_EPS = 1e-30


def uplink_ref(u, n, r_sm, r_pm=None, progress=None, weights=None, *,
               mode: str = "binary", wsum_values: bool = True,
               want_uhat: bool = False):
    K, P = u.shape
    u32 = u.astype(jnp.float32)
    if weights is None:
        weights = jnp.ones((K,), jnp.float32)
    uhat = None
    if mode == "prob":
        p = jnp.clip(u32, 0.0, 1.0)
        # materialize the mask ONCE: without the barrier XLA duplicates
        # the sample (div + clip + compare) into each of its consumers
        # (pack, popcount, weighted sum), which costs more than the
        # whole staged pipeline on CPU
        m = jax.lax.optimization_barrier(r_sm < p)
        v = jnp.where(m, 1.0, 0.0)
    else:
        n32 = n.astype(jnp.float32)
        safe_n = jnp.where(jnp.abs(n32) < _EPS, _EPS, n32)
        if mode == "binary":
            p = jnp.clip(u32 / safe_n, 0.0, 1.0)
            m = jax.lax.optimization_barrier(r_sm < p)
            hat_sm = jnp.where(m, n32, 0.0)
            lo = jnp.minimum(n32, 0.0)
            hi = jnp.maximum(n32, 0.0)
            v = hat_sm if wsum_values else jnp.where(m, 1.0, 0.0)
        else:  # signed
            p = jnp.clip((u32 + n32) / (2.0 * safe_n), 0.0, 1.0)
            m = jax.lax.optimization_barrier(r_sm < p)
            hat_sm = jnp.where(m, n32, -n32)
            hi = jnp.abs(n32)
            lo = -hi
            v = hat_sm if wsum_values else jnp.where(m, 1.0, -1.0)
        if want_uhat:
            bar = jnp.clip(u32, lo, hi)
            if r_pm is not None:
                gate = r_pm < jnp.asarray(progress, jnp.float32)
                uhat = jnp.where(gate, hat_sm, bar).astype(u.dtype)
            else:
                uhat = hat_sm.astype(u.dtype)

    bits = m.astype(jnp.uint32)
    pad = (-P) % WORD
    if pad:
        bits = jnp.pad(bits, [(0, 0), (0, pad)])
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    words = jnp.sum(bits.reshape(K, -1, WORD) << shifts[None, None, :],
                    axis=-1, dtype=jnp.uint32)
    # client-axis reductions as an unrolled row walk (K is a small static
    # shape): each step is a contiguous (P,) axpy, where both the strided
    # jnp.sum(axis=0) lowering and a (1,K)x(K,P) dot_general are several
    # times slower on CPU.  Counts stay exact ints — bitwise-same int32.
    counts = m[0].astype(jnp.int32)                       # binary popcount
    wsum = weights[0] * v[0]
    for k in range(1, K):
        counts = counts + m[k].astype(jnp.int32)
        wsum = wsum + weights[k] * v[k]
    return words, counts, wsum, uhat


def unpack_counts_ref(words: jax.Array) -> jax.Array:
    """(K, W) packed rows → (W·32,) int32 binary popcounts.

    Unrolled over the (small, static) client axis: each step unpacks one
    contiguous row — the broadcast-then-``sum(axis=0)`` form materializes
    the full (K, W, 32) bit tensor and reduces it strided.
    """
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    K = words.shape[0]
    acc = ((words[0][:, None] >> shifts[None, :])
           & jnp.uint32(1)).astype(jnp.int32)
    for k in range(1, K):
        acc = acc + (((words[k][:, None] >> shifts[None, :])
                      & jnp.uint32(1)).astype(jnp.int32))
    return acc.reshape(-1)
