"""Public ops: fused mask uplink on arbitrary (K, P) stacks (+ STE).

``use_pallas=False`` runs the jnp oracle — same uniforms, same math, so
the two routes agree bitwise on words/counts (and to reduction-order
rounding on the f32 weighted sums).  The oracle is itself ONE fused XLA
program, which is what the ``ref`` backend benchmarks against the staged
three-kernel pipeline.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..psm_mask.ops import ste_clip_bwd
from ..tiling import pad_to_multiple
from . import ref
from .mask_uplink import (BLOCK_C, BLOCK_R, WORD, unpack_counts_apply_pallas,
                          unpack_counts_pallas, uplink_fused)


def _packed_len(n_bits: int) -> int:
    return (n_bits + WORD - 1) // WORD


class UplinkOut(NamedTuple):
    """One round's fused uplink for a (K clients, P params) stack."""

    words: jax.Array            # (K, ceil(P/32)) uint32 wire rows
    counts: jax.Array           # (P,) int32 Σ_k m_k (signed: Σ ±1)
    wsum: jax.Array             # (P,) f32 Σ_k w_k · v_k
    uhat: Optional[jax.Array] = None   # (K, P) STE forward value


def _pad_all(arrs):
    """Pad (K, P) operands to the kernel's block multiples (zeros sample
    to mask bit 0, so padding never leaks into words/counts/wsum)."""
    out = []
    for a in arrs:
        if a is None:
            out.append(None)
            continue
        a = pad_to_multiple(a, BLOCK_R, axis=0)
        out.append(pad_to_multiple(a, BLOCK_C, axis=1))
    return out


def mask_uplink_fused(u: jax.Array, n: Optional[jax.Array], r_sm: jax.Array,
                      r_pm=None, progress=None, weights=None, *,
                      mode: str = "binary", wsum_values: bool = True,
                      want_uhat: bool = False, use_pallas: bool = True,
                      interpret: bool = True) -> UplinkOut:
    """Sample → pack → count → weighted-sum, one pass over a (K, P) stack.

    ``mode="prob"`` reads P[m=1] directly from ``u`` (``n`` ignored);
    ``r_pm=None`` is the progress≡1 final-uplink draw.  Signed counts are
    the true Σ_k (±1) — the kernel's binary popcount with the 2c − K fix
    applied here, where K is the UNPADDED client count (padded rows would
    otherwise each contribute −1).
    """
    K, P = u.shape
    if n is None:
        n = u                                    # prob mode: unused operand
    if weights is None:
        weights = jnp.ones((K,), jnp.float32)
    if not use_pallas:
        words, c, wsum, uhat = ref.uplink_ref(
            u, n, r_sm, r_pm, progress, weights, mode=mode,
            wsum_values=wsum_values, want_uhat=want_uhat)
    else:
        up, np_, rs, rp = _pad_all([u, n, r_sm, r_pm])
        wp = pad_to_multiple(weights.astype(jnp.float32), BLOCK_R, axis=0)
        outs = uplink_fused(up, np_, rs, rp, progress, wp, mode=mode,
                            wsum_values=wsum_values, want_uhat=want_uhat,
                            interpret=interpret)
        words = outs[0][:K, :_packed_len(P)]
        c = jnp.sum(outs[1], axis=0, dtype=jnp.int32)[:P]
        wsum = jnp.sum(outs[2], axis=0)[:P]
        uhat = outs[3][:K, :P] if want_uhat else None
    if mode == "signed":
        c = 2 * c - K
    return UplinkOut(words, c, wsum, uhat)


# ---------------------------------------------------------------------------
# STE-differentiable variant — gradient flows to ``u`` exactly as the
# staged tree_psm/psm_ste path (shared ste_clip_bwd), everything else 0.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _uplink_ste(u, n, r_sm, r_pm, progress, weights, mode, wsum_values,
                use_pallas, interpret):
    out = mask_uplink_fused(u, n, r_sm, r_pm, progress, weights, mode=mode,
                            wsum_values=wsum_values, want_uhat=True,
                            use_pallas=use_pallas, interpret=interpret)
    return out.words, out.counts, out.wsum, out.uhat


def _uplink_ste_fwd(u, n, r_sm, r_pm, progress, weights, mode, wsum_values,
                    use_pallas, interpret):
    out = _uplink_ste(u, n, r_sm, r_pm, progress, weights, mode,
                      wsum_values, use_pallas, interpret)
    gate = (None if r_pm is None
            else r_pm < jnp.asarray(progress, jnp.float32))
    return out, (u, n, gate)


def _uplink_ste_bwd(mode, wsum_values, use_pallas, interpret, res, cts):
    u, n, gate = res
    ct_u = ste_clip_bwd(mode, u, n, gate, cts[3])   # û cotangent only
    return (ct_u, jnp.zeros_like(n), jnp.zeros_like(u),
            None if gate is None else jnp.zeros_like(u),
            None if gate is None else jnp.zeros((), jnp.float32),
            jnp.zeros((u.shape[0],), jnp.float32))


_uplink_ste.defvjp(_uplink_ste_fwd, _uplink_ste_bwd)


def mask_uplink_ste(u, n, r_sm, r_pm=None, progress=None, weights=None, *,
                    mode: str = "binary", wsum_values: bool = True,
                    use_pallas: bool = True,
                    interpret: bool = True) -> UplinkOut:
    """:func:`mask_uplink_fused` with û emitted and STE gradients to ``u``
    (binary/signed only — FedPM's prob mode never differentiates the
    uplink draw)."""
    if mode == "prob":
        raise ValueError("mask_uplink_ste: prob mode has no STE gradient")
    if weights is None:
        weights = jnp.ones((u.shape[0],), jnp.float32)
    progress = (None if r_pm is None
                else jnp.asarray(progress, jnp.float32))
    return UplinkOut(*_uplink_ste(u, n, r_sm, r_pm, progress, weights, mode,
                                  wsum_values, use_pallas, interpret))


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------

def unpack_counts(words: jax.Array, *, use_pallas: bool = True,
                  interpret: bool = True) -> jax.Array:
    """(K, W) packed rows → (W·32,) int32 binary popcounts, no bit tensor
    in HBM on the pallas route (partials reduced per word-block)."""
    K, W = words.shape
    if not use_pallas:
        return ref.unpack_counts_ref(words)
    wp = pad_to_multiple(pad_to_multiple(words, 128, axis=1),
                         BLOCK_R, axis=0)
    parts = unpack_counts_pallas(wp, interpret=interpret)
    return jnp.sum(parts, axis=0, dtype=jnp.int32)[: W * WORD]


def unpack_counts_apply(words: jax.Array, noise: jax.Array, base: jax.Array,
                        mul, a, b, *, use_pallas: bool = True,
                        interpret: bool = True) -> jax.Array:
    """``base + noise ⊙ (mul·(a·c + b))`` with c the per-element popcount
    of ``words`` — the Eq. (5) shared-noise server update straight from
    the aggregated wire rows.  ``noise``/``base`` are flat (P,); binary
    counts use (a, b) = (1, 0), signed Σ(±1) uses (2, −K).
    """
    P = noise.shape[0]
    noise = noise.astype(jnp.float32)
    base = base.astype(jnp.float32)
    if not use_pallas:
        c = ref.unpack_counts_ref(words)[:P].astype(jnp.float32)
        return base + noise * (mul * (a * c + b))
    wp = pad_to_multiple(pad_to_multiple(words, 128, axis=1),
                         BLOCK_R, axis=0)
    Wp = wp.shape[1]
    noise_p = pad_to_multiple(noise, Wp * WORD).reshape(1, -1)
    base_p = pad_to_multiple(base, Wp * WORD).reshape(1, -1)
    scalars = jnp.stack([jnp.asarray(mul, jnp.float32),
                         jnp.asarray(a, jnp.float32),
                         jnp.asarray(b, jnp.float32)])
    out = unpack_counts_apply_pallas(wp, noise_p, base_p, scalars,
                                     interpret=interpret)
    return out[0, :P]
