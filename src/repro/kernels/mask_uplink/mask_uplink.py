"""Fused mask-uplink Pallas TPU kernel — the paper's whole wire hot path.

The FedMRN uplink is: sample the 1-bit mask under PSM (Eq. 6/7/10),
bitpack it into uint32 words, and reduce the per-element mask counts the
server aggregates.  Run as separate kernels that chain makes three full
HBM round trips over model-sized tensors and materializes both the mask
tree and (server side) an unpacked bit tensor 32× the wire size.  Here
the whole chain is ONE ``pallas_call``: each (block_r, block_c) tile of
``u``/``n``/uniforms is read into VMEM once and leaves as

  words    (R, C/32) uint32      the packed wire payload rows
  counts   (R/br, C) int32       per-row-block popcount partials
  wsum     (R/br, C) f32         per-row-block Σ_r w_r · v_r ⊙ m_r
                                 partials (v = noise → Eq. 5 masked-noise
                                 sums, or v = ±1 → weighted mask sums)
  û        (R, C), optional      the PSM/STE forward value

— the {0,1} mask itself never touches HBM.  The server-side mirrors,
``unpack_counts`` and ``unpack_counts_apply``, go from aggregated words
straight to counts (and into ``base + noise ⊙ (mul·(a·c + b))``, the
global-model update) without materializing unpacked bits.

Uniforms are drawn OUTSIDE (seeded jax.random streams — the server must
reproduce G(s) exactly), like ``kernels/psm_mask``.  ``mode="prob"``
reads P[m=1] directly from ``u`` (FedPM sigmoid scores); the ``r_pm``
gate input is optional — omitted, the kernel is the progress=1 final
uplink draw.  Callers pad shapes to block multiples (``kernels.tiling``)
so the in-kernel reductions never see out-of-bounds lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32
BLOCK_R = 8        # sublane-aligned client rows per tile
BLOCK_C = 4096     # bits per tile = 128 uint32 words (lane-aligned)
_EPS = 1e-30


def _uplink_kernel(*refs, mode: str, with_gate: bool, want_uhat: bool,
                   wsum_values: bool):
    it = iter(refs)
    u_ref, n_ref, r_sm_ref = next(it), next(it), next(it)
    r_pm_ref = next(it) if with_gate else None
    w_ref = next(it)
    prog_ref = next(it) if with_gate else None
    words_ref, counts_ref, wsum_ref = next(it), next(it), next(it)
    uhat_ref = next(it) if want_uhat else None

    u = u_ref[...].astype(jnp.float32)
    n = n_ref[...].astype(jnp.float32)
    r_sm = r_sm_ref[...]
    if mode == "prob":
        p = jnp.clip(u, 0.0, 1.0)
        m = r_sm < p
        v = jnp.where(m, 1.0, 0.0)
    else:
        safe_n = jnp.where(jnp.abs(n) < _EPS, _EPS, n)
        if mode == "binary":
            p = jnp.clip(u / safe_n, 0.0, 1.0)
            m = r_sm < p
            hat_sm = jnp.where(m, n, 0.0)
            lo = jnp.minimum(n, 0.0)
            hi = jnp.maximum(n, 0.0)
            v = hat_sm if wsum_values else jnp.where(m, 1.0, 0.0)
        else:  # signed
            p = jnp.clip((u + n) / (2.0 * safe_n), 0.0, 1.0)
            m = r_sm < p
            hat_sm = jnp.where(m, n, -n)
            hi = jnp.abs(n)
            lo = -hi
            v = hat_sm if wsum_values else jnp.where(m, 1.0, -1.0)
    if want_uhat:
        bar = jnp.clip(u, lo, hi)
        if with_gate:
            gate = r_pm_ref[...] < prog_ref[0]
            uhat = jnp.where(gate, hat_sm, bar)
        else:                       # progress ≡ 1: every element is masked
            uhat = hat_sm
        uhat_ref[...] = uhat.astype(uhat_ref.dtype)

    br, bc = m.shape
    bits = m.reshape(br, bc // WORD, WORD).astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    words_ref[...] = jnp.sum(bits << shifts[None, None, :], axis=-1,
                             dtype=jnp.uint32)
    # binary popcount partials even in signed mode: Σ(±1) = 2c − K is an
    # affine fix the wrapper applies with the TRUE (unpadded) client count
    counts_ref[...] = jnp.sum(m.astype(jnp.int32), axis=0, keepdims=True)
    w = w_ref[...].astype(jnp.float32)              # (br, 1)
    wsum_ref[...] = jnp.sum(w * v, axis=0, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("mode", "wsum_values", "want_uhat",
                                    "interpret", "block_r", "block_c"))
def uplink_fused(u: jax.Array, n: jax.Array, r_sm: jax.Array,
                 r_pm, progress, weights: jax.Array, *,
                 mode: str = "binary", wsum_values: bool = True,
                 want_uhat: bool = False, interpret: bool = True,
                 block_r: int = BLOCK_R, block_c: int = BLOCK_C):
    """One fused pass over (R, C) tiles; R, C must be block multiples.

    ``r_pm=None`` (with ``progress=None``) drops the PM gate input — the
    progress=1 uplink draw.  Returns ``(words, count_partials,
    wsum_partials[, uhat])``; partials are (R/block_r, C) and summed over
    axis 0 by the wrapper.
    """
    R, C = u.shape
    br, bc = min(block_r, R), min(block_c, C)
    assert R % br == 0 and C % bc == 0 and bc % WORD == 0, (R, C, br, bc)
    with_gate = r_pm is not None
    gr = R // br
    grid = (gr, C // bc)
    tile = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    row_spec = pl.BlockSpec((1, bc), lambda i, j: (i, j))

    in_specs = [tile, tile, tile]
    args = [u, n, r_sm]
    if with_gate:
        in_specs.append(tile)
        args.append(r_pm)
    in_specs.append(pl.BlockSpec((br, 1), lambda i, j: (i, 0)))
    args.append(weights.reshape(R, 1))
    if with_gate:
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))
        args.append(jnp.asarray(progress, jnp.float32).reshape(1))

    out_specs = [pl.BlockSpec((br, bc // WORD), lambda i, j: (i, j)),
                 row_spec, row_spec]
    out_shape = [jax.ShapeDtypeStruct((R, C // WORD), jnp.uint32),
                 jax.ShapeDtypeStruct((gr, C), jnp.int32),
                 jax.ShapeDtypeStruct((gr, C), jnp.float32)]
    if want_uhat:
        out_specs.append(tile)
        out_shape.append(jax.ShapeDtypeStruct((R, C), u.dtype))

    return pl.pallas_call(
        functools.partial(_uplink_kernel, mode=mode, with_gate=with_gate,
                          want_uhat=want_uhat, wsum_values=wsum_values),
        grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# server side: aggregated words → counts (→ applied update), no bit tensor
# ---------------------------------------------------------------------------

def _counts_kernel(words_ref, counts_ref):
    words = words_ref[...]                           # (bk, bw)
    bk, bw = words.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    counts_ref[...] = jnp.sum(bits.astype(jnp.int32),
                              axis=0).reshape(1, bw * WORD)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_k", "block_w"))
def unpack_counts_pallas(words: jax.Array, *, interpret: bool = True,
                         block_k: int = BLOCK_R, block_w: int = 128):
    """(K, W) packed rows → (K/bk, W·32) int32 popcount partials."""
    K, W = words.shape
    bk, bw = min(block_k, K), min(block_w, W)
    assert K % bk == 0 and W % bw == 0, (K, W, bk, bw)
    grid = (K // bk, W // bw)
    return pl.pallas_call(
        _counts_kernel, grid=grid,
        in_specs=[pl.BlockSpec((bk, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, bw * WORD), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K // bk, W * WORD), jnp.int32),
        interpret=interpret,
    )(words)


def _counts_apply_kernel(words_ref, noise_ref, base_ref, sc_ref, out_ref):
    words = words_ref[...]                           # (K, bw): all clients
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    bw = words.shape[1]
    # f32 popcount is exact for K < 2^24; a·c + b is the signed-count fix
    c = jnp.sum(bits.astype(jnp.float32), axis=0).reshape(1, bw * WORD)
    mul, a, b = sc_ref[0], sc_ref[1], sc_ref[2]
    out_ref[...] = base_ref[...] + noise_ref[...] * (mul * (a * c + b))


@functools.partial(jax.jit, static_argnames=("interpret", "block_w"))
def unpack_counts_apply_pallas(words: jax.Array, noise: jax.Array,
                               base: jax.Array, scalars: jax.Array, *,
                               interpret: bool = True, block_w: int = 128):
    """words (K, W), noise/base (1, W·32), scalars (mul, a, b) →
    ``base + noise ⊙ (mul·(a·c + b))`` as (1, W·32) f32 — the Eq. (5)
    shared-noise server update straight from the wire words."""
    K, W = words.shape
    bw = min(block_w, W)
    assert W % bw == 0, (W, bw)
    grid = (W // bw,)
    return pl.pallas_call(
        _counts_apply_kernel, grid=grid,
        in_specs=[pl.BlockSpec((K, bw), lambda i: (0, i)),
                  pl.BlockSpec((1, bw * WORD), lambda i: (0, i)),
                  pl.BlockSpec((1, bw * WORD), lambda i: (0, i)),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((1, bw * WORD), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, W * WORD), jnp.float32),
        interpret=interpret,
    )(words, noise, base, scalars)
