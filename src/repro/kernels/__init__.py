"""Pallas TPU kernels (validated on CPU with interpret=True).

psm_mask     fused PSM masking chain (the paper's hot elementwise path)
bitpack      1-bit mask wire-format pack/unpack
rwkv6_scan   RWKV6 wkv linear-attention recurrence (chunked, VMEM state)
mask_uplink  whole-uplink fusion: PSM sample → bitpack → popcount /
             weighted-sum partials in one pass (+ the server-side
             counts→update apply kernel)

See README.md in this directory for the family inventory and the
dispatch/fallback rules.
"""
