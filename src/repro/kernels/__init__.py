"""Pallas TPU kernels (validated on CPU with interpret=True).

psm_mask    fused PSM masking chain (the paper's hot elementwise path)
bitpack     1-bit mask wire-format pack/unpack
rwkv6_scan  RWKV6 wkv linear-attention recurrence (chunked, VMEM state)
"""
