"""1-bit mask packing Pallas kernel — the FedMRN wire format, on-chip.

Packs an int8 {0,1} mask tile (R, C·32) into uint32 words (R, C) with
shift/or on 32 int32 lanes at a time.  TPU has no scalar bit twiddling in
the VPU path worth using here; a (R, C, 32)·(32,) weighted-sum against the
power-of-two vector maps onto the VPU/MXU cleanly and XLA-Pallas lowers it
as a single fused loop.  Unpack is the mirror (shift+mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32
BLOCK_R = 8
BLOCK_W = 128   # words per block → 4096 bits per row-block


def _pack_kernel(bits_ref, out_ref):
    bits = bits_ref[...].astype(jnp.uint32)            # (BR, BW*32)
    br, bw32 = bits.shape
    bw = bw32 // WORD
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    words = jnp.sum(bits.reshape(br, bw, WORD) << shifts[None, None, :],
                    axis=-1, dtype=jnp.uint32)
    out_ref[...] = words


def _unpack_kernel(words_ref, out_ref):
    words = words_ref[...]
    br, bw = words.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    out_ref[...] = bits.reshape(br, bw * WORD).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_bits_pallas(bits: jax.Array, *, interpret: bool = True):
    """bits: (R, C) int8 {0,1} with C % 32 == 0 → (R, C//32) uint32."""
    R, C = bits.shape
    assert C % WORD == 0
    W = C // WORD
    br = min(BLOCK_R, R)
    bw = min(BLOCK_W, W)
    grid = (pl.cdiv(R, br), pl.cdiv(W, bw))
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bw * WORD), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, W), jnp.uint32),
        interpret=interpret,
    )(bits)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_bits_pallas(words: jax.Array, *, interpret: bool = True):
    """words: (R, W) uint32 → (R, W*32) int8 {0,1}."""
    R, W = words.shape
    br = min(BLOCK_R, R)
    bw = min(BLOCK_W, W)
    grid = (pl.cdiv(R, br), pl.cdiv(W, bw))
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bw * WORD), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, W * WORD), jnp.int8),
        interpret=interpret,
    )(words)
