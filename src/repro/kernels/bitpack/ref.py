"""Pure-jnp oracle for the bitpack kernel (mirrors core.packing)."""
import jax.numpy as jnp

WORD = 32


def pack_ref(bits):
    R, C = bits.shape
    W = C // WORD
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32).reshape(R, W, WORD) << shifts,
                   axis=-1, dtype=jnp.uint32)


def unpack_ref(words):
    R, W = words.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(R, W * WORD).astype(jnp.int8)
