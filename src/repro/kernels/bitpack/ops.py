"""Public pack/unpack ops with pallas/jnp dispatch."""
from .bitpack import pack_bits_pallas, unpack_bits_pallas  # noqa: F401
from .ref import pack_ref, unpack_ref  # noqa: F401


def pack(bits, *, use_pallas=True, interpret=True):
    if use_pallas:
        return pack_bits_pallas(bits, interpret=interpret)
    return pack_ref(bits)


def unpack(words, *, use_pallas=True, interpret=True):
    if use_pallas:
        return unpack_bits_pallas(words, interpret=interpret)
    return unpack_ref(words)
