"""Synthetic datasets + federated partitioners (paper §5.1.2).

No datasets ship in this offline container, so the paper's FMNIST/SVHN/
CIFAR are replaced by controllable synthetic tasks with the same *federated
structure*: IID, Non-IID-1 (Dirichlet label skew) and Non-IID-2 (each
client holds only a few labels) — the partitioners are exactly the paper's.

Two task families:
  - image-like classification: class prototypes + noise on (H, W, C) grids,
    hard enough that a CNN beats a linear probe but CPU-trainable.
  - token LM: a deterministic modular-sum language so next-token accuracy
    is a meaningful, learnable metric for the LM examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageTask:
    x: np.ndarray          # (N, H, W, C) float32
    y: np.ndarray          # (N,) int32
    n_classes: int


def make_image_task(seed: int, *, n: int = 4000, hw: int = 16,
                    n_classes: int = 8, noise: float = 0.6) -> ImageTask:
    rng = np.random.RandomState(seed)
    protos = rng.randn(n_classes, hw, hw, 1).astype(np.float32)
    # low-pass the prototypes so convolutions have local structure to find
    k = np.ones((3, 3)) / 9.0
    for c in range(n_classes):
        p = protos[c, :, :, 0]
        p = np.pad(p, 1, mode="edge")
        sm = sum(p[i:i + hw, j:j + hw] * k[i, j]
                 for i in range(3) for j in range(3))
        protos[c, :, :, 0] = sm
    y = rng.randint(0, n_classes, size=n).astype(np.int32)
    x = protos[y] + noise * rng.randn(n, hw, hw, 1).astype(np.float32)
    return ImageTask(x.astype(np.float32), y, n_classes)


def make_lm_task(seed: int, *, n_seq: int = 2048, seq_len: int = 32,
                 vocab: int = 64) -> Tuple[np.ndarray, int]:
    """Deterministic 'modular language': t_{i+1} = (t_i + t_{i-1}) % vocab.

    Perfectly learnable; next-token accuracy → 1.0 for a capable model.
    """
    rng = np.random.RandomState(seed)
    toks = np.zeros((n_seq, seq_len), np.int32)
    toks[:, 0] = rng.randint(0, vocab, n_seq)
    toks[:, 1] = rng.randint(0, vocab, n_seq)
    for i in range(2, seq_len):
        toks[:, i] = (toks[:, i - 1] + toks[:, i - 2]) % vocab
    return toks, vocab


# ---------------------------------------------------------------------------
# federated partitioners (paper §5.1.2)
# ---------------------------------------------------------------------------

def partition_iid(seed: int, n: int, num_clients: int) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def partition_dirichlet(seed: int, labels: np.ndarray, num_clients: int,
                        alpha: float = 0.3) -> List[np.ndarray]:
    """Non-IID-1: per-label client proportions ~ Dir(alpha)."""
    if len(labels) < num_clients:
        # the repair loop below cannot give every client a sample
        raise ValueError(
            f"cannot partition {len(labels)} samples over "
            f"{num_clients} clients — every client needs at least one")
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    out: List[List[int]] = [[] for _ in range(num_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            out[cid].extend(part.tolist())
    # guarantee every client has at least one sample; donors must keep
    # one themselves or popping would re-empty a just-repaired client
    for cid in range(num_clients):
        if not out[cid]:
            donors = [i for i in range(num_clients) if len(out[i]) > 1]
            if not donors:
                raise ValueError(
                    f"alpha={alpha} left client {cid} empty and no "
                    "client has a sample to spare — use more samples or "
                    "fewer clients")
            donor = max(donors, key=lambda i: len(out[i]))
            out[cid].append(out[donor].pop())
    return [np.sort(np.array(o, dtype=np.int64)) for o in out]


def partition_labels(seed: int, labels: np.ndarray, num_clients: int,
                     labels_per_client: int = 3) -> List[np.ndarray]:
    """Non-IID-2: each client sees only ``labels_per_client`` labels."""
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    # deal labels round-robin from repeated shuffles: every client gets
    # exactly `labels_per_client` distinct labels AND every label is owned
    # by ≥1 client (so no data is orphaned and no restriction is violated)
    deck: List[int] = []
    while len(deck) < num_clients * labels_per_client:
        deck.extend(rng.permutation(n_classes).tolist())
    client_labels: List[List[int]] = []
    for cid in range(num_clients):
        ls: List[int] = []
        for l in deck[cid * labels_per_client:]:
            if l not in ls:
                ls.append(l)
            if len(ls) == labels_per_client:
                break
        client_labels.append(ls)
    per_label_clients: Dict[int, List[int]] = {c: [] for c in range(n_classes)}
    for cid, ls in enumerate(client_labels):
        for l in ls:
            per_label_clients[int(l)].append(cid)
    out: List[List[int]] = [[] for _ in range(num_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        owners = per_label_clients[c]
        if not owners:   # possible when num_clients*k < n_classes
            owners = [int(rng.randint(num_clients))]
            client_labels[owners[0]].append(c)
        for k, part in enumerate(np.array_split(idx, len(owners))):
            out[owners[k]].extend(part.tolist())
    return [np.sort(np.array(o, dtype=np.int64)) for o in out]


def make_partition(kind: str, seed: int, labels: np.ndarray,
                   num_clients: int, **kw) -> List[np.ndarray]:
    if kind == "iid":
        return partition_iid(seed, len(labels), num_clients)
    if kind == "noniid1":
        return partition_dirichlet(seed, labels, num_clients,
                                   alpha=kw.get("alpha", 0.3))
    if kind == "noniid2":
        return partition_labels(seed, labels, num_clients,
                                labels_per_client=kw.get("labels_per_client", 3))
    raise ValueError(f"unknown partition kind {kind!r}")


# ---------------------------------------------------------------------------
# fixed-shape local batch sampling (scan-friendly)
# ---------------------------------------------------------------------------

def sample_local_batches(seed: int, x: np.ndarray, y: np.ndarray,
                         idx: np.ndarray, *, steps: int, batch: int):
    """(steps, batch, ...) stacked batches sampled with replacement."""
    rng = np.random.RandomState(seed)
    take = rng.choice(idx, size=(steps, batch), replace=True)
    return jnp.asarray(x[take]), jnp.asarray(y[take])
