"""Synthetic data + federated partitioners + device-resident datasets."""
from .synthetic import (  # noqa: F401
    ImageTask, make_image_task, make_lm_task, make_partition,
    partition_dirichlet, partition_iid, partition_labels,
    sample_local_batches,
)
from .federated import (  # noqa: F401
    CohortedDataset, CohortShard, FederatedDataset, cohort_gather,
    make_cohorted_dataset, make_federated_dataset,
)
