"""Synthetic data + federated partitioners."""
from .synthetic import (  # noqa: F401
    ImageTask, make_image_task, make_lm_task, make_partition,
    partition_dirichlet, partition_iid, partition_labels,
    sample_local_batches,
)
