"""Synthetic data + federated partitioners + device-resident datasets."""
from .synthetic import (  # noqa: F401
    ImageTask, make_image_task, make_lm_task, make_partition,
    partition_dirichlet, partition_iid, partition_labels,
    sample_local_batches,
)
from .federated import FederatedDataset, make_federated_dataset  # noqa: F401
