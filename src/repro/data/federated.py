"""Device-resident federated dataset — the scan engine's data layer.

The host-loop engines fed each round from Python callbacks
(``client_batch_fn(rnd, cid)`` + ``stack_client_batches``): one numpy
fancy-index, one host→device transfer, and one stack per round.  A
multi-round ``lax.scan`` program cannot call back into Python, so the
whole dataset moves onto the device ONCE:

  - ``x, y``            stacked example arrays, (N, ...) device-resident;
  - ``client_idx``      (C, Lmax) int32 partition matrix — row c lists
                        client c's example indices, wrap-padded to the
                        longest client so the matrix is rectangular;
  - ``client_len``      (C,) int32 true partition sizes (sampling draws
                        positions modulo the real length, so the padding
                        is never sampled).

``gather_batches(round_idx, picked)`` is the in-program replacement for
the host batch path: a pure jax function ``(round_idx, picked) ->
(K, S, B, ...)`` batches, traceable inside jit / scan.  Batch positions
derive from ``fold_in(fold_in(key(batch_seed), round_idx), cid)``, so the
same (round, client) always yields the same batch — on the host (legacy
``batch_fn`` adapter, used by the looped/batched engines) and inside the
scan program alike.  That shared derivation is what makes the three
engines' trajectories bit-comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class FederatedDataset:
    """Stacked examples + partition index matrices, all device-resident."""

    x: jax.Array                    # (N, ...) examples
    y: jax.Array                    # (N,) labels / targets
    client_idx: jax.Array           # (C, Lmax) int32, wrap-padded rows
    client_len: jax.Array           # (C,) int32 true sizes
    x_test: Optional[jax.Array]     # (Nt, ...) device-resident test set
    y_test: Optional[jax.Array]     # (Nt,)
    batch_seed: int = 0

    @property
    def num_clients(self) -> int:
        return self.client_idx.shape[0]

    # ---- in-program batch gather -------------------------------------

    def _client_key(self, round_idx, cid) -> jax.Array:
        key = jax.random.key(self.batch_seed)
        key = jax.random.fold_in(key, round_idx)
        return jax.random.fold_in(key, cid)

    def client_batch(self, round_idx, cid, *, steps: int,
                     batch: int) -> Tuple[jax.Array, jax.Array]:
        """(S, B, ...) local batches for one client — pure, traceable."""
        key = self._client_key(round_idx, cid)
        pos = jax.random.randint(key, (steps, batch), 0,
                                 self.client_len[cid])
        take = self.client_idx[cid, pos]
        return self.x[take], self.y[take]

    def gather_batches(self, round_idx, picked, *, steps: int,
                       batch: int) -> Tuple[jax.Array, jax.Array]:
        """(K, S, B, ...) batches for the picked clients, in-program.

        ``picked`` is a (K,) int32 array; ``round_idx`` may be traced
        (it is the scan counter inside the experiment program).
        """
        return jax.vmap(lambda c: self.client_batch(
            round_idx, c, steps=steps, batch=batch))(picked)

    # ---- legacy host adapter -----------------------------------------

    def batch_fn(self, *, steps: int, batch: int) -> Callable[[int, int], Any]:
        """``client_batch_fn(rnd, cid)`` adapter for the host-loop engines.

        Same key derivation ⇒ identical batch values to the in-program
        gather; jitted so repeated host calls stay cheap.
        """
        fn = jax.jit(lambda r, c: self.client_batch(
            r, c, steps=steps, batch=batch))
        return lambda rnd, cid: fn(jnp.int32(rnd), jnp.int32(cid))


def make_federated_dataset(
    x: np.ndarray,
    y: np.ndarray,
    parts: Sequence[np.ndarray],
    *,
    x_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
    batch_seed: int = 0,
) -> FederatedDataset:
    """Stack a partitioned task onto the device.

    ``parts`` is the partitioner output (one index array per client, as
    from :func:`repro.data.make_partition`).  Rows of the index matrix are
    wrap-padded (cycled) to the longest client so a rectangular int32
    matrix can live on device; sampling never reads the padding because
    positions are drawn in ``[0, client_len)``.
    """
    lens = np.array([len(p) for p in parts], np.int32)
    if (lens <= 0).any():
        raise ValueError("every client needs at least one example")
    lmax = int(lens.max())
    idx = np.stack([np.resize(np.asarray(p, np.int64), lmax)
                    for p in parts]).astype(np.int32)
    return FederatedDataset(
        x=jnp.asarray(x), y=jnp.asarray(y),
        client_idx=jnp.asarray(idx), client_len=jnp.asarray(lens),
        x_test=None if x_test is None else jnp.asarray(x_test),
        y_test=None if y_test is None else jnp.asarray(y_test),
        batch_seed=batch_seed)
