"""Device-resident federated dataset — the scan engine's data layer.

The host-loop engines fed each round from Python callbacks
(``client_batch_fn(rnd, cid)`` + ``stack_client_batches``): one numpy
fancy-index, one host→device transfer, and one stack per round.  A
multi-round ``lax.scan`` program cannot call back into Python, so the
whole dataset moves onto the device ONCE:

  - ``x, y``            stacked example arrays, (N, ...) device-resident;
  - ``client_idx``      (C, Lmax) int32 partition matrix — row c lists
                        client c's example indices, wrap-padded to the
                        longest client so the matrix is rectangular;
  - ``client_len``      (C,) int32 true partition sizes (sampling draws
                        positions modulo the real length, so the padding
                        is never sampled).

``gather_batches(round_idx, picked)`` is the in-program replacement for
the host batch path: a pure jax function ``(round_idx, picked) ->
(K, S, B, ...)`` batches, traceable inside jit / scan.  Batch positions
derive from ``fold_in(fold_in(key(batch_seed), round_idx), cid)``, so the
same (round, client) always yields the same batch — on the host (legacy
``batch_fn`` adapter, used by the looped/batched engines) and inside the
scan program alike.  That shared derivation is what makes the three
engines' trajectories bit-comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class FederatedDataset:
    """Stacked examples + partition index matrices, all device-resident."""

    x: jax.Array                    # (N, ...) examples
    y: jax.Array                    # (N,) labels / targets
    client_idx: jax.Array           # (C, Lmax) int32, wrap-padded rows
    client_len: jax.Array           # (C,) int32 true sizes
    x_test: Optional[jax.Array]     # (Nt, ...) device-resident test set
    y_test: Optional[jax.Array]     # (Nt,)
    batch_seed: int = 0

    @property
    def num_clients(self) -> int:
        return self.client_idx.shape[0]

    # ---- in-program batch gather -------------------------------------

    def _client_key(self, round_idx, cid) -> jax.Array:
        key = jax.random.key(self.batch_seed)
        key = jax.random.fold_in(key, round_idx)
        return jax.random.fold_in(key, cid)

    def client_batch(self, round_idx, cid, *, steps: int,
                     batch: int) -> Tuple[jax.Array, jax.Array]:
        """(S, B, ...) local batches for one client — pure, traceable."""
        key = self._client_key(round_idx, cid)
        pos = jax.random.randint(key, (steps, batch), 0,
                                 self.client_len[cid])
        take = self.client_idx[cid, pos]
        return self.x[take], self.y[take]

    def gather_batches(self, round_idx, picked, *, steps: int,
                       batch: int) -> Tuple[jax.Array, jax.Array]:
        """(K, S, B, ...) batches for the picked clients, in-program.

        ``picked`` is a (K,) int32 array; ``round_idx`` may be traced
        (it is the scan counter inside the experiment program).
        """
        return jax.vmap(lambda c: self.client_batch(
            round_idx, c, steps=steps, batch=batch))(picked)

    # ---- legacy host adapter -----------------------------------------

    def batch_fn(self, *, steps: int, batch: int) -> Callable[[int, int], Any]:
        """``client_batch_fn(rnd, cid)`` adapter for the host-loop engines.

        Same key derivation ⇒ identical batch values to the in-program
        gather; jitted so repeated host calls stay cheap.
        """
        fn = jax.jit(lambda r, c: self.client_batch(
            r, c, steps=steps, batch=batch))
        return lambda rnd, cid: fn(jnp.int32(rnd), jnp.int32(cid))

    # ---- the streaming tier ------------------------------------------

    def cohorted(self, cohort_size: int) -> "CohortedDataset":
        """This population re-sharded into host cohorts for the cohort
        engine (``Experiment.run(engine="cohort")``)."""
        return CohortedDataset.from_federated(self, cohort_size)


def _as_parts_list(parts) -> List[np.ndarray]:
    """Normalize a partition spec to a list of per-client index arrays.

    Accepts the partitioner output (a sequence of 1-D arrays) or — the
    population-scale fast path — a 2-D ``(C, L)`` array meaning C clients
    of uniform length L (``make_cohorted_dataset`` at C = 1e6 cannot
    afford a million tiny-array concatenations).
    """
    if isinstance(parts, np.ndarray) and parts.ndim == 2:
        return parts          # handled vectorized by the cohort builder
    return [np.asarray(p, np.int64) for p in parts]


# ---------------------------------------------------------------------------
# cohort-sharded populations: the streaming tier's data layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CohortShard:
    """One cohort's host-side slice of the population.

    ``idx`` is the wrap-padded index matrix in COHORT-LOCAL example
    coordinates (rows index into ``ex_idx`` order), padded only to this
    cohort's own longest client — under client-size skew the giant
    client inflates one shard's matrix instead of all of them
    (the whole-population matrix is C × global-Lmax).
    """

    clients: np.ndarray     # (Cc,) int32 global client ids
    ex_idx: np.ndarray      # (Ne,) int64 global example rows, client-major
    idx: np.ndarray         # (Cc, Lc) int32 cohort-local wrap-padded rows
    lens: np.ndarray        # (Cc,) int32 true client sizes

    @property
    def num_clients(self) -> int:
        return self.idx.shape[0]

    @property
    def num_examples(self) -> int:
        return self.ex_idx.shape[0]

    @property
    def lmax(self) -> int:
        return self.idx.shape[1]


@dataclasses.dataclass(frozen=True)
class CohortedDataset:
    """A client population sharded into host-resident cohorts.

    The streaming counterpart of :class:`FederatedDataset`: examples and
    index matrices stay in HOST numpy, sharded by cohort (a contiguous
    block of ``cohort_size`` clients), and :meth:`stage` moves ONE
    cohort's block to the device — padded to the population-wide maxima
    so every cohort shares a single compiled program shape.  The cohort
    engine (``fed/engine.make_cohort_engine``) double-buffers these
    blocks host→device while the current cohort's round program runs,
    which is what lets C = 1e5–1e6 simulated clients run on a device
    that could never hold the whole population.

    Batch-key derivation is identical to :class:`FederatedDataset`
    (keys fold the GLOBAL client id; index rows are cohort-local), so
    cohort-partitioned gathers equal whole-population gathers exactly.
    """

    x: np.ndarray                   # (N, ...) host examples
    y: np.ndarray                   # (N,) host labels
    shards: Tuple[CohortShard, ...]
    cohort_of: np.ndarray           # (C,) int32 client -> cohort id
    local_of: np.ndarray            # (C,) int32 client -> index in cohort
    x_test: Optional[jax.Array]     # device-resident (tiny next to x)
    y_test: Optional[jax.Array]
    batch_seed: int = 0

    @property
    def num_clients(self) -> int:
        return self.cohort_of.shape[0]

    @property
    def num_cohorts(self) -> int:
        return len(self.shards)

    # staging pads: one compiled program shape across ALL cohorts
    @property
    def pad_clients(self) -> int:
        return max(s.num_clients for s in self.shards)

    @property
    def pad_examples(self) -> int:
        return max(s.num_examples for s in self.shards)

    @property
    def pad_len(self) -> int:
        return max(s.lmax for s in self.shards)

    def stage(self, j: int) -> Dict[str, jax.Array]:
        """Cohort ``j``'s device block, padded to the population maxima.

        Padding rows get ``client_len = 1`` (a zero bound would break the
        in-program ``randint``) and index row 0 — they are only ever
        gathered for slots the engine weights/masks to zero.  This host
        slice-and-pad + transfer is exactly the work the cohort engine's
        prefetch thread hides behind the previous cohort's compute.
        """
        s = self.shards[j]
        xs = np.zeros((self.pad_examples,) + self.x.shape[1:], self.x.dtype)
        ys = np.zeros((self.pad_examples,) + self.y.shape[1:], self.y.dtype)
        xs[:s.num_examples] = self.x[s.ex_idx]
        ys[:s.num_examples] = self.y[s.ex_idx]
        idx = np.zeros((self.pad_clients, self.pad_len), np.int32)
        idx[:s.num_clients, :s.lmax] = s.idx
        lens = np.ones((self.pad_clients,), np.int32)
        lens[:s.num_clients] = s.lens
        return {"x": jax.device_put(jnp.asarray(xs)),
                "y": jax.device_put(jnp.asarray(ys)),
                "client_idx": jax.device_put(jnp.asarray(idx)),
                "client_len": jax.device_put(jnp.asarray(lens))}

    @classmethod
    def from_federated(cls, ds: FederatedDataset,
                       cohort_size: int) -> "CohortedDataset":
        """Re-shard a device-resident dataset into host cohorts."""
        idx = np.asarray(ds.client_idx)
        lens = np.asarray(ds.client_len)
        parts = [idx[c, :lens[c]] for c in range(ds.num_clients)]
        return make_cohorted_dataset(
            np.asarray(ds.x), np.asarray(ds.y), parts,
            cohort_size=cohort_size, x_test=ds.x_test, y_test=ds.y_test,
            batch_seed=ds.batch_seed)


def cohort_gather(block: Dict[str, jax.Array], round_idx, cids, locs,
                  *, steps: int, batch: int,
                  batch_seed: int) -> Tuple[jax.Array, jax.Array]:
    """(K, S, B, ...) batches for picked clients out of ONE staged cohort.

    Pure/traceable; the cohort-tier replacement for
    ``FederatedDataset.gather_batches``.  ``cids`` carries GLOBAL client
    ids (the batch key folds them, preserving whole-population key
    parity) while ``locs`` carries the cohort-LOCAL rows the staged
    index matrix is addressed by.
    """

    def one(cid, loc):
        key = jax.random.key(batch_seed)
        key = jax.random.fold_in(key, round_idx)
        key = jax.random.fold_in(key, cid)
        pos = jax.random.randint(key, (steps, batch), 0,
                                 block["client_len"][loc])
        take = block["client_idx"][loc, pos]
        return block["x"][take], block["y"][take]

    return jax.vmap(one)(cids, locs)


def make_cohorted_dataset(
    x: np.ndarray,
    y: np.ndarray,
    parts,
    *,
    cohort_size: int,
    x_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
    batch_seed: int = 0,
) -> CohortedDataset:
    """Shard a partitioned task into host-resident cohorts.

    ``parts`` is the partitioner output (one index array per client) or a
    2-D ``(C, L)`` array for uniform-size clients — the vectorized path
    population-scale synthetic benchmarks need.  Clients are assigned to
    cohorts contiguously: cohort ``j`` holds clients
    ``[j·cohort_size, (j+1)·cohort_size)``.
    """
    if cohort_size < 1:
        raise ValueError(f"cohort_size must be >= 1, got {cohort_size}")
    parts = _as_parts_list(parts)
    uniform = isinstance(parts, np.ndarray)
    C = parts.shape[0] if uniform else len(parts)
    if C == 0:
        raise ValueError("need at least one client")
    x = np.asarray(x)
    y = np.asarray(y)
    shards = []
    for c0 in range(0, C, cohort_size):
        c1 = min(c0 + cohort_size, C)
        if uniform:
            lens = np.full((c1 - c0,), parts.shape[1], np.int64)
            ex_idx = np.asarray(parts[c0:c1], np.int64).reshape(-1)
        else:
            plist = parts[c0:c1]
            lens = np.array([len(p) for p in plist], np.int64)
            if (lens <= 0).any():
                raise ValueError("every client needs at least one example")
            ex_idx = (np.concatenate(plist) if plist else
                      np.zeros((0,), np.int64))
        off = np.zeros_like(lens)
        np.cumsum(lens[:-1], out=off[1:])
        lc = int(lens.max())
        # wrap-padding in cohort-local coordinates: row c cycles client
        # c's own examples, exactly like make_federated_dataset's
        # np.resize rows (positions < client_len never see the padding)
        grid = np.arange(lc, dtype=np.int64)[None, :]
        idx = (off[:, None] + grid % lens[:, None]).astype(np.int32)
        shards.append(CohortShard(
            clients=np.arange(c0, c1, dtype=np.int32), ex_idx=ex_idx,
            idx=idx, lens=lens.astype(np.int32)))
    ids = np.arange(C, dtype=np.int32)
    return CohortedDataset(
        x=x, y=y, shards=tuple(shards),
        cohort_of=ids // np.int32(cohort_size),
        local_of=ids % np.int32(cohort_size),
        x_test=None if x_test is None else jnp.asarray(x_test),
        y_test=None if y_test is None else jnp.asarray(y_test),
        batch_seed=batch_seed)


def make_federated_dataset(
    x: np.ndarray,
    y: np.ndarray,
    parts: Sequence[np.ndarray],
    *,
    x_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
    batch_seed: int = 0,
) -> FederatedDataset:
    """Stack a partitioned task onto the device.

    ``parts`` is the partitioner output (one index array per client, as
    from :func:`repro.data.make_partition`).  Rows of the index matrix are
    wrap-padded (cycled) to the longest client so a rectangular int32
    matrix can live on device; sampling never reads the padding because
    positions are drawn in ``[0, client_len)``.
    """
    lens = np.array([len(p) for p in parts], np.int32)
    if (lens <= 0).any():
        raise ValueError("every client needs at least one example")
    lmax = int(lens.max())
    idx = np.stack([np.resize(np.asarray(p, np.int64), lmax)
                    for p in parts]).astype(np.int32)
    return FederatedDataset(
        x=jnp.asarray(x), y=jnp.asarray(y),
        client_idx=jnp.asarray(idx), client_len=jnp.asarray(lens),
        x_test=None if x_test is None else jnp.asarray(x_test),
        y_test=None if y_test is None else jnp.asarray(y_test),
        batch_seed=batch_seed)
