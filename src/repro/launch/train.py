"""End-to-end training driver.

Two modes:
  --run     actually train a reduced variant of the selected arch on the
            synthetic LM task on this host (CPU) — the runnable e2e check
            (a few hundred steps of a ~100M-param-class model works).
  --lower   lower/compile the FULL config against the production mesh
            (identical to dryrun, provided here as the deploy entrypoint).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --run \
      --steps 200 --d-model 256 --layers 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs
from ..data import make_lm_task
from ..models.registry import build_model
from .steps import TrainHParams, make_train_step


def run_host_training(arch: str, *, steps: int, layers: int, d_model: int,
                      batch: int, seq: int, lr: float,
                      algorithm: str = "centralized",
                      log_every: int = 20):
    cfg = get_config(arch).reduced(layers=layers, d_model=d_model, vocab=64)
    model = build_model(cfg)
    toks, vocab = make_lm_task(0, n_seq=4096, seq_len=seq + 1, vocab=64)
    params = model.init(jax.random.key(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={arch} reduced: {n_params/1e6:.1f}M params, "
          f"steps={steps}, batch={batch}, seq={seq}")

    hp = TrainHParams(lr=lr, momentum=0.9)
    step_fn = jax.jit(make_train_step(model, hp))
    momentum = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def wrap(t):
        b = {"tokens": t[:, :-1], "labels": t[:, 1:]}
        if cfg.arch_type == "vlm":
            B, S = t[:, :-1].shape
            b["frontend_embeds"] = jnp.zeros(
                (B, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
            b["positions3"] = jnp.broadcast_to(
                jnp.arange(S + cfg.frontend_tokens)[None, None],
                (3, B, S + cfg.frontend_tokens))
        elif cfg.arch_type == "audio":
            B, S = t[:, :-1].shape
            b["frontend_embeds"] = jnp.zeros((B, S, cfg.d_model), cfg.dtype)
        return b

    rng = np.random.RandomState(0)
    t0 = time.time()
    losses = []
    for i in range(steps):
        take = rng.randint(0, len(toks), batch)
        loss_val = None
        params, momentum, loss_val = step_fn(params, momentum,
                                             wrap(jnp.asarray(toks[take])))
        losses.append(float(loss_val))
        if i % log_every == 0 or i == steps - 1:
            dt = time.time() - t0
            print(f"  step {i:4d} loss {losses[-1]:.4f} "
                  f"({(i+1)*batch*seq/max(dt,1e-9):.0f} tok/s)")
    assert losses[-1] < losses[0], "training did not reduce loss"
    print(f"final loss {losses[-1]:.4f} (initial {losses[0]:.4f}) — OK")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--run", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.run:
        run_host_training(args.arch, steps=args.steps, layers=args.layers,
                          d_model=args.d_model, batch=args.batch,
                          seq=args.seq, lr=args.lr)
    else:
        # production lowering path (shares dryrun's machinery)
        from .dryrun import run_and_save
        run_and_save(args.arch, args.shape, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
