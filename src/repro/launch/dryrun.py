import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) combination against the production
mesh with ShapeDtypeStruct inputs — no allocation, proving the sharding
config is coherent and the program fits.

Outputs one JSON record per combination into experiments/dryrun/:
memory_analysis, cost_analysis, HLO collective byte totals (per §Roofline),
wall compile time.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--all] [--sharded --algo fedpm]
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import INPUT_SHAPES, get_config, list_archs, shape_applicable
from ..models.registry import (build_model, cache_specs, input_specs,
                               param_specs, count_params)
from ..sharding.rules import (batch_shardings, cache_shardings,
                              param_shardings)
from ..sharding import hlo_analysis
from ..sharding.hints import mesh_context
from .mesh import V5E, make_production_mesh
from .steps import TrainHParams, step_for_kind

# gradient-accumulation factor per arch for the train shape (activation
# memory ÷ M; chosen so every arch fits v5e's 16 GB HBM)
MICROBATCHES = {
    "qwen3-moe-235b-a22b": 4,
    "zamba2-1.2b": 4,
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _f32_promotion_bytes(hlo: str, threshold: float = 256e6) -> float:
    """Bytes of large f32 buffers produced by bf16→f32 converts — the
    XLA-CPU bf16-promotion artifact (absent on TPU)."""
    total = 0.0
    seen = set()
    for m in re.finditer(
            r"%([\w.\-]+) = f32\[([0-9,]+)\][^=\n]*"
            r"(?:convert|wrapped_convert[\w.]*)\(", hlo):
        name, dims = m.groups()
        if name in seen:
            continue
        seen.add(name)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= threshold:
            total += n * 4
    return total


def _momentum_specs(params):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              dtype=jnp.bfloat16, sharded: bool = False,
              fed_algo: str = "fedmrn", fed_rounds: int = 1):
    """Lower+compile one combination; returns the result record dict."""
    cfg = get_config(arch)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": dtype})
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "params": count_params(cfg),
           "sharded": sharded}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = build_model(cfg)
    p_specs = param_specs(cfg)
    # params in the requested dtype
    p_specs = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), p_specs)
    # ZeRO-shard params over the data axes in every mode: training shards
    # grads/opt-state alongside; serving shards weights (gathered at use)
    p_shard = param_shardings(p_specs, mesh, num_layers=cfg.num_layers,
                              encoder_layers=cfg.encoder_layers,
                              zero=True)
    specs = input_specs(cfg, shape)
    b_shard = batch_shardings(specs["batch"], mesh)

    if sharded:
        from ..fed import FLConfig, MaskCodec, get_algorithm
        from ..fed.codecs import mask_count_bits, min_count_dtype
        from ..fed.sharded import (PodRoundSpec, client_axis_of,
                                   make_pod_round, pod_batch_specs,
                                   pod_param_shardings)
        C = mesh.shape[client_axis_of(mesh)]
        algo = get_algorithm(fed_algo)
        # mask-codec families default to shared noise on the pod path:
        # the cross-client collective then carries integer mask counts
        # (int_mask_agg auto-enables inside make_pod_round)
        probe_codec = algo.codec(FLConfig(algorithm=fed_algo), p_specs)
        is_mask = isinstance(probe_codec, MaskCodec)
        flc = FLConfig(algorithm=fed_algo, num_clients=C,
                       clients_per_round=C, local_steps=2,
                       shared_noise=is_mask)
        fb_specs = pod_batch_specs(
            {k: v for k, v in specs["batch"].items() if k != "positions3"},
            C, flc.local_steps)
        step, args, in_shardings = make_pod_round(
            fed_algo, mesh, PodRoundSpec(config=flc, rounds=fed_rounds),
            loss_fn=model.loss_fn, p_specs=p_specs,
            p_shard=pod_param_shardings(
                p_specs, mesh, num_layers=cfg.num_layers,
                encoder_layers=cfg.encoder_layers),
            batch_specs=fb_specs)
        rec["fed_rounds"] = fed_rounds
        rec["algorithm"] = fed_algo
        # the codec as the pod program runs it (flc carries the pod
        # shared-noise default, so fedmrn IS count-aggregatable here)
        pod_codec = algo.codec(flc, p_specs)
        rec["codec"] = type(pod_codec).__name__
        rec["uplink"] = pod_codec.wire_bits(p_specs).row()
        if is_mask and pod_codec.count_aggregatable:
            # the wire format the pod aggregation uses for mask counts
            rec["mask_agg"] = {
                "logical_bits": mask_count_bits(C),
                "dtype": np.dtype(min_count_dtype(C)).name,
            }
    elif shape.kind == "train":
        hp = TrainHParams(microbatches=MICROBATCHES.get(arch, 1))
        step = step_for_kind(model, "train", hp)
        m_specs = _momentum_specs(p_specs)
        m_shard = param_shardings(m_specs, mesh, num_layers=cfg.num_layers,
                                  encoder_layers=cfg.encoder_layers,
                                  zero=True)
        args = (p_specs, m_specs, specs["batch"])
        in_shardings = (p_shard, m_shard, b_shard)
    elif shape.kind == "prefill":
        step = step_for_kind(model, "prefill")
        args = (p_specs, specs["batch"])
        in_shardings = (p_shard, b_shard)
    else:  # decode
        step = step_for_kind(model, "decode")
        c_specs = specs["cache"]
        c_shard = cache_shardings(c_specs, mesh, batch=shape.global_batch)
        args = (p_specs, c_specs, specs["batch"])
        in_shardings = (p_shard, c_shard, b_shard)

    hint_axes = None
    if sharded:
        # clients train independently: activation hints must not span the
        # client axis ('pod' when multi-pod, else 'data')
        from ..fed.sharded import client_axis_of
        ca = client_axis_of(mesh)
        hint_axes = tuple(a for a in ("pod", "data")
                          if a in mesh.shape and a != ca)
    t0 = time.time()
    with mesh_context(mesh, batch_axes=hint_axes):
        jitted = jax.jit(step, in_shardings=in_shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # some jax builds return [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = hlo_analysis.analyze(hlo)
    promo = _f32_promotion_bytes(hlo)
    if sharded:
        # element dtypes crossing the client axis: for count-aggregatable
        # mask codecs the big all-reduce must be integer (s8/s16), the
        # acceptance probe of the ⌈log2(K+1)⌉-bit wire format
        rec["allreduce_dtypes"] = sorted(set(
            re.findall(r"= (\w+)\[[0-9,]*\][^=\n]*all-reduce", hlo)))

    rec.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        n_chips=n_chips,
        memory={
            "argument_B": int(ma.argument_size_in_bytes),
            "output_B": int(ma.output_size_in_bytes),
            "temp_B": int(ma.temp_size_in_bytes),
            "total_B": int(ma.argument_size_in_bytes
                           + ma.temp_size_in_bytes),
            # XLA-CPU promotes ALL bf16 compute (incl. loop carries) to
            # f32; on TPU bf16 is native and these copies don't exist.
            # We report the identified promotion buffers and a
            # TPU-adjusted fit (see EXPERIMENTS.md §Dry-run caveats).
            "cpu_f32_promotion_B": int(promo),
            "fits_v5e": bool(ma.argument_size_in_bytes
                             + ma.temp_size_in_bytes < V5E.hbm_bytes),
            "fits_v5e_tpu_adjusted": bool(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes - promo
                < V5E.hbm_bytes),
        },
        xla_cost={k: float(v) for k, v in ca.items()
                  if k in ("flops", "bytes accessed", "transcendentals")},
        hlo_analysis=coll.as_dict(),
    )
    return rec


def run_and_save(arch, shape_name, *, multi_pod, sharded=False,
                 fed_algo="fedmrn", fed_rounds=1, out_dir=OUT_DIR):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if sharded:
        tag += f"__{fed_algo}"
        if fed_rounds > 1:
            tag += f"__r{fed_rounds}"
    try:
        rec = lower_one(arch, shape_name, multi_pod=multi_pod,
                        sharded=sharded, fed_algo=fed_algo,
                        fed_rounds=fed_rounds)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    mem = rec.get("memory", {})
    print(f"[{rec['status']:7s}] {tag} "
          f"compile={rec.get('compile_s', '-')}s "
          f"temp={mem.get('temp_B', 0)/1e9:.2f}GB "
          f"{rec.get('reason', rec.get('error', ''))[:80]}")
    return rec


def _probe_wire_overheads(codec, algo, cfg, probe):
    """MEASURED serde overheads of one service-tier exchange.

    Frames one zero-update uplink exactly as the client posts it
    (``serde.dumps_msg`` with round/cid/weight/loss meta) and one model
    downlink exactly as the coordinator publishes it, and returns
    ``(uplink_framing_bits, downlink_overhead_bits)`` — the frame bytes
    beyond the raw payload on each leg.  Deterministic: the frame layout
    is sorted-keys serde, so these are THE figures a service run pays
    per message.
    """
    from ..fed.codecs import MaskCodec
    from ..fed.service import serde

    zeros = jax.tree_util.tree_map(jnp.zeros_like, probe)
    if isinstance(codec, MaskCodec):
        payload = {"mask": zeros}
        if codec.carries_seed:
            payload["seed"] = jax.random.key(0)
    elif getattr(codec, "needs_key", False):
        payload = {"value": zeros, "key": jax.random.key(0)}
    else:
        payload = {"value": zeros}
    msg = codec.encode(payload)
    body = serde.dumps_msg(msg, round=0, cid=0, weight=1.0, loss=0.0)
    up_framing = len(body) * 8 - msg.bits
    state = algo.init_state(cfg, probe)
    blob = serde.dumps_tree(
        {"params": probe, "state": state}, round=0, rounds=cfg.rounds,
        seed=0, algorithm=cfg.algorithm, done=False,
        cids=[0] * cfg.clients_per_round)
    dl_overhead = len(blob) * 8 - serde.tree_payload_bits(probe)
    return int(up_framing), int(dl_overhead)


def serve_smoke(fed_algo: str = "fedmrn", *, rounds: int = 2,
                faults: bool = False) -> dict:
    """Loopback smoke of the wire-true coordinator (deliverable of the
    service subsystem): run a tiny federation of ``fed_algo`` over real
    HTTP on a probe MLP and print measured-vs-analytic wire accounting.

    Every figure on the "measured" side was counted from bytes that
    actually crossed a socket; the "analytic" side is the codec's
    :meth:`CommRecord` claim.  The two must agree exactly (the
    acceptance criterion ``tests/test_service.py`` enforces).

    With ``faults=True`` the run rides a :class:`FaultPlan` (one dropped
    + one corrupt uplink, quorum = K-1) and prints the degraded-round
    accounting instead of silently pretending the federation was clean.
    """
    from ..data import make_federated_dataset, make_image_task, make_partition
    from ..fed import (Experiment, ExperimentSpec, FaultPlan, FLConfig,
                       ServiceConfig, algorithm_codec)
    from ..models.cnn import mlp_apply, mlp_init, mlp_loss

    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(jax.random.key(0), d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=fed_algo, num_clients=8, clients_per_round=4,
                   rounds=rounds, local_steps=2, batch_size=16, lr=0.1)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    exp = Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                    data=ds, config=cfg,
                                    eval_apply=mlp_apply))
    service = None
    if faults:
        service = ServiceConfig(
            mode="sync", quorum=cfg.clients_per_round - 1,
            run_timeout_s=120.0,
            faults=FaultPlan(drop_uplinks=((0, 0),),
                             corrupt_uplinks=((min(1, rounds - 1), 1),)))
    t0 = time.time()
    res = exp.run(engine="service", service=service)
    wall = time.time() - t0
    rep = exp.service_report
    codec = algorithm_codec(cfg, params)
    analytic_up = codec.measured_bits(params)
    print(f"service smoke: {fed_algo} K={cfg.clients_per_round} "
          f"R={cfg.rounds} on {rep.base_url} ({wall:.1f}s) "
          f"final_acc={res.final_acc:.3f}")
    print(f"  uplink    measured {rep.uplink_payload_bits:>10d} b payload "
          f"(+{rep.uplink_framing_bits} b framing) over "
          f"{rep.n_uplinks} messages")
    print(f"            analytic {rep.n_uplinks * analytic_up:>10d} b "
          f"({analytic_up} b/client x {rep.n_uplinks})  "
          f"{'OK' if rep.uplink_payload_bits == rep.n_uplinks * analytic_up else 'MISMATCH'}")
    print(f"  downlink  measured {rep.downlink_params_bits:>10d} b params "
          f"per request (+{rep.downlink_overhead_bits} b state+framing), "
          f"{rep.downlink_requests} requests")
    print(f"            analytic {rep.comm.downlink_bits:>10d} b  "
          f"{'OK' if rep.downlink_params_bits == rep.comm.downlink_bits else 'MISMATCH'}")
    out = {"algorithm": fed_algo, "final_acc": res.final_acc,
           "measured_uplink_bits": rep.uplink_payload_bits,
           "analytic_uplink_bits": rep.n_uplinks * analytic_up,
           "measured_downlink_bits": rep.downlink_params_bits,
           "wall_s": wall}
    if faults:
        balanced = rep.n_uplinks == sum(rep.participation)
        print(f"  degraded  participation {list(rep.participation)} of "
              f"expected {list(rep.expected)}; rejected {dict(rep.rejected)}; "
              f"client faults {dict(rep.client_faults)}  "
              f"{'OK' if balanced else 'MISMATCH'}")
        out.update({"participation": list(rep.participation),
                    "rejected": dict(rep.rejected),
                    "client_faults": dict(rep.client_faults),
                    "accounting_balanced": balanced})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sharded", "--fedmrn", dest="sharded",
                    action="store_true",
                    help="lower the registry-driven pod round instead of "
                         "plain steps (--fedmrn is the legacy alias)")
    ap.add_argument("--serve", action="store_true",
                    help="loopback smoke of the wire-true coordinator "
                         "(engine='service') on a probe MLP: measured vs "
                         "analytic uplink/downlink bits for --algo")
    ap.add_argument("--serve-faults", action="store_true",
                    help="with --serve: inject a FaultPlan (one dropped "
                         "+ one corrupt uplink, quorum=K-1) and print "
                         "the degraded-round accounting")
    ap.add_argument("--list-algorithms", action="store_true",
                    help="print the simulation-engine algorithm registry "
                         "(name + per-client uplink bits/param on the "
                         "reduced arch) and exit")
    ap.add_argument("--algo", default=None,
                    help="pod-round algorithm: ANY registered name "
                         "(see --list-algorithms); default fedmrn")
    ap.add_argument("--fed-mode", default=None,
                    help="deprecated alias of --algo")
    ap.add_argument("--fed-rounds", type=int, default=1,
                    help="rounds fused per dispatch (lax.scan over the "
                         "pod round body when > 1)")
    args = ap.parse_args()
    fed_algo = args.algo or args.fed_mode or "fedmrn"

    if args.serve:
        serve_smoke(fed_algo, faults=args.serve_faults)
        return

    if args.list_algorithms:
        # the simulation registry — every name here is runnable through
        # the Experiment API AND lowerable on the pod path (--sharded
        # --algo <name>).  One row per entry: the codec's comm table
        # (CommRecord.row(): exact MEASURED bpp, paper-style bpp,
        # downlink) on a small CNN probe model, plus the MEASURED serde
        # wire overheads the service tier pays per message (satellite:
        # these used to live only in ServiceReport, so the comm table
        # under-reported real wire cost).
        import dataclasses as _dc

        from ..fed import FLConfig, get_algorithm, list_algorithms
        from ..models.cnn import cnn_init
        probe = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))
        cfg0 = FLConfig()
        header = (f"{'algorithm':12s} {'codec':12s} {'bpp':>8s} "
                  f"{'bpp(paper)':>10s} {'uplink MB':>10s} "
                  f"{'downlink Mb':>12s} {'compr x':>8s} "
                  f"{'frame b':>8s} {'dl ovh b':>9s}")
        print(header)
        for name in list_algorithms():
            algo = get_algorithm(name)
            cfg = _dc.replace(cfg0, algorithm=name)
            codec = algo.codec(cfg, probe)
            framing, dl_overhead = _probe_wire_overheads(
                codec, algo, cfg, probe)
            row = _dc.replace(codec.wire_bits(probe),
                              framing_bits=framing,
                              downlink_overhead_bits=dl_overhead).row()
            print(f"{name:12s} {type(codec).__name__:12s} "
                  f"{row['uplink_bpp']:8.3f} "
                  f"{row['uplink_bpp_paper']:10.3f} "
                  f"{row['uplink_MB']:10.4f} "
                  f"{row['downlink_bits'] / 1e6:12.3f} "
                  f"{row['compression_x']:8.2f} "
                  f"{row['framing_bits']:8d} "
                  f"{row['downlink_overhead_bits']:9d}")
        return

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_and_save(arch, shape, multi_pod=mp,
                             sharded=args.sharded, fed_algo=fed_algo,
                             fed_rounds=args.fed_rounds)


if __name__ == "__main__":
    main()
