"""Production mesh construction (spec §MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant — importing this module never touches
jax device state.  v5e hardware constants for the roofline live here too.
"""
from __future__ import annotations

import dataclasses

import jax

from ..compat import mesh_axis_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke runs of the sharded programs."""
    return jax.make_mesh((1, 1), ("data", "model"), **mesh_axis_kwargs(2))


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e per-chip roofline constants (target hardware)."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # FLOP/s
    hbm_bw: float = 819e9               # B/s
    ici_bw: float = 50e9                # B/s per link
    hbm_bytes: float = 16e9             # capacity


V5E = HardwareSpec()
