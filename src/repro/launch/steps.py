"""Step builders: train_step / serve_step factories shared by the dry-run,
the real train/serve drivers, and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.registry import Model, build_model

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-3
    momentum: float = 0.9
    grad_clip: float = 1.0
    microbatches: int = 1   # gradient accumulation (activation memory ÷ M)


def make_train_step(model: Model, hp: TrainHParams = TrainHParams()
                    ) -> Callable:
    """(params, momentum, batch) -> (params, momentum, loss).

    SGD+momentum with fp32 momentum master state — the centralized
    (non-federated) training path used by train_4k shapes.  With
    ``microbatches > 1`` the global batch is split and gradients are
    accumulated in fp32 (same step semantics, activations ÷ M).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss_fn)(params, batch)

    def train_step(params, momentum, batch):
        if hp.microbatches > 1:
            M = hp.microbatches

            def split(x):
                # positions3 carries batch on dim 1
                if x.ndim >= 2 and x.shape[0] == 3:
                    return x.reshape((3, M, x.shape[1] // M) + x.shape[2:]
                                     ).transpose(1, 0, *range(2, x.ndim + 1))
                return x.reshape((M, x.shape[0] // M) + x.shape[1:])

            mb = {k: split(v) for k, v in batch.items()}

            def acc_body(carry, b):
                loss_sum, g_acc = carry
                loss, grads = grads_of(params, b)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (loss_sum + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0), g0), mb)
            loss = loss_sum / M
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
        else:
            loss, grads = grads_of(params, batch)
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree_util.tree_leaves(grads))
        scale = jnp.minimum(1.0, hp.grad_clip * jax.lax.rsqrt(gsq + 1e-12))
        new_m = jax.tree_util.tree_map(
            lambda m, g: hp.momentum * m + scale * g.astype(jnp.float32),
            momentum, grads)
        new_p = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - hp.lr * m).astype(p.dtype),
            params, new_m)
        return new_p, new_m, loss

    return train_step


def make_prefill_step(model: Model) -> Callable:
    """(params, batch) -> last-position logits (serving prefill)."""
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.arch_type == "audio":
            from ..models import encdec
            enc_out = encdec.encode(params, cfg, batch["frontend_embeds"])
            h = encdec._decoder_hidden(params, cfg, batch["tokens"], enc_out)
        elif cfg.arch_type == "hybrid":
            from ..models import zamba2
            h, _ = zamba2.forward_hidden(params, cfg, batch)
        else:
            from ..models import transformer
            h, _ = transformer.forward_hidden(params, cfg, batch,
                                              inference=True)
        last = h[:, -1]
        if cfg.tie_embeddings:
            head = params["embed"]["tok"].T
        else:
            head = params["head"]
        return last.astype(jnp.float32) @ head.astype(jnp.float32)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """(params, cache, tokens) -> (logits, cache). One decode token."""

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])

    return serve_step


def step_for_kind(model: Model, kind: str,
                  hp: TrainHParams = TrainHParams()) -> Callable:
    if kind == "train":
        return make_train_step(model, hp)
    if kind == "prefill":
        return make_prefill_step(model)
    if kind == "decode":
        return make_serve_step(model)
    raise ValueError(kind)
