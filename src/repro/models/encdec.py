"""Encoder–decoder transformer (seamless-m4t style, audio → text).

The audio frontend (mel + conformer conv) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, S_enc, d).
We implement the full transformer: bidirectional encoder over frames,
causal decoder with cross-attention, chunked-softmax LM loss, and a decode
path whose cache = per-layer self-attn KV + precomputed cross-attn KV.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import hints
from . import attention as attn_mod
from .layers import (chunked_xent, embed, embedding_init, gelu_mlp,
                     gelu_mlp_init, normal_init, rmsnorm, rmsnorm_init,
                     split_keys)

Params = Dict[str, Any]


def _enc_layer_init(key, cfg: ModelConfig) -> Params:
    ka, km = split_keys(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn_mod.attn_init(ka, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim,
                                   bias=True, dtype=cfg.dtype),
        "ln2": rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": gelu_mlp_init(km, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> Params:
    ka, kx, km = split_keys(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
        "self": attn_mod.attn_init(ka, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim,
                                   bias=True, dtype=cfg.dtype),
        "ln_x": rmsnorm_init(cfg.d_model, cfg.dtype),
        "cross": attn_mod.attn_init(kx, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.head_dim,
                                    bias=True, dtype=cfg.dtype),
        "ln2": rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": gelu_mlp_init(km, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def model_init(key, cfg: ModelConfig) -> Params:
    ke, kenc, kdec, kh = split_keys(key, 4)
    enc_keys = jnp.stack(split_keys(kenc, cfg.encoder_layers))
    dec_keys = jnp.stack(split_keys(kdec, cfg.num_layers))
    return {
        "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "enc_ln": rmsnorm_init(cfg.d_model, cfg.dtype),
        "final_ln": rmsnorm_init(cfg.d_model, cfg.dtype),
        "head": normal_init(kh, (cfg.d_model, cfg.vocab_size),
                            cfg.d_model ** -0.5, cfg.dtype),
    }


def encode(p: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub frontend embeddings → encoder states."""
    B, S = frames.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, lp):
        h = hints.hint_spec(h, {0: "batch", 2: "model"})
        a = attn_mod.attention_fwd(
            lp["attn"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions,
            rope_theta=cfg.rope_theta, causal=False)
        h = h + a
        h = h + gelu_mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, None

    h, _ = jax.lax.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        frames.astype(cfg.dtype), p["enc"])
    return rmsnorm(p["enc_ln"], h, cfg.norm_eps)


def _decoder_hidden(p, cfg, tokens, enc_out):
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    Se = enc_out.shape[1]
    kv_positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    h = embed(p["embed"], tokens)

    def body(h, lp):
        h = hints.hint_spec(h, {0: "batch", 2: "model"})
        a = attn_mod.attention_fwd(
            lp["self"], rmsnorm(lp["ln1"], h, cfg.norm_eps),
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions,
            rope_theta=cfg.rope_theta, causal=True)
        h = h + a
        c = attn_mod.attention_fwd(
            lp["cross"], rmsnorm(lp["ln_x"], h, cfg.norm_eps),
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=None, causal=False,
            x_kv=enc_out)
        h = h + c
        h = h + gelu_mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, None

    h, _ = jax.lax.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        h, p["dec"])
    return rmsnorm(p["final_ln"], h, cfg.norm_eps)


def loss_fn(p: Params, cfg: ModelConfig, batch) -> jax.Array:
    enc_out = encode(p, cfg, batch["frontend_embeds"])
    h = _decoder_hidden(p, cfg, batch["tokens"], enc_out)
    return chunked_xent(h, p["head"], batch["labels"],
                        softcap=cfg.logit_softcap)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    self_k: jax.Array    # (L, B, T, KV, hd)
    self_v: jax.Array
    cross_k: jax.Array   # (L, B, S_enc, KV, hd) — precomputed, static
    cross_v: jax.Array
    step: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: int | None = None) -> EncDecCache:
    L = cfg.num_layers
    enc_len = enc_len or max_len
    z = jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    zx = jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    return EncDecCache(z, z, zx, zx, jnp.zeros((), jnp.int32))


def prime_cross_cache(p: Params, cfg: ModelConfig, cache: EncDecCache,
                      enc_out: jax.Array) -> EncDecCache:
    def one(lp):
        return attn_mod.precompute_cross_kv(
            lp["cross"], enc_out, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim)

    ck, cv = jax.vmap(one)(p["dec"])
    return cache._replace(cross_k=ck, cross_v=cv)


def decode_step(p: Params, cfg: ModelConfig, cache: EncDecCache,
                tokens: jax.Array):
    h = embed(p["embed"], tokens)

    def body(h, inp):
        lp, (sk, sv, ck, cv) = inp
        lc = attn_mod.KVCache(sk, sv, cache.step)
        a, nc = attn_mod.decode_attention(
            lp["self"], rmsnorm(lp["ln1"], h, cfg.norm_eps), lc,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)
        h = h + a
        c = attn_mod.cross_attention_decode(
            lp["cross"], rmsnorm(lp["ln_x"], h, cfg.norm_eps), (ck, cv),
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim)
        h = h + c
        h = h + gelu_mlp(lp["mlp"], rmsnorm(lp["ln2"], h, cfg.norm_eps))
        return h, (nc.k, nc.v)

    h, (nk, nv) = jax.lax.scan(
        body, h, (p["dec"],
                  (cache.self_k, cache.self_v, cache.cross_k, cache.cross_v)))
    h = rmsnorm(p["final_ln"], h, cfg.norm_eps)
    logits = h.astype(jnp.float32) @ p["head"].astype(jnp.float32)
    return logits, cache._replace(self_k=nk, self_v=nv,
                                  step=cache.step + 1)
