"""Zamba2 hybrid: Mamba2 backbone + one *shared* attention block.

Zamba2's signature design (arXiv:2411.15242): the backbone is a stack of
Mamba2 blocks; every ``attn_every`` blocks, a single shared transformer
block (attention + SwiGLU, one set of weights reused at every application)
is applied to ``concat(h, h_embed)`` (current hidden + the original
embedding) projected back to d_model.

Layout: the 38 Mamba2 layers are grouped into ``ceil(L/attn_every)``
groups; each group is a stacked `lax.scan`, followed by one application of
the shared block.  Decode carries one Mamba2 cache per layer plus one KV
cache per shared-block *application* (activations differ per application
even though weights are shared).
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import hints
from . import attention as attn_mod
from . import mamba2 as m2
from .layers import (chunked_xent, embed, embedding_init, normal_init,
                     rmsnorm, rmsnorm_init, split_keys, swiglu, swiglu_init)

Params = Dict[str, Any]


def _mamba_dims(cfg: ModelConfig) -> m2.Mamba2Dims:
    return m2.dims(cfg.d_model, state=cfg.ssm_state,
                   head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                   d_conv=cfg.conv_kernel)


def _groups(cfg: ModelConfig) -> List[int]:
    k = cfg.attn_every
    full, rem = divmod(cfg.num_layers, k)
    return [k] * full + ([rem] if rem else [])


def shared_block_init(key, cfg: ModelConfig) -> Params:
    kp, ka, km = split_keys(key, 3)
    d = cfg.d_model
    return {
        "pre_proj": normal_init(kp, (2 * d, d), (2 * d) ** -0.5, cfg.dtype),
        "ln1": rmsnorm_init(d, cfg.dtype),
        "attn": attn_mod.attn_init(ka, d, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.head_dim, bias=cfg.qkv_bias,
                                   dtype=cfg.dtype),
        "ln2": rmsnorm_init(d, cfg.dtype),
        "mlp": swiglu_init(km, d, cfg.d_ff, cfg.dtype),
    }


def model_init(key, cfg: ModelConfig) -> Params:
    ke, km, ks, kh = split_keys(key, 4)
    dm = _mamba_dims(cfg)
    layer_keys = jnp.stack(split_keys(km, cfg.num_layers))

    def one_mamba(k):
        k1, = split_keys(k, 1)
        return {"ln": rmsnorm_init(cfg.d_model, cfg.dtype),
                "mixer": m2.mamba2_init(k1, dm, cfg.dtype)}

    return {
        "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "mamba": jax.vmap(one_mamba)(layer_keys),
        "shared": shared_block_init(ks, cfg),
        "final_ln": rmsnorm_init(cfg.d_model, cfg.dtype),
        "head": normal_init(kh, (cfg.d_model, cfg.vocab_size),
                            cfg.d_model ** -0.5, cfg.dtype),
    }


def _shared_fwd(p: Params, h, h0, cfg: ModelConfig, *, positions):
    x = jnp.concatenate([h, h0], axis=-1) @ p["pre_proj"]
    a = attn_mod.attention_fwd(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
        n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
        positions=positions, rope_theta=cfg.rope_theta, causal=True,
        window=cfg.sliding_window)
    x = x + a
    x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return h + x


def forward_hidden(p: Params, cfg: ModelConfig, batch):
    dm = _mamba_dims(cfg)
    h = embed(p["embed"], batch["tokens"])
    h0 = h
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def mamba_body(h, lp):
        h = hints.hint_spec(h, {0: "batch", 2: "model"})
        x = rmsnorm(lp["ln"], h, cfg.norm_eps)
        return h + m2.mamba2_fwd(lp["mixer"], x, dm, cfg.norm_eps), None

    off = 0
    for g in _groups(cfg):
        sub = jax.tree_util.tree_map(lambda x: x[off: off + g], p["mamba"])
        h, _ = jax.lax.scan(
            jax.checkpoint(mamba_body,
                           policy=jax.checkpoint_policies.nothing_saveable),
            h, sub)
        h = _shared_fwd(p["shared"], h, h0, cfg, positions=positions)
        off += g
    return rmsnorm(p["final_ln"], h, cfg.norm_eps), jnp.float32(0)


def loss_fn(p: Params, cfg: ModelConfig, batch) -> jax.Array:
    h, _ = forward_hidden(p, cfg, batch)
    return chunked_xent(h, p["head"], batch["labels"],
                        softcap=cfg.logit_softcap)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class Zamba2Cache(NamedTuple):
    mamba: Any          # stacked (L, ...) Mamba2Cache
    attn_k: jax.Array   # (n_apps, B, T, KV, hd)
    attn_v: jax.Array
    h0: jax.Array       # (B, 1, d) embedding of the current token
    step: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Zamba2Cache:
    dm = _mamba_dims(cfg)
    one = m2.init_mamba2_cache(batch, dm, dtype)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one)
    n_apps = len(_groups(cfg))
    kv = jnp.zeros((n_apps, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                   dtype)
    return Zamba2Cache(stacked, kv, kv,
                       jnp.zeros((batch, 1, cfg.d_model), dtype),
                       jnp.zeros((), jnp.int32))


def decode_step(p: Params, cfg: ModelConfig, cache: Zamba2Cache,
                tokens: jax.Array):
    dm = _mamba_dims(cfg)
    h = embed(p["embed"], tokens)
    h0 = h

    def mamba_body(h, inp):
        lp, lc = inp
        x = rmsnorm(lp["ln"], h, cfg.norm_eps)
        mix, nc = m2.mamba2_decode(lp["mixer"], x, lc, dm, cfg.norm_eps)
        return h + mix, nc

    new_mamba = []
    ak, av = cache.attn_k, cache.attn_v
    off = 0
    for gi, g in enumerate(_groups(cfg)):
        sub_p = jax.tree_util.tree_map(lambda x: x[off: off + g], p["mamba"])
        sub_c = jax.tree_util.tree_map(lambda x: x[off: off + g],
                                       cache.mamba)
        h, nm = jax.lax.scan(mamba_body, h, (sub_p, sub_c))
        new_mamba.append(nm)
        # shared attention application gi with its own KV cache
        x = jnp.concatenate([h, h0], axis=-1) @ p["shared"]["pre_proj"]
        lc = attn_mod.KVCache(ak[gi], av[gi], cache.step)
        a, nc = attn_mod.decode_attention(
            p["shared"]["attn"],
            rmsnorm(p["shared"]["ln1"], x, cfg.norm_eps), lc,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=cfg.sliding_window)
        ak = ak.at[gi].set(nc.k)
        av = av.at[gi].set(nc.v)
        x = x + a
        x = x + swiglu(p["shared"]["mlp"],
                       rmsnorm(p["shared"]["ln2"], x, cfg.norm_eps))
        h = h + x
        off += g

    new_mamba = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba)
    h = rmsnorm(p["final_ln"], h, cfg.norm_eps)
    logits = h.astype(jnp.float32) @ p["head"].astype(jnp.float32)
    return logits, Zamba2Cache(new_mamba, ak, av, h0, cache.step + 1)
