"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim rotary channels into three sections
(temporal, height, width) rotated by three separate position streams; for
text tokens the three streams coincide (t=h=w=index), recovering vanilla
RoPE — exactly Qwen2-VL's scheme.  The vision frontend being a stub, the
position streams arrive precomputed from ``input_specs`` as (3, B, S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MROPE_SECTIONS = (2, 1, 1)  # fractions of head_dim/2 given to (t, h, w) *4ths


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def _apply_rot(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, *, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions3: (3, B, S) — (t, h, w) streams."""
    hd = x.shape[-1]
    half = hd // 2
    tot = sum(MROPE_SECTIONS)
    splits = [half * s // tot for s in MROPE_SECTIONS]
    splits[-1] = half - sum(splits[:-1])
    freqs = rope_freqs(hd, theta)                       # (half,)
    # build a (B, S, half) angle tensor section-by-section
    parts, off = [], 0
    for i, w in enumerate(splits):
        f = freqs[off: off + w]
        ang = positions3[i][..., None].astype(jnp.float32) * f
        parts.append(ang)
        off += w
    ang = jnp.concatenate(parts, axis=-1)               # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)
