"""Model registry: one (init, loss, decode) bundle per architecture family,
plus ``input_specs`` — ShapeDtypeStruct stand-ins for every input of every
(arch × input-shape) combination (dry-run safe: no allocation).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from . import encdec, transformer, zamba2

Params = Any


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss_fn: Callable[[Params, Dict[str, jax.Array]], jax.Array]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.arch_type == "hybrid":
        mod = zamba2
    elif cfg.arch_type == "audio":
        mod = encdec
    else:
        mod = transformer
    return Model(
        cfg=cfg,
        init=lambda key: mod.model_init(key, cfg),
        loss_fn=lambda p, b: mod.loss_fn(p, cfg, b),
        decode_step=lambda p, c, t: mod.decode_step(p, cfg, c, t),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
            mod.init_cache(cfg, batch, max_len, dtype),
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs) per (arch, shape)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    tok = jnp.int32
    if cfg.arch_type == "vlm":
        P = cfg.frontend_tokens
        return {
            "frontend_embeds": _sds((B, P, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S - P), tok),
            "labels": _sds((B, S - P), tok),
            "positions3": _sds((3, B, S), tok),
        }
    if cfg.arch_type == "audio":
        return {
            "frontend_embeds": _sds((B, S, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S), tok),
            "labels": _sds((B, S), tok),
        }
    return {"tokens": _sds((B, S), tok), "labels": _sds((B, S), tok)}


def cache_specs(cfg: ModelConfig, B: int, max_len: int,
                dtype=jnp.bfloat16) -> Any:
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(B, max_len, dtype))


def decode_batch_specs(cfg: ModelConfig, B: int) -> Dict[str, Any]:
    return {"tokens": _sds((B, 1), jnp.int32)}


def param_specs(cfg: ModelConfig) -> Params:
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """All non-param inputs for the step the shape exercises."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape.global_batch,
                                           shape.seq_len)}
    if shape.kind == "prefill":
        specs = train_batch_specs(cfg, shape.global_batch, shape.seq_len)
        specs.pop("labels", None)
        return {"batch": specs}
    # decode: one token + a seq_len-deep cache
    return {
        "batch": decode_batch_specs(cfg, shape.global_batch),
        "cache": cache_specs(cfg, shape.global_batch, shape.seq_len),
    }


def count_params(cfg: ModelConfig) -> int:
    import math
    specs = param_specs(cfg)
    return sum(math.prod(s.shape) for s in jax.tree_util.tree_leaves(specs))
