"""Decoder-only transformer LM assembly (dense / MoE / VLM / SSM / RWKV).

Layers are *stacked* (every leaf has a leading L axis) and iterated with
``lax.scan`` + ``jax.checkpoint`` — the HLO contains each block body once,
which keeps 94-layer × 512-device compiles tractable and matches the
production remat policy.

The same assembly serves four arch types:
  dense   — GQA attention + SwiGLU
  moe     — GQA attention + top-k expert layer
  ssm     — Mamba2 or RWKV6 mixer (attention-free)
  vlm     — dense + M-RoPE positions + stub patch-embedding prefix
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..compat import optimization_barrier
from ..configs.base import ModelConfig
from ..sharding import hints
from . import attention as attn_mod
from . import mamba2 as m2
from . import rwkv6 as rk
from .layers import (chunked_xent, embed, embedding_init, normal_init,
                     rmsnorm, rmsnorm_init, split_keys, swiglu, swiglu_init)
from .moe import moe_fwd, moe_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer init / forward
# ---------------------------------------------------------------------------

def _mixer_kind(cfg: ModelConfig) -> str:
    if cfg.arch_type == "ssm":
        return "mamba2" if cfg.ssm_state else "rwkv6"
    return "attn"


def layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = split_keys(key, 2)
    kind = _mixer_kind(cfg)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
                 "ln2": rmsnorm_init(cfg.d_model, cfg.dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.attn_init(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            bias=cfg.qkv_bias, dtype=cfg.dtype)
    elif kind == "mamba2":
        dm = m2.dims(cfg.d_model, state=cfg.ssm_state,
                     head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                     d_conv=cfg.conv_kernel)
        p["mixer"] = m2.mamba2_init(k1, dm, cfg.dtype)
    else:  # rwkv6
        p["mixer"] = rk.time_mix_init(k1, cfg.d_model, cfg.dtype)
    if cfg.num_experts:
        p["moe"] = moe_init(k2, cfg.d_model, cfg.d_ff, cfg.num_experts,
                            cfg.dtype)
    elif kind == "rwkv6":
        p["mlp"] = rk.channel_mix_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    elif kind == "attn":
        p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    # mamba2 blocks are mixer-only (norm + mixer), matching Mamba2 LMs —
    # unless the config gives d_ff, in which case add a SwiGLU (zamba2 style)
    if kind == "mamba2" and cfg.d_ff:
        p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def layer_fwd(p: Params, h: jax.Array, cfg: ModelConfig, *,
              positions=None, inference: bool = False
              ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence layer forward → (h, aux_loss)."""
    kind = _mixer_kind(cfg)
    aux = jnp.float32(0)
    x = rmsnorm(p["ln1"], h, cfg.norm_eps)
    if kind == "attn":
        mix = attn_mod.attention_fwd(
            p["attn"], x, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions,
            rope_theta=cfg.rope_theta, use_mrope=cfg.mrope,
            causal=True, window=cfg.sliding_window)
    elif kind == "mamba2":
        dm = m2.dims(cfg.d_model, state=cfg.ssm_state,
                     head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                     d_conv=cfg.conv_kernel)
        mix = m2.mamba2_fwd(p["mixer"], x, dm, cfg.norm_eps)
    else:
        mix, _, _ = rk.time_mix_fwd(p["mixer"], x, eps=cfg.norm_eps)
    h = h + mix
    x2 = rmsnorm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        out, aux = moe_fwd(p["moe"], x2, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           aux_weight=cfg.router_aux_weight,
                           inference=inference)
    elif "mlp" in p and kind == "rwkv6":
        out, _ = rk.channel_mix_fwd(p["mlp"], x2)
    elif "mlp" in p:
        out = swiglu(p["mlp"], x2)
    else:
        out = jnp.zeros_like(h)
    return h + out, aux


# ---------------------------------------------------------------------------
# model init / forward / loss
# ---------------------------------------------------------------------------

def model_init(key, cfg: ModelConfig) -> Params:
    ke, kl, kh = split_keys(key, 3)
    layer_keys = jnp.stack(split_keys(kl, cfg.num_layers))
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    p = {"embed": embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
         "final_ln": rmsnorm_init(cfg.d_model, cfg.dtype),
         "layers": layers}
    if not cfg.tie_embeddings:
        p["head"] = normal_init(kh, (cfg.d_model, cfg.vocab_size),
                                cfg.d_model ** -0.5, cfg.dtype)
    return p


def _head_matrix(p: Params, cfg: ModelConfig) -> jax.Array:
    return (p["embed"]["tok"].T if cfg.tie_embeddings else p["head"])


def _embed_inputs(p, cfg: ModelConfig, batch) -> Tuple[jax.Array, Any]:
    """Token (+ stub modality prefix) embedding → (h, positions)."""
    h = embed(p["embed"], batch["tokens"])
    if cfg.modality in ("vision", "audio") and "frontend_embeds" in batch:
        # STUB frontends (per spec): precomputed patch/frame embeddings are
        # prepended to the token sequence.
        h = jnp.concatenate(
            [batch["frontend_embeds"].astype(h.dtype), h], axis=1)
    B, S = h.shape[:2]
    if cfg.mrope:
        positions = batch.get("positions3")
        if positions is None:
            base = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            positions = jnp.broadcast_to(base[None], (3, B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return h, positions


def forward_hidden(p: Params, cfg: ModelConfig, batch, *,
                   inference: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """(B,S,d) final hidden states + accumulated aux loss."""
    h, positions = _embed_inputs(p, cfg, batch)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body_fn(h, layer_p):
        layer_p = optimization_barrier(layer_p)  # see decode_step
        h2, aux = layer_fwd(layer_p, h, cfg, positions=positions,
                            inference=inference)
        return h2, aux

    def scan_body(carry, layer_p):
        h, aux_sum = carry
        # checkpoint saves one h per layer — shard them over batch AND
        # d_model ('model' axis), else 94-layer stacks are O(100GB)/device.
        # The optimization_barrier pins the save to bf16: without it XLA
        # hoists the rmsnorm f32 upcast out of the loop and keeps a 2×-size
        # f32 copy of the whole stack.
        h = optimization_barrier(
            hints.hint_spec(h, {0: "batch", 2: "model"}))
        h2, aux = body_fn(h, layer_p)
        return (h2, aux_sum + aux), None

    (h, aux), _ = jax.lax.scan(scan_body, (h, jnp.float32(0)), p["layers"])
    return rmsnorm(p["final_ln"], h, cfg.norm_eps), aux


def loss_fn(p: Params, cfg: ModelConfig, batch) -> jax.Array:
    """Mean next-token cross-entropy (+ MoE aux)."""
    h, aux = forward_hidden(p, cfg, batch)
    labels = batch["labels"]
    if h.shape[1] != labels.shape[1]:
        # modality prefix (stub frontend) carries no labels
        h = h[:, h.shape[1] - labels.shape[1]:]
    return chunked_xent(h, _head_matrix(p, cfg), labels,
                        softcap=cfg.logit_softcap) + aux


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    layers: Any          # stacked per-layer cache pytree (leading L axis)
    step: jax.Array      # scalar int32


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> DecodeCache:
    kind = _mixer_kind(cfg)
    L = cfg.num_layers

    def one():
        if kind == "attn":
            return attn_mod.init_kv_cache(
                batch, max_len, cfg.num_kv_heads, cfg.head_dim,
                window=cfg.sliding_window, dtype=dtype)
        if kind == "mamba2":
            dm = m2.dims(cfg.d_model, state=cfg.ssm_state,
                         head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                         d_conv=cfg.conv_kernel)
            return m2.init_mamba2_cache(batch, dm, dtype)
        return rk.init_rwkv_cache(batch, cfg.d_model, dtype)

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (L,) + x.shape), one())
    return DecodeCache(stacked, jnp.zeros((), jnp.int32))


def decode_step(p: Params, cfg: ModelConfig, cache: DecodeCache,
                tokens: jax.Array) -> Tuple[jax.Array, DecodeCache]:
    """One-token step. tokens: (B, 1) → logits (B, 1, V)."""
    kind = _mixer_kind(cfg)
    h = embed(p["embed"], tokens)

    def body(h, inp):
        layer_p, layer_c = inp
        # barrier: XLA-CPU promotes bf16 dots to f32 and would otherwise
        # hoist the convert of the WHOLE stacked weight tensor out of the
        # layer loop (an f32 copy of all params — ~19 GB at 235b)
        layer_p, layer_c = optimization_barrier((layer_p, layer_c))
        x = rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        if kind == "attn":
            lc = attn_mod.KVCache(layer_c.k, layer_c.v, cache.step)
            mix, nc = attn_mod.decode_attention(
                layer_p["attn"], x, lc, n_heads=cfg.num_heads,
                n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, use_mrope=cfg.mrope,
                window=cfg.sliding_window)
        elif kind == "mamba2":
            dm = m2.dims(cfg.d_model, state=cfg.ssm_state,
                         head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                         d_conv=cfg.conv_kernel)
            mix, nc = m2.mamba2_decode(layer_p["mixer"], x, layer_c, dm,
                                       cfg.norm_eps)
        else:
            mix, new_state, tm_x = rk.time_mix_fwd(
                layer_p["mixer"], x, state=layer_c.state,
                last_x=layer_c.tm_x, eps=cfg.norm_eps)
        if kind == "rwkv6":
            h = h + mix
            x2 = rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            out, cm_x = rk.channel_mix_fwd(layer_p["mlp"], x2,
                                           last_x=layer_c.cm_x)
            h = h + out
            return h, rk.RWKVLayerCache(new_state, tm_x, cm_x)
        h = h + mix
        x2 = rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
        if "moe" in layer_p:
            out, _ = moe_fwd(layer_p["moe"], x2, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             aux_weight=cfg.router_aux_weight,
                             inference=True)
        elif "mlp" in layer_p:
            out = swiglu(layer_p["mlp"], x2)
        else:
            out = jnp.zeros_like(h)
        return h + out, nc

    h, new_layers = jax.lax.scan(body, h, (p["layers"], cache.layers))
    h = rmsnorm(p["final_ln"], h, cfg.norm_eps)
    logits = h.astype(jnp.float32) @ _head_matrix(p, cfg).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, DecodeCache(new_layers, cache.step + 1)
