"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

TPU adaptation (DESIGN.md §3): GShard/Switch fixed-capacity formulation —
tokens are scatter-added into (E, C, d) buffers, experts run batched
einsums (dense, MXU-aligned), outputs gather back with router weights.

Sharding (§Perf iterations 1-3, EXPERIMENTS.md): the production path is a
FULLY-MANUAL shard_map over (data [+pod], model):
  - tokens are manual over the data axes (each shard routes/dispatches its
    own tokens — zero dispatch communication);
  - experts are manual over 'model' (each shard owns E/16 experts and
    dispatches only tokens routed to THEM);
  - ZeRO-sharded expert weights are all-gathered over 'data' explicitly
    (the unavoidable ZeRO gather);
  - the combine is ONE explicit psum over 'model' per layer.
Earlier auto-'model' versions let XLA partition the combine gather and it
emitted a full (Tb, d) all-reduce PER ASSIGNMENT k (8×/layer, 4.5 TB/step
at qwen3-235b scale); moving the combine outside the shard_map was worse
(boundary materialisation, 14.6 TB).  The manual psum-once design is the
standard expert-parallel schedule.

FLOP-faithful: compute is E·C·d·f with C ≈ tokens·top_k/E·capacity_factor,
proportional to *active* experts only.  Overflow drops (standard).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding import hints
from .layers import normal_init, split_keys

Params = Dict[str, Any]


def moe_init(key, d: int, f: int, n_experts: int, dtype) -> Params:
    kr, kg, ku, kd = split_keys(key, 4)
    s = d ** -0.5
    return {
        "router": normal_init(kr, (d, n_experts), s, jnp.float32),
        "gate": normal_init(kg, (n_experts, d, f), s, dtype),
        "up": normal_init(ku, (n_experts, d, f), s, dtype),
        "down": normal_init(kd, (n_experts, f, d), f ** -0.5, dtype),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int,
             factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k / n_experts * factor))
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU lane alignment


def _route_block(xb, router, top_k):
    """xb: (Tb, d) → (gate_vals, expert_ids, probs)."""
    logits = xb.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    return gate_vals, expert_ids, probs


def _dispatch_top1(xb, ids, E, C, dtype, id_offset=0):
    """Scatter the k-th assignment into a local (E, C, d) buffer.

    ``id_offset``/E: in expert-parallel manual mode, only experts
    [id_offset, id_offset+E) are local; other tokens are masked out.
    """
    Tb, d = xb.shape
    local = ids - id_offset
    owned = (local >= 0) & (local < E)
    safe = jnp.where(owned, local, 0)
    onehot = jax.nn.one_hot(safe, E, dtype=jnp.int32) * owned[:, None]
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_expert, safe[:, None], 1)[:, 0]
    keep = owned & (pos < C)
    dest = safe * C + jnp.minimum(pos, C - 1)
    contrib = jnp.where(keep, 1.0, 0.0).astype(dtype)
    buf = jnp.zeros((E * C, d), dtype)
    buf = buf.at[dest].add(xb * contrib[:, None])
    return buf.reshape(E, C, d), dest, contrib


def _moe_manual(xb, router, gate, up, down, *, top_k, C1, aux_weight,
                batch_axes, wdtype, E, zero_axes):
    """Fully-manual expert-parallel MoE (inside shard_map over data+model).

    xb: (T_local, d) — this data shard's tokens, replicated over 'model'.
    gate/up/down: local (E/16, d, f[/zero]) slices; router: local slice
    over its zero axis (re-gathered below).
    """
    gate = gate.astype(wdtype)
    up = up.astype(wdtype)
    down = down.astype(wdtype)
    # ---- ZeRO re-gather of weights over the data axes (explicit) ----------
    if zero_axes:
        ax = zero_axes if len(zero_axes) > 1 else zero_axes[0]
        router = jax.lax.all_gather(router, ax, axis=1, tiled=True)
        gate = jax.lax.all_gather(gate, ax, axis=2, tiled=True)
        up = jax.lax.all_gather(up, ax, axis=2, tiled=True)
        down = jax.lax.all_gather(down, ax, axis=1, tiled=True)
    router = router.astype(jnp.float32)
    E_loc = gate.shape[0]
    eo = jax.lax.axis_index("model") * E_loc
    Tb, d = xb.shape
    dtype = xb.dtype

    gate_vals, expert_ids, probs = _route_block(xb, router, top_k)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (Tb * top_k))
    aux = (aux_weight * E * jnp.sum(me * ce))[None]

    def k_body(acc, inp):
        ids, gv = inp
        buf, dest, contrib = _dispatch_top1(xb, ids, E_loc, C1, dtype,
                                            id_offset=eo)
        g = jnp.einsum("ecd,edf->ecf", buf, gate)
        u = jnp.einsum("ecd,edf->ecf", buf, up)
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, down)
        gathered = out_buf.reshape(E_loc * C1, d)[dest] * contrib[:, None]
        return acc + gathered * gv[:, None].astype(dtype), None

    acc0 = jnp.zeros((Tb, d), dtype)
    out_partial, _ = jax.lax.scan(k_body, acc0,
                                  (expert_ids.T, gate_vals.T))
    # ---- ONE combine reduction per layer: reduce-scatter over d (the
    # residual consumer is d-sharded over 'model', so scattering matches
    # the consumer layout AND halves the bytes vs a full psum) -------------
    out = jax.lax.psum_scatter(out_partial.astype(jnp.float32), "model",
                               scatter_dimension=1, tiled=True)
    return out.astype(dtype), aux


def moe_fwd(p: Params, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25,
            aux_weight: float = 0.01,
            inference: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss).

    ``inference=True`` skips the f32 shard_map boundary (only needed to
    dodge an XLA-CPU crash in the *backward* replicated-input all-reduce).
    """
    B, S, d = x.shape
    E = p["router"].shape[1]
    T = B * S

    # ---- manual expert-parallel path (production mesh) ---------------------
    mesh, baxes = hints.current_mesh()
    if (mesh is not None and baxes and "model" in mesh.shape
            and E % mesh.shape["model"] == 0):
        dp = hints.batch_axes_size()
        if T % dp == 0 and (T // dp) >= 8:
            from jax.sharding import PartitionSpec as P
            Tl = T // dp
            msize = mesh.shape["model"]
            C1 = capacity(Tl, E, 1, capacity_factor)
            ba = baxes if len(baxes) > 1 else baxes[0]
            # physical weight shardings (rules.py): gate/up (E→model,
            # f→data when divisible); router (d, E) replicated-ish
            f = p["gate"].shape[2]
            zero_axes = baxes if (f % dp == 0 and
                                  p["router"].shape[0] % dp == 0) else ()
            za = (zero_axes if len(zero_axes) != 1 else zero_axes[0])
            w_in = (P(None, za) if zero_axes else P(),
                    P("model", None, za) if zero_axes else P("model"),
                    P("model", None, za) if zero_axes else P("model"),
                    P("model", za) if zero_axes else P("model"))
            fn = partial(_moe_manual, top_k=top_k, C1=C1,
                         aux_weight=aux_weight, batch_axes=baxes,
                         wdtype=p["gate"].dtype, E=E, zero_axes=zero_axes)
            sm = jax.shard_map(
                fn, mesh=mesh,
                in_specs=(P(ba, None),) + w_in,
                out_specs=(P(ba, "model"), P(ba)),   # out d-sharded (RS)
                axis_names=set(baxes) | {"model"}, check_vma=False)
            if inference:
                w_args = (p["router"], p["gate"], p["up"], p["down"])
            else:
                w_args = (p["router"].astype(jnp.float32),
                          p["gate"].astype(jnp.float32),
                          p["up"].astype(jnp.float32),
                          p["down"].astype(jnp.float32))
            out, aux = sm(x.reshape(T, d), *w_args)
            return out.reshape(B, S, d), jnp.mean(aux)

    # ---- local fallback (CPU tests / tiny meshes) ---------------------------
    dp = hints.batch_axes_size()
    if T % dp or (T // dp) < 8:
        dp = 1
    Tb = T // dp
    C1 = capacity(Tb, E, 1, capacity_factor)

    xt = hints.hint_spec(x.reshape(dp, Tb, d), {0: "batch"})
    gate_vals, expert_ids, probs = jax.vmap(
        lambda xb: _route_block(xb, p["router"], top_k))(xt)

    me = jnp.mean(probs.reshape(T, E), axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (T * top_k))
    aux = aux_weight * E * jnp.sum(me * ce)

    dtype = x.dtype
    ids_k = expert_ids.transpose(2, 0, 1)        # (K, dp, Tb)
    gv_k = gate_vals.transpose(2, 0, 1)          # (K, dp, Tb)

    def k_body(acc, inp):
        ids, gv = inp
        buf, dest, contrib = jax.vmap(
            lambda xb, i: _dispatch_top1(xb, i, E, C1, dtype))(xt, ids)
        buf = hints.hint_spec(buf, {0: "batch", 1: "model"})
        g = jnp.einsum("becd,edf->becf", buf, p["gate"])
        u = jnp.einsum("becd,edf->becf", buf, p["up"])
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("becf,efd->becd", h, p["down"])
        out_buf = hints.hint_spec(out_buf, {0: "batch", 1: "model"})

        def _combine(out_b, dest_b, contrib_b, gv_b):
            gathered = out_b.reshape(E * C1, d)[dest_b] * contrib_b[:, None]
            return gathered * gv_b[:, None].astype(dtype)

        out_k = jax.vmap(_combine)(out_buf, dest, contrib, gv)
        return acc + hints.hint_spec(out_k, {0: "batch"}), None

    acc0 = jnp.zeros((dp, Tb, d), dtype)
    out, _ = jax.lax.scan(k_body, acc0, (ids_k, gv_k))
    return out.reshape(B, S, d), aux
