"""The paper's own workload family: small CNNs (+ MLP) for image
classification (paper §5.1.1 uses 4-conv and 8-conv CNNs with BN+ReLU).

Pure-functional conv nets via lax.conv_general_dilated; group-norm replaces
batch-norm (BN's cross-device batch statistics are hostile to both FL
simulation determinism and pjit sharding; GN is the standard substitution
and keeps the "normalisation between convs" property the paper relies on).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from .layers import normal_init, split_keys

Params = Dict[str, Any]


def _conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _group_norm(x, gamma, beta, groups=4, eps=1e-5):
    B, H, W, C = x.shape
    g = x.reshape(B, H, W, groups, C // groups)
    mu = g.mean(axis=(1, 2, 4), keepdims=True)
    var = g.var(axis=(1, 2, 4), keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    return g.reshape(B, H, W, C) * gamma + beta


def cnn_init(key, *, n_classes: int, channels: Sequence[int] = (16, 32),
             in_ch: int = 1, hw: int = 16, dtype=jnp.float32) -> Params:
    """`len(channels)` conv blocks (conv-GN-ReLU-pool) + linear head.

    channels=(16,32) ≈ paper's 4-conv net scaled to CPU; pass 4 entries for
    the 8-conv CIFAR variant.
    """
    ks = split_keys(key, len(channels) + 1)
    p: Params = {"convs": []}
    c_in = in_ch
    for i, c_out in enumerate(channels):
        p["convs"].append({
            "w": normal_init(ks[i], (3, 3, c_in, c_out),
                             (9 * c_in) ** -0.5, dtype),
            "b": jnp.zeros((c_out,), dtype),
            "gamma": jnp.ones((c_out,), dtype),
            "beta": jnp.zeros((c_out,), dtype),
        })
        c_in = c_out
    feat = (hw // (2 ** len(channels))) ** 2 * c_in
    p["fc_w"] = normal_init(ks[-1], (feat, n_classes), feat ** -0.5, dtype)
    p["fc_b"] = jnp.zeros((n_classes,), dtype)
    return p


def cnn_apply(p: Params, x: jax.Array) -> jax.Array:
    for blk in p["convs"]:
        x = _conv(x, blk["w"], blk["b"])
        x = _group_norm(x, blk["gamma"], blk["beta"])
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    return x @ p["fc_w"] + p["fc_b"]


def cnn_loss(p: Params, batch: Tuple[jax.Array, jax.Array]) -> jax.Array:
    x, y = batch
    logits = cnn_apply(p, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


def cnn_accuracy(p: Params, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(cnn_apply(p, x), -1) == y).astype(jnp.float32))


def cnn_eval_program(x: jax.Array, y: jax.Array, *, batch_size: int = 256):
    """Batched device-resident ``params -> accuracy`` (scan-engine eval)."""
    from ..core.evaluation import make_eval_program
    return make_eval_program(cnn_apply, x, y, batch_size=batch_size)


# --- tiny MLP for the fastest unit tests -----------------------------------

def mlp_init(key, *, d_in: int, d_hidden: int, n_classes: int,
             dtype=jnp.float32) -> Params:
    k1, k2 = split_keys(key, 2)
    return {"w1": normal_init(k1, (d_in, d_hidden), d_in ** -0.5, dtype),
            "b1": jnp.zeros((d_hidden,), dtype),
            "w2": normal_init(k2, (d_hidden, n_classes),
                              d_hidden ** -0.5, dtype),
            "b2": jnp.zeros((n_classes,), dtype)}


def mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    x = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def mlp_loss(p: Params, batch) -> jax.Array:
    x, y = batch
    logp = jax.nn.log_softmax(mlp_apply(p, x))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


def mlp_accuracy(p: Params, x, y) -> jax.Array:
    return jnp.mean((jnp.argmax(mlp_apply(p, x), -1) == y).astype(jnp.float32))


def mlp_eval_program(x: jax.Array, y: jax.Array, *, batch_size: int = 256):
    """Batched device-resident ``params -> accuracy`` (scan-engine eval)."""
    from ..core.evaluation import make_eval_program
    return make_eval_program(mlp_apply, x, y, batch_size=batch_size)
