"""Shared building blocks: norms, MLPs, embeddings, initialisers.

All modules are (init, apply) pairs of pure functions over dict pytrees.
Weights are stored in float32 or bf16 per ``cfg.dtype``; math runs in the
param dtype with float32 norm accumulation.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (llama-family) and GELU MLP (encoder stacks)
# ---------------------------------------------------------------------------

def swiglu_init(key, d, f, dtype):
    k1, k2, k3 = split_keys(key, 3)
    s = d ** -0.5
    return {
        "gate": normal_init(k1, (d, f), s, dtype),
        "up": normal_init(k2, (d, f), s, dtype),
        "down": normal_init(k3, (f, d), f ** -0.5, dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ p["gate"])
    return (g * (x @ p["up"])) @ p["down"]


def gelu_mlp_init(key, d, f, dtype):
    k1, k2 = split_keys(key, 2)
    return {
        "in": normal_init(k1, (d, f), d ** -0.5, dtype),
        "out": normal_init(k2, (f, d), f ** -0.5, dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["in"]) @ p["out"]


# ---------------------------------------------------------------------------
# Embedding / LM head with seq-chunked softmax cross-entropy.
#
# The chunked loss never materialises the full (B, S, V) logits tensor —
# essential for 150k-vocab archs at 32k seq (a single full logits tensor
# would be tens of GB per device).
# ---------------------------------------------------------------------------

def embedding_init(key, vocab, d, dtype):
    return {"tok": normal_init(key, (vocab, d), 0.02, dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(emb_or_head: jax.Array, h: jax.Array,
              softcap: float = 0.0) -> jax.Array:
    logits = h @ emb_or_head
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def chunked_xent(h: jax.Array, head: jax.Array, labels: jax.Array,
                 *, chunk: int = 128, softcap: float = 0.0) -> jax.Array:
    """Mean token cross-entropy, scanning over sequence chunks.

    h: (B, S, D); head: (D, V); labels: (B, S) int32. S % chunk == 0 is
    arranged by padding upstream.
    """
    B, S, D = h.shape
    if S % chunk:
        pad = chunk - S % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    n_chunks = S // chunk
    from ..sharding import hints
    hc = hints.hint_batch(
        h.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3), bdim=1)
    lc = hints.hint_batch(
        labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2), bdim=1)

    # checkpointed: the backward recomputes the (B, chunk, V) logits of
    # each chunk rather than saving them (fp32 logits at 150k vocab are
    # ~4 GB per chunk — saving all chunks would dominate device memory)
    @jax.checkpoint
    def body(acc, inp):
        hx, lx = inp
        logits = lm_logits(head, hx.astype(jnp.float32), softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1)[..., 0]
        valid = (lx >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (acc[0] + nll.sum(), acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
