"""Mamba2 (SSD) block — selective state-space with scalar per-head decay.

Faithful to the Mamba2 parameterisation (in_proj → [z | x | B | C | dt],
depthwise causal conv on [x|B|C], softplus dt, A = -exp(A_log) scalar per
head, SSM recurrence h ← exp(dt·A)·h + dt·(B ⊗ x), y = C·h + D·x, gated
RMSNorm, out_proj), with n_groups = 1.

Sequence processing is a `lax.scan` over time (the Pallas `mamba2_ssd`
kernel implements the chunked form for TPU; this pure-JAX path is the
oracle and the dry-run lowering).  Decode carries (conv_state, ssm_state) — O(1)
per token, which is what qualifies SSM archs for long_500k.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import normal_init, rmsnorm, rmsnorm_init, split_keys

Params = Dict[str, Any]


class Mamba2Dims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    d_conv: int

    @property
    def conv_channels(self):
        return self.d_inner + 2 * self.d_state


def dims(d_model: int, *, state: int, head_dim: int = 64,
         expand: int = 2, d_conv: int = 4) -> Mamba2Dims:
    d_inner = expand * d_model
    return Mamba2Dims(d_model, d_inner, d_inner // head_dim, head_dim,
                      state, d_conv)


def mamba2_init(key, dm: Mamba2Dims, dtype) -> Params:
    kin, kconv, kdt, kout, knorm = split_keys(key, 5)
    d, di, H = dm.d_model, dm.d_inner, dm.n_heads
    proj_out = 2 * di + 2 * dm.d_state + H
    return {
        "in_proj": normal_init(kin, (d, proj_out), d ** -0.5, dtype),
        "conv_w": normal_init(kconv, (dm.d_conv, dm.conv_channels),
                              dm.d_conv ** -0.5, dtype),
        "conv_b": jnp.zeros((dm.conv_channels,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": normal_init(kout, (di, d), di ** -0.5, dtype),
    }


def _split_proj(zxbcdt, dm: Mamba2Dims):
    di, ds, H = dm.d_inner, dm.d_state, dm.n_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    B = zxbcdt[..., 2 * di:2 * di + ds]
    C = zxbcdt[..., 2 * di + ds:2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds:]
    return z, x, B, C, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _ssm_step(h, inp, A, dm: Mamba2Dims):
    """h: (B, H, hd, N). One recurrence step."""
    x_t, B_t, C_t, dt_t = inp       # (B,di) (B,N) (B,N) (B,H)
    B_, H, hd, N = h.shape
    xh = x_t.reshape(B_, H, hd)
    decay = jnp.exp(dt_t * A)[:, :, None, None]           # (B,H,1,1)
    dBx = (dt_t[:, :, None, None] * xh[..., None] *
           B_t[:, None, None, :])                          # (B,H,hd,N)
    h = decay * h + dBx
    y = jnp.einsum("bhpn,bn->bhp", h, C_t)                 # (B,H,hd)
    return h, y


def mamba2_fwd(p: Params, x: jax.Array, dm: Mamba2Dims,
               eps: float = 1e-5) -> jax.Array:
    """x: (B, S, d) → (B, S, d). Full-sequence scan."""
    from ..sharding import hints
    Bb, S, d = x.shape
    zxbcdt = hints.hint_spec(x @ p["in_proj"], {0: "batch", 2: "model"})
    z, xs, Bs, Cs, dt_raw = _split_proj(zxbcdt, dm)
    xbc = jnp.concatenate([xs, Bs, Cs], axis=-1)
    xbc = hints.hint_spec(_causal_conv(xbc, p["conv_w"], p["conv_b"]),
                          {0: "batch", 2: "model"})
    xs = xbc[..., :dm.d_inner]
    Bs = xbc[..., dm.d_inner:dm.d_inner + dm.d_state]
    Cs = xbc[..., dm.d_inner + dm.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                # (H,)

    out_dtype = x.dtype

    def step(h, inp):
        x_t, B_t, C_t, dt_t = inp
        h, y = _ssm_step(
            h, (x_t.astype(jnp.float32), B_t.astype(jnp.float32),
                C_t.astype(jnp.float32), dt_t), A, dm)
        return h, y.astype(out_dtype)   # stream outputs at model precision

    h0 = jnp.zeros((Bb, dm.n_heads, dm.head_dim, dm.d_state), jnp.float32)
    # stream xs in bf16 (largest panel); upcast per step — halves the
    # sequence-resident buffers without touching state precision
    seq = (hints.hint_spec(xs.transpose(1, 0, 2), {1: "batch", 2: "model"}),
           Bs.transpose(1, 0, 2),
           Cs.transpose(1, 0, 2),
           dt.transpose(1, 0, 2))

    # two-level scan with chunk-checkpointing: a flat scan's backward saves
    # the (S, B, H, hd, N) state trajectory — ~68 GB/device at 4k seq.
    # Chunking saves only chunk-boundary states and recomputes inside.
    chunk = 64
    if S % chunk == 0 and S > chunk:
        nseq = jax.tree_util.tree_map(
            lambda t: t.reshape((S // chunk, chunk) + t.shape[1:]), seq)

        @jax.checkpoint
        def chunk_body(h, inp):
            return jax.lax.scan(step, h, inp)

        _, ys = jax.lax.scan(chunk_body, h0, nseq)      # (S/c, c, B, H, hd)
        ys = ys.reshape((S,) + ys.shape[2:])
    else:
        _, ys = jax.lax.scan(step, h0, seq)             # (S,B,H,hd)
    y = ys.transpose(1, 0, 2, 3).astype(jnp.float32)
    y = y + p["D"][None, None, :, None] * xs.reshape(
        Bb, S, dm.n_heads, dm.head_dim).astype(jnp.float32)
    y = y.reshape(Bb, S, dm.d_inner).astype(x.dtype)
    y = hints.hint_spec(y, {0: "batch", 2: "model"})
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), eps)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode: O(1) state per token
# ---------------------------------------------------------------------------

class Mamba2Cache(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_channels) last inputs
    ssm: jax.Array    # (B, H, hd, N) float32


def init_mamba2_cache(batch: int, dm: Mamba2Dims, dtype=jnp.bfloat16):
    return Mamba2Cache(
        jnp.zeros((batch, dm.d_conv - 1, dm.conv_channels), dtype),
        jnp.zeros((batch, dm.n_heads, dm.head_dim, dm.d_state), jnp.float32),
    )


def mamba2_decode(p: Params, x: jax.Array, cache: Mamba2Cache,
                  dm: Mamba2Dims, eps: float = 1e-5):
    """x: (B, 1, d) → (B, 1, d), updated cache."""
    Bb = x.shape[0]
    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xs, Bs, Cs, dt_raw = _split_proj(zxbcdt, dm)
    xbc_t = jnp.concatenate([xs, Bs, Cs], axis=-1)          # (B, C)
    window = jnp.concatenate([cache.conv, xbc_t[:, None]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :dm.d_inner]
    Bs = conv_out[..., dm.d_inner:dm.d_inner + dm.d_state]
    Cs = conv_out[..., dm.d_inner + dm.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h, y = _ssm_step(cache.ssm,
                     (xs.astype(jnp.float32), Bs.astype(jnp.float32),
                      Cs.astype(jnp.float32), dt), A, dm)
    y = y + p["D"][None, :, None] * xs.reshape(
        Bb, dm.n_heads, dm.head_dim).astype(jnp.float32)
    y = y.reshape(Bb, 1, dm.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z[:, None]), eps)
    out = y @ p["out_proj"]
    return out, Mamba2Cache(window[:, 1:], h)
