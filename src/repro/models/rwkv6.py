"""RWKV6 "Finch" block — linear attention with data-dependent decay.

Time-mix:   r,k,v,g projections of token-shift lerps; per-channel decay
            w_t = exp(-exp(w0 + lora(x_t))) (the data-dependent decay that
            distinguishes Finch from RWKV5); per-head state S ∈ R^{hd×hd}:
              out_t = r_t · (diag(u)·k_tᵀv_t + S_t)
              S_{t+1} = diag(w_t)·S_t + k_tᵀ v_t
Channel-mix: squared-ReLU MLP gated by a receptance sigmoid.

Decode state is O(heads·hd²) per layer regardless of context length —
the arch is attention-free and long_500k-eligible.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import normal_init, rmsnorm, rmsnorm_init, split_keys

Params = Dict[str, Any]
HEAD_DIM = 64
DECAY_LORA = 32


def rwkv6_dims(d_model: int):
    assert d_model % HEAD_DIM == 0
    return d_model // HEAD_DIM, HEAD_DIM


def time_mix_init(key, d: int, dtype) -> Params:
    H, hd = rwkv6_dims(d)
    ks = split_keys(key, 8)
    s = d ** -0.5
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),     # lerp weights r,k,v,g,w
        "wr": normal_init(ks[0], (d, d), s, dtype),
        "wk": normal_init(ks[1], (d, d), s, dtype),
        "wv": normal_init(ks[2], (d, d), s, dtype),
        "wg": normal_init(ks[3], (d, d), s, dtype),
        "wo": normal_init(ks[4], (d, d), s, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),  # decay bias (slow decay)
        "w_lora_a": normal_init(ks[5], (d, DECAY_LORA), s, dtype),
        "w_lora_b": normal_init(ks[6], (DECAY_LORA, d),
                                DECAY_LORA ** -0.5, dtype),
        "u": normal_init(ks[7], (H, hd), 0.5, jnp.float32),  # bonus
        "ln_x": rmsnorm_init(d, dtype),
    }


def channel_mix_init(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), dtype),      # lerp weights k, r
        "wk": normal_init(k1, (d, f), d ** -0.5, dtype),
        "wv": normal_init(k2, (f, d), f ** -0.5, dtype),
        "wr": normal_init(k3, (d, d), d ** -0.5, dtype),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros or `last` for the first position)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """Per-head linear-attention recurrence.

    r,k,v: (B, S, H, hd); w: (B, S, H, hd) decays in (0,1);
    u: (H, hd) bonus; s0: (B, H, hd, hd) initial state.
    Returns out (B, S, H, hd) and final state.
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    seq = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    T = r.shape[1]
    chunk = 64
    if T % chunk == 0 and T > chunk:
        # chunk-checkpointed scan: save only chunk-boundary states, not the
        # full (T, B, H, hd, hd) trajectory (tens of GB at 4k seq)
        nseq = jax.tree_util.tree_map(
            lambda t: t.reshape((T // chunk, chunk) + t.shape[1:]), seq)

        @jax.checkpoint
        def chunk_body(s, inp):
            return jax.lax.scan(step, s, inp)

        s, outs = jax.lax.scan(chunk_body, s0, nseq)
        outs = outs.reshape((T,) + outs.shape[2:])
    else:
        s, outs = jax.lax.scan(step, s0, seq)
    return outs.transpose(1, 0, 2, 3), s


def time_mix_fwd(p: Params, x: jax.Array, *, state=None, last_x=None,
                 eps: float = 1e-5):
    """x: (B,S,d). state: (B,H,hd,hd) carried across calls (decode)."""
    B, S, d = x.shape
    H, hd = rwkv6_dims(d)
    xx = _shift(x, last_x) - x
    lerp = lambda i: x + xx * p["mu"][i]
    r = (lerp(0) @ p["wr"]).reshape(B, S, H, hd)
    k = (lerp(1) @ p["wk"]).reshape(B, S, H, hd)
    v = (lerp(2) @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(lerp(3) @ p["wg"])
    w_raw = p["w0"] + (lerp(4) @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32)))    # (B,S,d) ∈ (0,1)
    w = w.reshape(B, S, H, hd)

    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    out, new_state = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w, p["u"], state)
    out = out.reshape(B, S, d).astype(x.dtype)
    out = rmsnorm(p["ln_x"], out, eps) * g
    return out @ p["wo"], new_state, x[:, -1:]


def channel_mix_fwd(p: Params, x: jax.Array, *, last_x=None):
    xx = _shift(x, last_x) - x
    k = jnp.square(jax.nn.relu((x + xx * p["mu"][0]) @ p["wk"]))
    r = jax.nn.sigmoid((x + xx * p["mu"][1]) @ p["wr"])
    return r * (k @ p["wv"]), x[:, -1:]


class RWKVLayerCache(NamedTuple):
    state: jax.Array   # (B, H, hd, hd)
    tm_x: jax.Array    # (B, 1, d) last input to time-mix
    cm_x: jax.Array    # (B, 1, d) last input to channel-mix


def init_rwkv_cache(batch: int, d: int, dtype=jnp.bfloat16):
    H, hd = rwkv6_dims(d)
    return RWKVLayerCache(
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, 1, d), dtype),
        jnp.zeros((batch, 1, d), dtype),
    )
