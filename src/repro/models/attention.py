"""GQA attention: chunked (flash-style) prefill/train, cached decode, SWA.

Memory discipline: the (S × T) score matrix is never materialised — we scan
over query chunks and, inside, over key/value chunks with an online softmax
(running max / normaliser).  For sliding-window attention the inner loop
reads only the static band of KV that the window can reach (so SWA costs
O(S·W), not O(S²)).

Decode uses a (B, T, KV, hd) cache with dynamic-slice writes; SWA decode
uses a ring buffer of length ``window`` so a 500k-token stream needs only
O(window) memory — this is what makes h2o-danube eligible for long_500k.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import rope as rope_mod
from .layers import normal_init, split_keys

Params = Dict[str, Any]
NEG_INF = -1e30


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int,
              *, bias: bool, dtype) -> Params:
    kq, kk, kv, ko = split_keys(key, 4)
    s = d ** -0.5
    p = {
        "wq": normal_init(kq, (d, n_heads * head_dim), s, dtype),
        "wk": normal_init(kk, (d, n_kv * head_dim), s, dtype),
        "wv": normal_init(kv, (d, n_kv * head_dim), s, dtype),
        "wo": normal_init(ko, (n_heads * head_dim, d),
                          (n_heads * head_dim) ** -0.5, dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _project_qkv(p, xq, xkv, n_heads, n_kv, head_dim):
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = xq.shape[:2]
    T = xkv.shape[1]
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, T, n_kv, head_dim),
            v.reshape(B, T, n_kv, head_dim))


def _chunk_attend(q, k, v, mask_bias):
    """One (q-chunk × kv-chunk) tile. q:(B,Cq,H,hd) k/v:(B,Ck,KV,hd).

    KV heads are expanded to the full H inside the tile (a local gather —
    Ck-sized, so the ×G memory cost is per-tile only).  This keeps every
    einsum partitionable on the H dim, which is how the tile compute
    shards over the 'model' axis (head-parallel attention).

    Returns unnormalised (acc, m, l) pieces for online softmax merge.
    mask_bias: (Cq, Ck) additive 0/-inf.
    """
    B, Cq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bchd->bhqc", q, k) / math.sqrt(hd)
    s = s.astype(jnp.float32) + mask_bias[None, None]
    m = jnp.max(s, axis=-1)                                   # (B,H,Cq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqc,bchd->bhqd", p.astype(v.dtype), v)
    return acc.astype(jnp.float32), m, l


def _merge(acc, m, l, acc2, m2, l2):
    m_new = jnp.maximum(m, m2)
    a1 = jnp.exp(m - m_new)
    a2 = jnp.exp(m2 - m_new)
    return (acc * a1[..., None] + acc2 * a2[..., None],
            m_new, l * a1 + l2 * a2)


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool, window: int = 0,
    q_chunk: int = 512, kv_chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Flash-style attention. q:(B,S,H,hd), k/v:(B,T,KV,hd) → (B,S,H,hd).

    ``q_offset`` is the absolute position of q[0] relative to k[0] (for
    cross-chunk causal masking during chunked prefill of a cache).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    # pad to multiples
    Sp = (S + q_chunk - 1) // q_chunk * q_chunk
    Tp = (T + kv_chunk - 1) // kv_chunk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    nq, nk = Sp // q_chunk, Tp // kv_chunk
    G = H // KV

    from ..sharding import hints
    msize = hints.model_axis_size()
    # head-parallel when H divides the model axis; otherwise shard the
    # q-chunk (sequence-parallel) — covers 20-head/12-head archs
    if H % max(msize, 1) == 0:
        q_dims = {1: "batch", 3: "model"}
        k_dims = {1: "batch", 3: "model"} if KV % max(msize, 1) == 0 \
            else {1: "batch"}
    else:
        q_dims = {1: "batch", 2: "model"}
        k_dims = {1: "batch"}
    qs = hints.hint_spec(
        qp.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4), q_dims)
    ks = hints.hint_spec(
        kp.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4), k_dims)
    vs = hints.hint_spec(
        vp.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4), k_dims)

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    def q_body(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = q_offset + iq * q_chunk + q_pos_base       # (Cq,)

        # checkpoint each (q-chunk × kv-chunk) tile: the backward pass
        # recomputes the tile's score/softmax instead of saving a
        # (B,KV,G,Cq,Ck) f32 tensor per tile (which is ~GBs per layer at
        # 4k-32k sequence lengths — the classic flash-attention trade)
        @jax.checkpoint
        def kv_body(carry, kv_and_idx):
            acc, m, l = carry
            kj, vj, jk = kv_and_idx
            k_pos = jk * kv_chunk + k_pos_base             # (Ck,)
            bias = jnp.zeros((q_chunk, kv_chunk), jnp.float32)
            if causal:
                bias = jnp.where(k_pos[None, :] > q_pos[:, None],
                                 NEG_INF, bias)
            if window:
                bias = jnp.where(k_pos[None, :] <= q_pos[:, None] - window,
                                 NEG_INF, bias)
            bias = jnp.where((k_pos[None, :] >= T), NEG_INF, bias)  # pad
            acc2, m2, l2 = _chunk_attend(qi, kj, vj, bias)
            return _merge(acc, m, l, acc2, m2, l2), None

        acc0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0), (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        out = out.astype(q.dtype)
        hdim = {0: "batch", 1: "model"} if H % max(msize, 1) == 0 \
            else {0: "batch", 2: "model"}
        return None, hints.hint_spec(out, hdim)

    _, outs = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    # outs: (nq, B, H, Cq, hd) → (B, S, H, hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sp, H, hd)
    return out[:, :S]


# ---------------------------------------------------------------------------
# full attention module (projections + rope + chunked core)
# ---------------------------------------------------------------------------

def attention_fwd(
    p: Params, x: jax.Array, *,
    n_heads: int, n_kv: int, head_dim: int,
    positions: Optional[jax.Array] = None,      # (B,S) or (3,B,S) for mrope
    rope_theta: float = 1e4, use_mrope: bool = False,
    causal: bool = True, window: int = 0,
    x_kv: Optional[jax.Array] = None,           # cross-attention source
    kv_positions: Optional[jax.Array] = None,
) -> jax.Array:
    xkv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(p, x, xkv, n_heads, n_kv, head_dim)
    if positions is not None:
        if use_mrope:
            q = rope_mod.apply_mrope(q, positions, theta=rope_theta)
            k = rope_mod.apply_mrope(
                k, positions if kv_positions is None else kv_positions,
                theta=rope_theta)
        else:
            q = rope_mod.apply_rope(q, positions, theta=rope_theta)
            kp = positions if kv_positions is None else kv_positions
            k = rope_mod.apply_rope(k, kp, theta=rope_theta)
    out = chunked_attention(q, k, v, causal=causal, window=window)
    B, S = x.shape[:2]
    return out.reshape(B, S, n_heads * head_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# decode path with KV cache (ring buffer when window > 0)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array        # (B, T, KV, hd); T = window if SWA else max_len
    v: jax.Array
    index: jax.Array    # scalar int32: absolute number of tokens seen


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  *, window: int = 0, dtype=jnp.bfloat16) -> KVCache:
    T = min(window, max_len) if window else max_len
    z = jnp.zeros((batch, T, n_kv, head_dim), dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


def decode_attention(
    p: Params, x: jax.Array, cache: KVCache, *,
    n_heads: int, n_kv: int, head_dim: int,
    rope_theta: float = 1e4, use_mrope: bool = False,
    window: int = 0,
) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, d)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv, head_dim)
    pos = jnp.full((B, 1), cache.index, jnp.int32)
    if use_mrope:
        pos3 = jnp.broadcast_to(pos, (3, B, 1))
        q = rope_mod.apply_mrope(q, pos3, theta=rope_theta)
        k = rope_mod.apply_mrope(k, pos3, theta=rope_theta)
    else:
        q = rope_mod.apply_rope(q, pos, theta=rope_theta)
        k = rope_mod.apply_rope(k, pos, theta=rope_theta)

    T = cache.k.shape[1]
    slot = (cache.index % T).astype(jnp.int32) if window else cache.index
    new_k = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))

    G = n_heads // n_kv
    qg = q.reshape(B, 1, n_kv, G, head_dim)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, new_k) / math.sqrt(head_dim)
    t_idx = jnp.arange(T)
    if window:
        # ring buffer: every slot written so far is within the window
        written = jnp.minimum(cache.index + 1, T)
        valid = t_idx < written
    else:
        valid = t_idx <= cache.index
    s = jnp.where(valid[None, None, None, None, :], s.astype(jnp.float32),
                  NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(new_v.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", a, new_v)
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"]
    return out, KVCache(new_k, new_v, cache.index + 1)


# ---------------------------------------------------------------------------
# cross-attention decode: static precomputed encoder KV
# ---------------------------------------------------------------------------

def precompute_cross_kv(p: Params, enc_out: jax.Array, *,
                        n_kv: int, head_dim: int):
    k = (enc_out @ p["wk"])
    v = (enc_out @ p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    B, T = enc_out.shape[:2]
    return (k.reshape(B, T, n_kv, head_dim), v.reshape(B, T, n_kv, head_dim))


def cross_attention_decode(p: Params, x: jax.Array, cross_kv, *,
                           n_heads: int, n_kv: int, head_dim: int):
    B = x.shape[0]
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, 1, n_heads, head_dim)
    k, v = cross_kv
    G = n_heads // n_kv
    qg = q.reshape(B, 1, n_kv, G, head_dim)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k) / math.sqrt(head_dim)
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", a, v)
    return out.reshape(B, 1, n_heads * head_dim) @ p["wo"]
