"""Model zoo: functional JAX implementations of the assigned families."""
