"""Version-compat shims for the jax APIs this repo uses.

Pinned floor is jax 0.4.37 (the container's toolchain); newer releases
added two things we rely on:

- ``jax.sharding.AxisType`` (mesh axis_types kwarg).  Older jax takes no
  ``axis_types`` and treats every axis as Auto — exactly the behaviour we
  request, so the shim simply drops the kwarg.
- autodiff/batching rules for ``jax.lax.optimization_barrier``.  On
  0.4.37 reverse-mode (and vmap) through the barrier raise
  NotImplementedError; the barrier is semantically the identity, so the
  shim registers identity jvp/transpose/batching rules directly on the
  primitive.  The barrier itself still applies in the forward computation
  — only the missing transformation rules are filled in.

Both shims are gated on the RUNNING jax version: on jax >= 0.5 the rules
ship with jax and :func:`install_barrier_rules` is a hard no-op, so a
toolchain bump can never double-register (or shadow) the real rules.
``tests/test_compat.py`` exercises both branches.
"""
from __future__ import annotations

from typing import Tuple

import jax

AxisType = getattr(jax.sharding, "AxisType", None)


def version_tuple(version: str) -> Tuple[int, ...]:
    """``"0.4.37"`` → ``(0, 4, 37)``; dev/rc suffixes are ignored
    (``"0.5.0.dev20250101"`` → ``(0, 5, 0)``)."""
    parts = []
    for p in version.split(".")[:3]:
        digits = ""
        for ch in p:
            if not ch.isdigit():
                break
            digits += ch
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)


#: True on the 0.4.x toolchain that needs the barrier-rule shims; jax
#: >= 0.5 ships the rules itself and must NOT be patched.
NEEDS_BARRIER_SHIMS = version_tuple(jax.__version__) < (0, 5)


def mesh_axis_kwargs(n_axes: int) -> dict:
    """kwargs for jax.make_mesh marking all ``n_axes`` axes Auto."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def install_barrier_rules(*, needed: bool = NEEDS_BARRIER_SHIMS) -> bool:
    """Fill in ``optimization_barrier``'s missing AD/batching rules.

    Returns True iff anything was registered this call.  No-op when
    ``needed`` is False (jax >= 0.5: the rules exist upstream and
    re-registering would shadow them) and idempotent when True (each
    rule is only added if the primitive has none — a second call
    returns False).
    """
    if not needed:
        return False
    from jax.interpreters import ad, batching
    try:
        from jax._src.lax.lax import optimization_barrier_p as prim
    except ImportError:      # layout changed → newer jax → rules exist
        return False

    def _tuple(outs):
        return tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)

    installed = False
    if prim not in batching.primitive_batchers:
        def _batch(args, dims):
            return _tuple(prim.bind(*args)), dims

        batching.primitive_batchers[prim] = _batch
        installed = True

    if prim not in ad.primitive_jvps:
        def _jvp(primals, tangents):
            tans = tuple(ad.instantiate_zeros(t) if isinstance(t, ad.Zero)
                         else t for t in tangents)
            return _tuple(prim.bind(*primals)), tans

        ad.primitive_jvps[prim] = _jvp
        installed = True

    if prim not in ad.primitive_transposes:
        def _transpose(cts, *args):
            return _tuple(cts)

        ad.primitive_transposes[prim] = _transpose
        installed = True
    return installed


install_barrier_rules()

optimization_barrier = jax.lax.optimization_barrier
