"""Version-compat shims for the jax APIs this repo uses.

Pinned floor is jax 0.4.37 (the container's toolchain); newer releases
added two things we rely on:

- ``jax.sharding.AxisType`` (mesh axis_types kwarg).  Older jax takes no
  ``axis_types`` and treats every axis as Auto — exactly the behaviour we
  request, so the shim simply drops the kwarg.
- autodiff/batching rules for ``jax.lax.optimization_barrier``.  On
  0.4.37 reverse-mode (and vmap) through the barrier raise
  NotImplementedError; the barrier is semantically the identity, so the
  shim registers identity jvp/transpose/batching rules directly on the
  primitive.  The barrier itself still applies in the forward computation
  — only the missing transformation rules are filled in.
"""
from __future__ import annotations

import jax

AxisType = getattr(jax.sharding, "AxisType", None)


def mesh_axis_kwargs(n_axes: int) -> dict:
    """kwargs for jax.make_mesh marking all ``n_axes`` axes Auto."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def _install_barrier_rules() -> None:
    from jax.interpreters import ad, batching
    try:
        from jax._src.lax.lax import optimization_barrier_p as prim
    except ImportError:      # layout changed → newer jax → rules exist
        return

    def _tuple(outs):
        return tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)

    if prim not in batching.primitive_batchers:
        def _batch(args, dims):
            return _tuple(prim.bind(*args)), dims

        batching.primitive_batchers[prim] = _batch

    if prim not in ad.primitive_jvps:
        def _jvp(primals, tangents):
            tans = tuple(ad.instantiate_zeros(t) if isinstance(t, ad.Zero)
                         else t for t in tangents)
            return _tuple(prim.bind(*primals)), tans

        ad.primitive_jvps[prim] = _jvp

    if prim not in ad.primitive_transposes:
        def _transpose(cts, *args):
            return _tuple(cts)

        ad.primitive_transposes[prim] = _transpose


_install_barrier_rules()

optimization_barrier = jax.lax.optimization_barrier
