# Repro harness targets.  PYTHONPATH=src is baked into every target.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test test-fast test-cohort test-sharded test-service test-faults \
    test-privacy bench-engine bench-engine-smoke bench-kernels \
    bench-kernels-smoke bench-scale bench-scale-smoke bench-service \
    bench-service-smoke bench-privacy bench-privacy-smoke bench \
    quickstart examples-smoke

# tier-1 verify: the whole suite, fail-fast (matches ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# engine + core only (skips the slow per-arch smoke sweep)
test-fast:
	$(PY) -m pytest -x -q tests/test_core_masking.py tests/test_kernels.py \
	    tests/test_mask_uplink.py \
	    tests/test_codecs.py tests/test_round_engine.py \
	    tests/test_scan_engine.py tests/test_fed_engine.py \
	    tests/test_experiment_api.py tests/test_history_golden.py

# streaming cohort tier: cohort ≡ scan parity, hierarchical count
# aggregation, skewed populations, 1e5-client smoke (CI job: test-cohort)
test-cohort:
	$(PY) -m pytest -x -q tests/test_cohort_engine.py \
	    tests/test_federated_skew.py

# wire-true service tier: serde round-trips, loopback sync ≡ scan parity,
# measured bytes-on-wire, async staleness goldens (CI job: test-service)
test-service:
	$(PY) -m pytest -x -q tests/test_service.py

# availability + fault-injection tier: dropout traces on every engine,
# degraded codec partials, service FaultPlan drops/corrupts/crashes/hangs
# with exact accounting (CI job: test-faults)
test-faults:
	$(PY) -m pytest -x -q tests/test_availability.py tests/test_faults.py

# distributed-DP tier: discrete mechanisms, RDP accounting, noise-once
# split invariance, five-engine DP parity, coordinator (ε, δ) metrics
# (CI job: test-privacy, run with REPRO_REQUIRE_HYPOTHESIS=1)
test-privacy:
	$(PY) -m pytest -x -q tests/test_privacy.py tests/test_compat.py

# multi-device tier: 8 fake CPU devices so the pod client mesh axis and
# the shard_map seed mesh genuinely partition (CI job: test-multidevice)
test-sharded:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PY) -m pytest -x -q tests/test_sharded_engine.py \
	    tests/test_sharding_units.py

# looped/batched/scan round engine benchmark (ISSUE 1+2 acceptance);
# writes machine-readable BENCH_engine.json at the repo root
bench-engine:
	$(PY) -m benchmarks.run --only engine

# 1 tiny config — keeps the BENCH_engine.json emitter green in CI
bench-engine-smoke:
	$(PY) -m benchmarks.run --only engine --quick

# fused vs staged mask-uplink kernel microbench (ISSUE 6 acceptance);
# writes machine-readable BENCH_kernels.json at the repo root
bench-kernels:
	$(PY) -m benchmarks.run --only kernels

# tiny sizes — keeps the BENCH_kernels.json emitter green in CI
bench-kernels-smoke:
	$(PY) -m benchmarks.run --only kernels --quick

# cohort-streaming scale bench: clients/sec at C up to 1e6 host-resident
# clients + prefetch on/off ratio; writes BENCH_scale.json at the repo root
bench-scale:
	$(PY) -m benchmarks.run --only scale

# small populations (C <= 1e4) — keeps the BENCH_scale.json emitter green
bench-scale-smoke:
	$(PY) -m benchmarks.run --only scale --quick

# loopback-HTTP coordinator bench: service vs scan rounds/sec, measured
# bytes-on-wire, sync vs async latency; writes BENCH_service.json
bench-service:
	$(PY) -m benchmarks.run --only service

# few rounds — keeps the BENCH_service.json emitter green in CI
bench-service-smoke:
	$(PY) -m benchmarks.run --only service --quick

# measured ε/accuracy/bits trade-off of the DP count release across
# noise multipliers; writes BENCH_privacy.json at the repo root
bench-privacy:
	$(PY) -m benchmarks.run --only privacy

# fewer rounds — keeps the BENCH_privacy.json emitter green in CI
bench-privacy-smoke:
	$(PY) -m benchmarks.run --only privacy --quick

bench:
	$(PY) -m benchmarks.run --quick

quickstart:
	$(PY) examples/quickstart.py

# tiny-round example runs — keeps the Experiment-API examples green in CI
examples-smoke:
	$(PY) examples/quickstart.py --rounds 4
	$(PY) examples/fed_image_cnn.py --rounds 3 --seeds 2
	$(PY) examples/alpha_curve.py --rounds 3 --seeds 1 --alphas 0.5,5.0
