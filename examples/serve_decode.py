"""Serving demo: batched autoregressive decode with a KV cache on a reduced
assigned architecture — the serve-side path the decode_32k / long_500k
dry-run shapes exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-1.8b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(layers=2, d_model=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(args.batch, max_len=256, dtype=jnp.float32)

    step = jax.jit(model.decode_step)
    tok = jnp.zeros((args.batch, 1), jnp.int32)
    # greedy decode loop
    logits, cache = step(params, cache, tok)   # compile
    t0 = time.time()
    out = []
    for _ in range(args.steps):
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = step(params, cache, tok)
        out.append(tok[:, 0])
    dt = time.time() - t0
    seqs = jnp.stack(out, axis=1)
    print(f"arch={args.arch} ({cfg.arch_type}) batch={args.batch}")
    print(f"{args.steps} steps in {dt:.2f}s → "
          f"{args.batch*args.steps/dt:.1f} tok/s (CPU, reduced model)")
    print("sample:", seqs[0][:16].tolist())
    if cfg.sliding_window:
        print(f"SWA ring cache: window={cfg.sliding_window} "
              "(bounded memory at any context length)")


if __name__ == "__main__":
    main()
