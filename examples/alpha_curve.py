"""ROADMAP 4(a): accuracy vs Dirichlet α — fedmrn vs fedavg.

The paper's Non-IID-1 partition draws each client's label mix from
Dirichlet(α); small α is extreme label skew.  This driver runs
``repro.fed.scenarios.alpha_curve`` for a set of algorithms over the
same synthetic task (identical samples and model init per α — only the
partition moves) and writes one JSON record of the measured curve.

Run:  PYTHONPATH=src python examples/alpha_curve.py

The committed smoke-scale record lives at
``experiments/alpha_curve_smoke.json`` (regenerate with
``--out experiments/alpha_curve_smoke.json``); CI re-runs the script at
the same scale and asserts the committed record is non-empty.
"""
import argparse
import json
import os

from repro.fed import FLConfig
from repro.fed.scenarios import alpha_curve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--alphas", default="0.1,1.0,10.0",
                    help="comma-separated Dirichlet α values")
    ap.add_argument("--algos", default="fedmrn,fedavg")
    ap.add_argument("--out", default="/tmp/alpha_curve.json")
    args = ap.parse_args()
    alphas = tuple(float(a) for a in args.alphas.split(","))

    record = {
        "scenario": "alpha_curve", "partition": "noniid1",
        "rounds": args.rounds, "seeds": args.seeds,
        "alphas": list(alphas), "algorithms": {},
    }
    spec_kw = dict(n=1024, hw=8, n_classes=4, d_hidden=24)
    for algo in args.algos.split(","):
        cfg = FLConfig(algorithm=algo, num_clients=8, clients_per_round=4,
                       rounds=args.rounds, local_steps=2, batch_size=16)
        curve = alpha_curve(cfg, alphas=alphas, seeds=args.seeds,
                            spec_kw=spec_kw)
        record["algorithms"][algo] = curve
        accs = {a: p["final_acc_mean"]
                for a, p in curve["points"].items()}
        print(f"{algo:8s} " + "  ".join(
            f"α={a}: {m:.3f}" for a, m in accs.items()))

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
