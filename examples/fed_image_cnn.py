"""End-to-end driver: full baseline sweep on the synthetic image task —
the CPU-scale analogue of the paper's Table 1 (one dataset, one
partition), with per-round accuracy curves, multi-seed error bars, and
checkpointing.

Run:  PYTHONPATH=src python examples/fed_image_cnn.py [--partition noniid2]

One ``ExperimentSpec`` per algorithm; ``--algos all`` enumerates every
algorithm in the plugin registry (``repro.fed.list_algorithms``) instead
of the curated paper zoo.  ``--seeds N`` (N > 1) runs each algorithm as a
vmapped multi-seed sweep — N seeds resident in ONE compiled program — and
reports mean±std.  ``--engine`` picks the execution model (scan fuses the
whole experiment into ⌈R/chunk⌉ jitted dispatches; batched dispatches one
program per round; looped is the seed's per-client reference loop).
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import Experiment, ExperimentSpec, FLConfig, list_algorithms
from repro.models.cnn import cnn_apply, cnn_init, cnn_loss

PAPER_ALGOS = ("fedavg", "fedmrn", "fedmrns", "signsgd", "terngrad", "topk",
               "drive", "eden", "fedpm", "fedsparsify")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--partition", default="noniid2",
                    choices=["iid", "noniid1", "noniid2"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--algos", default="paper", choices=["paper", "all"],
                    help="paper = the Table-1 zoo; all = every registered "
                         "algorithm (repro.fed.list_algorithms)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="N > 1 runs a vmapped N-seed sweep per algorithm "
                         "and reports mean±std")
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "batched", "looped"],
                    help="scan = whole experiment fused into chunked "
                         "lax.scan programs (default); batched = one XLA "
                         "program per round; looped = legacy per-client "
                         "reference loop")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rounds per scan dispatch (default: all)")
    ap.add_argument("--out", default="/tmp/fed_image_cnn")
    args = ap.parse_args()
    if args.seeds > 1 and args.engine != "scan":
        ap.error("--seeds > 1 runs the vmapped scan sweep; "
                 "drop --engine or use --engine scan")

    task = make_image_task(0, n=3000, hw=16, n_classes=8, noise=0.5)
    n_test = 600
    xtr, ytr = task.x[:-n_test], task.y[:-n_test]
    parts = make_partition(args.partition, 0, ytr, num_clients=10)
    params0 = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))
    ds = make_federated_dataset(xtr, ytr, parts, x_test=task.x[-n_test:],
                                y_test=task.y[-n_test:], batch_seed=997)
    os.makedirs(args.out, exist_ok=True)
    algos = PAPER_ALGOS if args.algos == "paper" else list_algorithms()

    print(f"partition={args.partition} rounds={args.rounds} "
          f"engine={args.engine} seeds={args.seeds}")
    print(f"{'algorithm':12s} {'acc':>6s} {'bpp':>7s} {'round-curve'}")
    for algo in algos:
        cfg = FLConfig(algorithm=algo, num_clients=10, clients_per_round=5,
                       rounds=args.rounds, local_steps=10, batch_size=32,
                       lr=0.1,
                       noise_alpha=0.025 if algo == "fedmrns" else 0.05)
        exp = Experiment(ExperimentSpec(
            loss_fn=cnn_loss, params=params0, data=ds, config=cfg,
            eval_apply=cnn_apply,               # eval auto-wired from split
            eval_every=max(1, args.rounds // 5)))

        if args.seeds > 1:
            sweep = exp.sweep(seeds=args.seeds, chunk=args.chunk)
            mean, std = sweep.point.mean_std()
            res = sweep.runs[0]
            acc_str = f"{mean:.3f}±{std:.3f}"
            curve = " ".join(f"{a:.2f}"
                             for a in sweep.acc.mean(axis=0))
            acc_save = jnp.asarray(sweep.acc)
        else:
            res = exp.run(engine=args.engine, chunk=args.chunk)
            acc_str = f"{res.final_acc:6.3f}"
            curve = " ".join(f"{a:.2f}" for a in res.acc)
            acc_save = jnp.asarray(res.acc)
        bpp = res.uplink_bits_per_client / res.num_params
        print(f"{algo:12s} {acc_str:>6s} {bpp:7.2f} {curve}")
        checkpoint.save(os.path.join(args.out, f"{algo}.npz"),
                        {"acc": acc_save})


if __name__ == "__main__":
    main()
