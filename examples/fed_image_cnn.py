"""End-to-end driver: full baseline sweep on the synthetic image task —
the CPU-scale analogue of the paper's Table 1 (one dataset, one
partition), with per-round accuracy curves and checkpointing.

Run:  PYTHONPATH=src python examples/fed_image_cnn.py [--partition noniid2]

``--engine scan`` (default) fuses the whole experiment into ⌈R/chunk⌉
jitted dispatches with a device-resident dataset and on-device eval;
``batched`` dispatches one program per round; ``looped`` is the seed's
per-client reference loop.
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import FLConfig, run_federated
from repro.models.cnn import cnn_eval_program, cnn_init, cnn_loss

ALGOS = ("fedavg", "fedmrn", "fedmrns", "signsgd", "terngrad", "topk",
         "drive", "eden", "fedpm", "fedsparsify")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--partition", default="noniid2",
                    choices=["iid", "noniid1", "noniid2"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "batched", "looped"],
                    help="scan = whole experiment fused into chunked "
                         "lax.scan programs (default); batched = one XLA "
                         "program per round; looped = legacy per-client "
                         "reference loop")
    ap.add_argument("--chunk", type=int, default=None,
                    help="rounds per scan dispatch (default: all)")
    ap.add_argument("--out", default="/tmp/fed_image_cnn")
    args = ap.parse_args()

    task = make_image_task(0, n=3000, hw=16, n_classes=8, noise=0.5)
    n_test = 600
    xtr, ytr = task.x[:-n_test], task.y[:-n_test]
    parts = make_partition(args.partition, 0, ytr, num_clients=10)
    params0 = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))
    ds = make_federated_dataset(xtr, ytr, parts, x_test=task.x[-n_test:],
                                y_test=task.y[-n_test:], batch_seed=997)
    eval_prog = cnn_eval_program(ds.x_test, ds.y_test)
    os.makedirs(args.out, exist_ok=True)

    print(f"partition={args.partition} rounds={args.rounds} "
          f"engine={args.engine}")
    header = f"{'algorithm':12s} {'acc':>6s} {'bpp':>7s} {'round-curve'}"
    print(header)
    for algo in ALGOS:
        cfg = FLConfig(algorithm=algo, num_clients=10, clients_per_round=5,
                       rounds=args.rounds, local_steps=10, batch_size=32,
                       lr=0.1,
                       noise_alpha=0.025 if algo == "fedmrns" else 0.05)

        hist = run_federated(cnn_loss, params0, ds, None, cfg,
                             eval_program=eval_prog,
                             eval_every=max(1, args.rounds // 5),
                             engine=args.engine, chunk=args.chunk)
        bpp = hist["uplink_bits_per_client"] / hist["params"]
        curve = " ".join(f"{a:.2f}" for a in hist["acc"])
        print(f"{algo:12s} {hist['final_acc']:6.3f} {bpp:7.2f} {curve}")
        checkpoint.save(os.path.join(args.out, f"{algo}.npz"),
                        {"acc": jnp.asarray(hist["acc"])})


if __name__ == "__main__":
    main()
