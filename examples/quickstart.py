"""Quickstart: FedMRN vs FedAvg on a synthetic federated image task.

Run:  PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's headline claim in ~2 min on CPU: FedMRN matches
FedAvg accuracy while sending 1 bit per parameter uplink (~32x compression).

The whole experiment runs as ONE jitted XLA program (engine="scan"): the
dataset lives on device (``make_federated_dataset``), and a multi-round
``lax.scan`` fuses client selection, batch gathering, local PSM training,
aggregation, and eval — the host dispatches once and reads the metric
buffers at the end.  Pass ``engine="batched"`` for one program per round,
or ``engine="looped"`` for the legacy per-client loop.
"""
import jax
import jax.numpy as jnp

from repro.data import make_federated_dataset, make_image_task, make_partition
from repro.fed import FLConfig, run_federated
from repro.models.cnn import cnn_eval_program, cnn_init, cnn_loss


def main():
    task = make_image_task(0, n=2000, hw=16, n_classes=8, noise=0.5)
    parts = make_partition("noniid2", 0, task.y, num_clients=10,
                           labels_per_client=3)
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=997)
    eval_prog = cnn_eval_program(jnp.asarray(task.x), jnp.asarray(task.y))

    for algo in ("fedavg", "fedmrn", "fedmrns", "signsgd"):
        # noise magnitude must match the local-update scale (paper Fig. 5);
        # FedMRNS needs about half of FedMRN's noise (paper §5.5)
        cfg = FLConfig(algorithm=algo, num_clients=10, clients_per_round=5,
                       rounds=15, local_steps=10, batch_size=32, lr=0.1,
                       noise_alpha=0.025 if algo == "fedmrns" else 0.05)
        hist = run_federated(cnn_loss, params, ds, None, cfg,
                             eval_program=eval_prog, eval_every=5,
                             engine="scan")
        bpp = hist["uplink_bits_per_client"] / hist["params"]
        print(f"{algo:10s} acc={hist['final_acc']:.3f} "
              f"uplink={bpp:6.2f} bit/param "
              f"(x{32/bpp:.1f} compression) wall={hist['wall_s']:.1f}s "
              f"dispatches={hist['num_dispatches']}")


if __name__ == "__main__":
    main()
