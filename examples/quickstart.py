"""Quickstart: FedMRN vs FedAvg on a synthetic federated image task.

Run:  PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's headline claim in ~1 min on CPU: FedMRN matches
FedAvg accuracy while sending 1 bit per parameter uplink (~32x compression).

Each round executes as ONE jitted XLA program (all selected clients vmapped
over a stacked client axis — see src/repro/fed/engine.py); pass
``engine="looped"`` to run_federated for the legacy per-client loop.
"""
import jax

from repro.data import make_image_task, make_partition, sample_local_batches
from repro.fed import FLConfig, run_federated
from repro.models.cnn import cnn_accuracy, cnn_init, cnn_loss


def main():
    task = make_image_task(0, n=2000, hw=16, n_classes=8, noise=0.5)
    parts = make_partition("noniid2", 0, task.y, num_clients=10,
                           labels_per_client=3)
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))

    def batch_fn_for(cfg):
        def batch_fn(rnd, cid):
            return sample_local_batches(
                rnd * 997 + cid, task.x, task.y, parts[cid],
                steps=cfg.local_steps, batch=cfg.batch_size)
        return batch_fn

    def eval_fn(p):
        import jax.numpy as jnp
        return float(cnn_accuracy(p, jnp.asarray(task.x),
                                  jnp.asarray(task.y)))

    for algo in ("fedavg", "fedmrn", "fedmrns", "signsgd"):
        # noise magnitude must match the local-update scale (paper Fig. 5);
        # FedMRNS needs about half of FedMRN's noise (paper §5.5)
        cfg = FLConfig(algorithm=algo, num_clients=10, clients_per_round=5,
                       rounds=15, local_steps=10, batch_size=32, lr=0.1,
                       noise_alpha=0.025 if algo == "fedmrns" else 0.05)
        hist = run_federated(cnn_loss, params, batch_fn_for(cfg), eval_fn,
                             cfg, eval_every=5)
        bpp = hist["uplink_bits_per_client"] / hist["params"]
        print(f"{algo:10s} acc={hist['final_acc']:.3f} "
              f"uplink={bpp:6.2f} bit/param "
              f"(x{32/bpp:.1f} compression) wall={hist['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
