"""Quickstart: FedMRN vs FedAvg on a synthetic federated image task.

Run:  PYTHONPATH=src python examples/quickstart.py [--rounds 15]

Demonstrates the paper's headline claim in ~2 min on CPU: FedMRN matches
FedAvg accuracy while sending 1 bit per parameter uplink (~32x compression).

The experiment is DECLARED once (``ExperimentSpec``: algorithm + config +
device-resident dataset + model refs — the eval program is auto-wired from
the test split) and run through the ``Experiment`` facade: the whole
experiment executes as ONE jitted XLA program (scan engine), and the
typed ``RunResult`` carries the acc/loss/uplink trajectories.  Pass
``engine="batched"`` or ``"looped"`` to ``run()`` for the per-round /
per-client execution models.
"""
import argparse
import dataclasses

import jax

from repro.data import make_federated_dataset, make_image_task, make_partition
from repro.fed import Experiment, ExperimentSpec, FLConfig
from repro.models.cnn import cnn_apply, cnn_init, cnn_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    args = ap.parse_args()

    task = make_image_task(0, n=2000, hw=16, n_classes=8, noise=0.5)
    parts = make_partition("noniid2", 0, task.y, num_clients=10,
                           labels_per_client=3)
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))
    ds = make_federated_dataset(task.x, task.y, parts,
                                x_test=task.x, y_test=task.y, batch_seed=997)

    cfg = FLConfig(num_clients=10, clients_per_round=5, rounds=args.rounds,
                   local_steps=10, batch_size=32, lr=0.1)
    for algo in ("fedavg", "fedmrn", "fedmrns", "signsgd"):
        # noise magnitude must match the local-update scale (paper Fig. 5);
        # FedMRNS needs about half of FedMRN's noise (paper §5.5)
        spec = ExperimentSpec(
            loss_fn=cnn_loss, params=params, data=ds,
            config=dataclasses.replace(
                cfg, algorithm=algo,
                noise_alpha=0.025 if algo == "fedmrns" else 0.05),
            eval_apply=cnn_apply,           # auto-wires the eval program
            eval_every=5)
        exp = Experiment(spec)
        res = exp.run()                     # scan engine: ONE program
        rec = exp.comm_record()             # the codec's measured cost
        print(f"{algo:10s} acc={res.final_acc:.3f} "
              f"{type(exp.codec()).__name__:11s} "
              f"uplink={rec.uplink_bpp:6.2f} bit/param "
              f"(paper {rec.uplink_bpp_paper:5.2f}, "
              f"x{rec.compression_x:.1f} compression) "
              f"wall={res.wall_s:.1f}s dispatches={res.num_dispatches}")


if __name__ == "__main__":
    main()
