"""Federated fine-tuning of a (reduced) assigned LLM architecture with
FedMRN — proving the mechanism is architecture-agnostic (DESIGN.md §4).

Any of the 10 assigned archs can be selected; the reduced variant of the
same family is trained on the synthetic modular language, federated across
clients, with FedMRN masks carrying the updates.

Run:  PYTHONPATH=src python examples/fed_llm_finetune.py --arch llama3.2-1b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.data import make_lm_task, partition_iid
from repro.fed import FLConfig, run_federated
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--algorithm", default="fedmrn")
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(layers=2, d_model=128, vocab=64)
    model = build_model(cfg)
    toks, vocab = make_lm_task(0, n_seq=512, seq_len=32, vocab=64)
    parts = partition_iid(0, len(toks), 4)
    params = model.init(jax.random.key(0))

    def wrap_batch(t):
        batch = {"tokens": t[:, :-1], "labels": t[:, 1:]}
        if cfg.arch_type == "vlm":
            B, S = t[:, :-1].shape
            P = cfg.frontend_tokens
            batch["frontend_embeds"] = jnp.zeros((B, P, cfg.d_model),
                                                 cfg.dtype)
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(S + P)[None, None], (3, B, S + P))
        elif cfg.arch_type == "audio":
            B, S = t[:, :-1].shape
            batch["frontend_embeds"] = jnp.zeros((B, S, cfg.d_model),
                                                 cfg.dtype)
        return batch

    def loss_fn(p, stacked):
        return model.loss_fn(p, stacked)

    flcfg = FLConfig(algorithm=args.algorithm, num_clients=4,
                     clients_per_round=2, rounds=args.rounds,
                     local_steps=6, batch_size=16, lr=0.3,
                     noise_alpha=2e-2)

    rng = np.random.RandomState(0)

    def batch_fn(rnd, cid):
        take = rng.choice(parts[cid], size=(flcfg.local_steps,
                                            flcfg.batch_size))
        stacked = jnp.asarray(toks[take])        # (steps, batch, seq)
        batches = [wrap_batch(stacked[i]) for i in range(stacked.shape[0])]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)

    def eval_fn(p):
        return -float(loss_fn(p, wrap_batch(jnp.asarray(toks[:64]))))

    hist = run_federated(loss_fn, params, batch_fn, eval_fn, flcfg,
                         eval_every=2)
    print(f"arch={args.arch} algo={args.algorithm} "
          f"params={hist['params']:,} "
          f"uplink={hist['uplink_bits_per_client']/8e3:.1f} KB/round")
    for r, a in zip(hist["round"], hist["acc"]):
        print(f"  round {r:3d}  negloss {a:.4f}")


if __name__ == "__main__":
    main()
