"""Federated fine-tuning of a (reduced) assigned LLM architecture with
FedMRN — proving the mechanism is architecture-agnostic (DESIGN.md §4).

Any of the 10 assigned archs can be selected; the reduced variant of the
same family is trained on the synthetic modular language, federated across
clients, with FedMRN masks carrying the updates.

The token corpus lives on device as a :class:`FederatedDataset`
(``x`` = inputs, ``y`` = shifted targets) and the whole fine-tune runs as
one scan-engine program via the Experiment API; eval is negative loss on
a held-out batch (``make_negloss_eval_program``) folded into the program.
``--seeds N`` demonstrates the vmapped multi-seed sweep on an LM workload.

Run:  PYTHONPATH=src python examples/fed_llm_finetune.py --arch llama3.2-1b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core import make_negloss_eval_program
from repro.data import make_federated_dataset, make_lm_task, partition_iid
from repro.fed import Experiment, ExperimentSpec, FLConfig
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--algorithm", default="fedmrn")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--seeds", type=int, default=1,
                    help="N > 1: vmapped multi-seed sweep, mean±std negloss")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(layers=2, d_model=128, vocab=64)
    model = build_model(cfg)
    toks, vocab = make_lm_task(0, n_seq=512, seq_len=32, vocab=64)
    parts = partition_iid(0, len(toks), 4)
    params = model.init(jax.random.key(0))

    def wrap_batch(tokens, labels):
        batch = {"tokens": tokens, "labels": labels}
        if cfg.arch_type == "vlm":
            B, S = tokens.shape
            P = cfg.frontend_tokens
            batch["frontend_embeds"] = jnp.zeros((B, P, cfg.d_model),
                                                 cfg.dtype)
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(S + P)[None, None], (3, B, S + P))
        elif cfg.arch_type == "audio":
            B, S = tokens.shape
            batch["frontend_embeds"] = jnp.zeros((B, S, cfg.d_model),
                                                 cfg.dtype)
        return batch

    def loss_fn(p, batch):
        tokens, labels = batch
        return model.loss_fn(p, wrap_batch(tokens, labels))

    # device-resident LM corpus: x = inputs, y = next-token targets
    ds = make_federated_dataset(toks[:, :-1], toks[:, 1:], parts,
                                batch_seed=7)
    eval_prog = make_negloss_eval_program(
        loss_fn, (toks[:64, :-1], toks[:64, 1:]))

    flcfg = FLConfig(algorithm=args.algorithm, num_clients=4,
                     clients_per_round=2, rounds=args.rounds,
                     local_steps=6, batch_size=16, lr=0.3,
                     noise_alpha=2e-2)
    exp = Experiment(ExperimentSpec(
        loss_fn=loss_fn, params=params, data=ds, config=flcfg,
        eval_program=eval_prog, eval_every=2))

    if args.seeds > 1:
        sweep = exp.sweep(seeds=args.seeds)
        res = sweep.runs[0]
        mean, std = sweep.point.mean_std()
        print(f"arch={args.arch} algo={args.algorithm} "
              f"params={res.num_params:,} "
              f"uplink={res.uplink_bits_per_client/8e3:.1f} KB/round "
              f"seeds={args.seeds}")
        for i, r in enumerate(res.eval_rounds):
            col = sweep.acc[:, i]
            print(f"  round {r:3d}  negloss {col.mean():.4f}"
                  f" ± {col.std():.4f}")
        print(f"final negloss {mean:.4f} ± {std:.4f}")
    else:
        res = exp.run()
        print(f"arch={args.arch} algo={args.algorithm} "
              f"params={res.num_params:,} "
              f"uplink={res.uplink_bits_per_client/8e3:.1f} KB/round")
        for r, a in zip(res.eval_rounds, res.acc):
            print(f"  round {r:3d}  negloss {a:.4f}")


if __name__ == "__main__":
    main()
