"""Streaming cohort engine (ISSUE 7): cohort ≡ scan trajectory parity at
fixed seed, prefetch on/off determinism, hierarchical count aggregation,
large-population smoke, and the engine/dataset mismatch guards."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (CohortedDataset, make_cohorted_dataset,
                        make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import (Experiment, ExperimentSpec, FLConfig, MaskCodec,
                       make_cohort_engine, run_federated)
from repro.models.cnn import mlp_apply, mlp_eval_program, mlp_init, mlp_loss

KEY = jax.random.key(0)


def _spec(algorithm, rounds=4, n_clients=8, **cfg_kw):
    task = make_image_task(0, n=800, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, n_clients)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=n_clients,
                   clients_per_round=4, rounds=rounds, local_steps=4,
                   batch_size=16, lr=0.1, noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7)
    prog = mlp_eval_program(jnp.asarray(task.x), jnp.asarray(task.y))
    return ExperimentSpec(loss_fn=mlp_loss, params=params, data=ds,
                          config=cfg, eval_program=prog)


def _assert_parity(a, b, loss_atol=1e-5):
    np.testing.assert_array_equal(a.schedule, b.schedule)
    assert a.eval_rounds == b.eval_rounds
    np.testing.assert_allclose(a.acc, b.acc, atol=1e-6)
    np.testing.assert_allclose(a.local_loss, b.local_loss, atol=loss_atol)
    # measured wire bits: K × per-client bits == scan's per-round
    # codec.round_bits(stacked) — every codec buffer is linear in K
    np.testing.assert_array_equal(a.uplink_bits_round, b.uplink_bits_round)


# ---------------------------------------------------------------------------
# the acceptance criterion: cohort ≡ scan at fixed seed, every family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm,kw", [
    ("fedmrn", {}), ("fedmrns", {}), ("fedpm", {}), ("fedavg", {}),
    # shared noise → the integer count partial path (incl. the signed
    # padded-row adjustment) is what merges across cohorts
    ("fedmrn", {"shared_noise": True}), ("fedmrns", {"shared_noise": True}),
    ("qsgd", {"qsgd_bits": 2}), ("terngrad", {}), ("fedsparsify", {}),
    ("signsgd", {})])
def test_cohort_scan_trajectory_parity(algorithm, kw):
    exp = Experiment(_spec(algorithm, **kw))
    rs = exp.run(engine="scan")
    # cohort_size=3 over 8 clients: every round straddles cohorts, so the
    # hierarchical merge path (partial → tree-add → finalize) is exercised
    rc = exp.run(engine="cohort", cohort_size=3)
    _assert_parity(rs, rc)


def test_cohort_size_invariance_and_single_cohort():
    """The trajectory is independent of the shard layout; one big cohort
    degenerates to the no-merge path."""
    exp = Experiment(_spec("fedmrn"))
    r3 = exp.run(engine="cohort", cohort_size=3)
    r8 = exp.run(engine="cohort", cohort_size=8)    # whole population
    _assert_parity(r3, r8, loss_atol=1e-6)


def test_cohort_prefetch_off_is_bitwise_identical():
    """prefetch=False (strict serial) must be a pure perf ablation."""
    exp = Experiment(_spec("fedmrn"))
    on = exp.run(engine="cohort", cohort_size=3, prefetch=True)
    off = exp.run(engine="cohort", cohort_size=3, prefetch=False)
    np.testing.assert_array_equal(np.asarray(on.acc), np.asarray(off.acc))
    np.testing.assert_array_equal(np.asarray(on.local_loss),
                                  np.asarray(off.local_loss))


def test_cohort_runs_prebuilt_cohorted_dataset():
    """An explicitly host-resident CohortedDataset reproduces the same
    trajectory as the auto-converted FederatedDataset."""
    spec = _spec("fedmrn")
    task = make_image_task(0, n=800, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    cds = make_cohorted_dataset(task.x, task.y, parts, cohort_size=3,
                                batch_seed=7)
    assert isinstance(cds, CohortedDataset)
    exp_fed = Experiment(spec)
    exp_coh = Experiment(dataclasses.replace(spec, data=cds))
    _assert_parity(exp_fed.run(engine="cohort", cohort_size=3),
                   exp_coh.run(engine="cohort"), loss_atol=1e-6)


def test_cohort_through_run_federated_shim():
    spec = _spec("fedmrn")
    with pytest.warns(DeprecationWarning):
        hist = run_federated(spec.loss_fn, spec.params, spec.data, None,
                             spec.config, eval_program=spec.eval_program,
                             engine="cohort")
    rs = Experiment(spec).run(engine="scan")
    np.testing.assert_allclose(hist["acc"], rs.acc, atol=1e-6)
    assert hist["engine"] == "cohort"


# ---------------------------------------------------------------------------
# hierarchical integer aggregation (the tentpole's count half)
# ---------------------------------------------------------------------------

def test_cohort_auto_upgrades_mask_counts_to_int8():
    """Uniform weights + count-aggregatable mask format (shared noise):
    cross-cohort partials ride in min_count_dtype(K), not f32."""
    spec = _spec("fedmrn", shared_noise=True)
    data = spec.data.cohorted(3)
    runner = make_cohort_engine(spec.loss_fn, spec.config, spec.params,
                                data, eval_program=spec.eval_program)
    assert isinstance(runner.codec, MaskCodec)
    assert runner.codec.count_dtype == jnp.int8      # K=4 fits ±127
    metrics, schedule, _ = runner.run()
    assert np.isfinite(metrics["loss"]).all()


def test_cohort_dispatch_count():
    """dispatches = Σ per-round cohort visits + R applies + evals."""
    exp = Experiment(_spec("fedmrn"))
    rc = exp.run(engine="cohort", cohort_size=3)
    co = np.asarray(rc.schedule) // 3
    visits = sum(len(np.unique(row)) for row in co)
    evals = len(rc.eval_rounds)
    assert rc.num_dispatches == visits + rc.config.rounds + evals


# ---------------------------------------------------------------------------
# larger-than-HBM smoke: 1e5 synthetic clients stream through
# ---------------------------------------------------------------------------

def test_cohort_streams_100k_clients():
    C, per, d = 100_000, 4, 16
    rng = np.random.RandomState(0)
    x = rng.randn(C * per, d).astype(np.float32)
    y = rng.randint(0, 4, C * per).astype(np.int32)
    parts = np.arange(C * per, dtype=np.int32).reshape(C, per)
    ds = make_cohorted_dataset(x, y, parts, cohort_size=8192,
                               x_test=x[:256], y_test=y[:256], batch_seed=7)
    assert len(ds.shards) == 13                      # ⌈1e5 / 8192⌉
    params = mlp_init(KEY, d_in=d, d_hidden=16, n_classes=4)
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=32,
                   rounds=2, local_steps=2, batch_size=4, lr=0.1,
                   noise_alpha=3e-2)
    exp = Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                    data=ds, config=cfg,
                                    eval_apply=mlp_apply, eval_every=2))
    r = exp.run(engine="cohort")
    assert np.isfinite(r.local_loss).all() and np.isfinite(r.final_acc)
    # only the visited cohorts' blocks were staged, never the population
    assert r.num_dispatches < 3 * cfg.rounds * cfg.clients_per_round


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------

def test_cohort_rejects_error_feedback():
    exp = Experiment(_spec("fedmrn", error_feedback=True))
    with pytest.raises(ValueError, match="error_feedback"):
        exp.run(engine="cohort", cohort_size=3)


def test_cohorted_dataset_rejected_by_device_engines():
    spec = _spec("fedmrn")
    cds = spec.data.cohorted(3)
    exp = Experiment(dataclasses.replace(spec, data=cds))
    for engine in ("scan", "batched", "looped"):
        with pytest.raises(ValueError, match="cohort"):
            exp.run(engine=engine)
    with pytest.raises(ValueError, match="FederatedDataset"):
        exp.sweep(seeds=2)


def test_cohort_size_conflicts_with_prebuilt_dataset():
    spec = _spec("fedmrn")
    exp = Experiment(dataclasses.replace(spec, data=spec.data.cohorted(3)))
    with pytest.raises(ValueError, match="cohort_size"):
        exp.run(engine="cohort", cohort_size=4)
    with pytest.raises(ValueError, match="cohort_size"):
        Experiment(spec).run(engine="scan", cohort_size=4)
