"""Shared pytest config: the ``tpu`` marker.

Tests marked ``@pytest.mark.tpu`` drive the Pallas kernels in compiled
(non-interpret) mode and only make sense on a real TPU host; elsewhere
they auto-skip here instead of being hand-guarded file by file.
"""
import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu: needs a real TPU (compiled, non-interpret Pallas); "
        "auto-skipped when jax.default_backend() != 'tpu'")


def pytest_collection_modifyitems(config, items):
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="requires TPU (compiled, non-interpret Pallas)")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip)
