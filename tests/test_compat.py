"""The jax version gate on ``repro.compat``'s 0.4.x shims.

A toolchain bump past 0.5 must turn :func:`install_barrier_rules` into
a hard no-op (the AD/batching rules ship with jax there — registering
ours would shadow them); on the pinned 0.4.37 floor the rules must be
installed exactly once, and gradients/vmap through the barrier must
work.  Both branches run on ANY toolchain: the gate is an explicit
argument.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import compat


def test_version_tuple_parses_releases_and_dev_builds():
    assert compat.version_tuple("0.4.37") == (0, 4, 37)
    assert compat.version_tuple("0.5.0") == (0, 5, 0)
    assert compat.version_tuple("0.5.0.dev20250101") == (0, 5, 0)
    assert compat.version_tuple("0.5.0rc1") == (0, 5, 0)
    assert compat.version_tuple("1.0") == (1, 0)
    assert compat.version_tuple("0.4.37") < (0, 5)
    assert not compat.version_tuple("0.5.3") < (0, 5)


def test_gate_matches_running_jax():
    assert compat.NEEDS_BARRIER_SHIMS == (
        compat.version_tuple(jax.__version__) < (0, 5))


def test_new_jax_branch_is_a_hard_noop():
    """needed=False (the >= 0.5 branch) must touch NO registry."""
    from jax.interpreters import ad, batching
    before = (dict(batching.primitive_batchers), dict(ad.primitive_jvps),
              dict(ad.primitive_transposes))
    assert compat.install_barrier_rules(needed=False) is False
    after = (dict(batching.primitive_batchers), dict(ad.primitive_jvps),
             dict(ad.primitive_transposes))
    assert before == after


def test_old_jax_branch_is_idempotent():
    """On the shimmed toolchain the rules are already in (module import
    installed them) — a second forced call must register nothing, so a
    double import / re-run can never stack rules."""
    if not compat.NEEDS_BARRIER_SHIMS:
        pytest.skip("running on jax >= 0.5: nothing was installed")
    assert compat.install_barrier_rules(needed=True) is False


def test_barrier_rules_actually_work():
    """grad + vmap through optimization_barrier — the failures the shim
    exists to fix on 0.4.37 (identity semantics either branch)."""

    def f(x):
        return jnp.sum(compat.optimization_barrier(x * 2.0))

    x = jnp.arange(3.0)
    assert jax.grad(f)(x) == pytest.approx([2.0, 2.0, 2.0])
    y = jax.vmap(lambda v: compat.optimization_barrier(v) + 1.0)(x)
    assert y == pytest.approx([1.0, 2.0, 3.0])


def test_mesh_axis_kwargs_shape():
    kw = compat.mesh_axis_kwargs(2)
    if compat.AxisType is None:
        assert kw == {}
    else:
        assert kw == {"axis_types": (compat.AxisType.Auto,) * 2}
