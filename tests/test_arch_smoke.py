"""Per-architecture smoke tests: reduced variant (≤2 layers, d≤512,
≤4 experts) runs one train step and one decode step on CPU; asserts output
shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.models.registry import build_model, input_specs, count_params

KEY = jax.random.key(0)
B, S = 2, 32


def _concrete_batch(cfg, B, S, key):
    """A small real train batch for the reduced config."""
    kt, ke = jax.random.split(key)
    tok = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.arch_type == "vlm":
        P = cfg.frontend_tokens
        batch["frontend_embeds"] = 0.02 * jax.random.normal(
            ke, (B, P, cfg.d_model), cfg.dtype)
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S + P)[None, None], (3, B, S + P))
    elif cfg.arch_type == "audio":
        batch["frontend_embeds"] = 0.02 * jax.random.normal(
            ke, (B, S, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(KEY)
        batch = _concrete_batch(cfg, B, S, KEY)

        @jax.jit
        def step(p, b):
            loss, grads = jax.value_and_grad(model.loss_fn)(p, b)
            p2 = jax.tree_util.tree_map(lambda a, g: a - 0.1 * g, p, grads)
            return loss, p2

        loss, p2 = step(params, batch)
        assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
        # one leaf actually moved
        moved = any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(p2)))
        assert moved, f"{arch}: no parameter moved"

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(KEY)
        cache = model.init_cache(B, 64, jnp.float32)
        tok = jnp.zeros((B, 1), jnp.int32)

        @jax.jit
        def step(p, c, t):
            return model.decode_step(p, c, t)

        logits, cache = step(params, cache, tok)
        logits, cache = step(params, cache, tok)  # second step reuses cache
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"

    def test_input_specs_no_allocation(self, arch):
        cfg = get_config(arch)  # FULL config — specs only, no arrays
        for shape in INPUT_SHAPES.values():
            specs = input_specs(cfg, shape)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert hasattr(leaf, "shape")

    def test_param_count_plausible(self, arch):
        cfg = get_config(arch)
        n = count_params(cfg)
        # every assigned arch is 0.3B..300B params
        assert 3e8 < n < 3e11, f"{arch}: {n/1e9:.2f}B params"
