"""Population skew (ISSUE 7 satellite): make_federated_dataset under
extreme client-size imbalance, the cohort tier's per-shard Lmax padding
win, and the cohort-vs-whole-population gather equivalence property."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # hypothesis is a pinned requirement (requirements.txt) and the
    # property tests are tier-1 in CI: REPRO_REQUIRE_HYPOTHESIS=1 there
    # makes a missing install a hard failure instead of a skip.
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.data import (cohort_gather, make_cohorted_dataset,
                        make_federated_dataset)


def _skewed(sizes, d=6, seed=0):
    """A population whose client c owns ``sizes[c]`` consecutive rows."""
    n = int(np.sum(sizes))
    rng = np.random.RandomState(seed)
    x = rng.randn(n, d).astype(np.float32)
    y = rng.randint(0, 4, n).astype(np.int32)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    parts = [np.arange(offs[c], offs[c + 1], dtype=np.int32)
             for c in range(len(sizes))]
    return x, y, parts


# ---------------------------------------------------------------------------
# extreme skew through the device-resident dataset
# ---------------------------------------------------------------------------

def test_extreme_skew_pads_to_largest_client():
    sizes = [500, 1, 1, 2, 300, 3, 1, 7]
    x, y, parts = _skewed(sizes)
    ds = make_federated_dataset(x, y, parts, batch_seed=3)
    assert ds.client_idx.shape == (8, 500)          # global Lmax padding
    np.testing.assert_array_equal(np.asarray(ds.client_len), sizes)


def test_skewed_gather_stays_inside_partitions():
    """Size-1 clients only ever sample their single example; every other
    client stays inside its slice."""
    sizes = [200, 1, 5, 1, 100]
    x, y, parts = _skewed(sizes)
    ds = make_federated_dataset(x, y, parts, batch_seed=3)
    xs, ys = ds.gather_batches(jnp.int32(0), jnp.arange(5, dtype=jnp.int32),
                               steps=3, batch=4)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    for c in range(5):
        got = np.asarray(xs[c]).reshape(-1, x.shape[1])
        owned = x[offs[c]:offs[c + 1]]
        for row in got:
            assert (row == owned).all(axis=1).any()
    np.testing.assert_array_equal(np.asarray(xs[1]),
                                  np.broadcast_to(x[200], xs[1].shape))


def test_cohort_shards_shrink_index_padding():
    """Grouping like-sized clients: per-cohort Lmax padding is a fraction
    of the whole-population C × global-Lmax index matrix."""
    sizes = [400, 395, 2, 3, 1, 4, 2, 1]           # big pair, small tail
    x, y, parts = _skewed(sizes)
    cds = make_cohorted_dataset(x, y, parts, cohort_size=2, batch_seed=3)
    lmaxes = [s.lmax for s in cds.shards]
    assert lmaxes == [400, 3, 4, 2]                # per-shard, not global
    global_cells = len(sizes) * max(sizes)
    shard_cells = sum(s.idx.shape[0] * s.idx.shape[1] for s in cds.shards)
    assert shard_cells < 0.3 * global_cells
    # staged blocks pad to the LARGEST shard only (one compiled shape)
    assert cds.pad_len == 400
    blk = cds.stage(3)
    assert blk["client_idx"].shape == (cds.pad_clients, cds.pad_len)


def test_cohorted_conversion_preserves_membership():
    sizes = [50, 1, 9, 30, 2, 60]
    x, y, parts = _skewed(sizes)
    ds = make_federated_dataset(x, y, parts, batch_seed=3)
    cds = ds.cohorted(4)
    assert cds.num_clients == 6 and len(cds.shards) == 2
    offs = np.concatenate([[0], np.cumsum(sizes)])
    for cid in range(6):
        j, loc = int(cds.cohort_of[cid]), int(cds.local_of[cid])
        shard = cds.shards[j]
        local = np.asarray(shard.idx[loc][:shard.lens[loc]])
        rows = np.asarray(shard.ex_idx)[local]      # local → global rows
        assert set(rows.tolist()) == set(range(offs[cid], offs[cid + 1]))


# ---------------------------------------------------------------------------
# the equivalence property: cohort-partitioned gather == whole-population
# gather at a fixed seed (what makes cohort ≡ scan trajectories possible)
# ---------------------------------------------------------------------------

def _assert_gather_equivalence(sizes, cohort_size, picked, round_idx,
                               steps=2, batch=3, batch_seed=11):
    x, y, parts = _skewed(sizes)
    ds = make_federated_dataset(x, y, parts, batch_seed=batch_seed)
    cds = make_cohorted_dataset(x, y, parts, cohort_size=cohort_size,
                                batch_seed=batch_seed)
    picked_dev = jnp.asarray(picked, jnp.int32)
    ref_x, ref_y = ds.gather_batches(jnp.int32(round_idx), picked_dev,
                                     steps=steps, batch=batch)
    for k, cid in enumerate(picked):
        j = int(cds.cohort_of[cid])
        bx, by = cohort_gather(
            cds.stage(j), jnp.int32(round_idx),
            jnp.asarray([cid], jnp.int32),
            jnp.asarray([cds.local_of[cid]], jnp.int32),
            steps=steps, batch=batch, batch_seed=batch_seed)
        np.testing.assert_array_equal(np.asarray(bx[0]),
                                      np.asarray(ref_x[k]))
        np.testing.assert_array_equal(np.asarray(by[0]),
                                      np.asarray(ref_y[k]))


def test_cohort_gather_equals_population_gather_fixed_cases():
    _assert_gather_equivalence([7, 1, 30, 2, 5, 12], 2, [0, 3, 5], 4)
    _assert_gather_equivalence([1, 1, 1, 900], 3, [0, 1, 2, 3], 0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def test_cohort_gather_equivalence_property(data):
        sizes = data.draw(st.lists(st.integers(1, 40), min_size=2,
                                   max_size=10), label="sizes")
        C = len(sizes)
        cohort_size = data.draw(st.integers(1, C), label="cohort_size")
        k = data.draw(st.integers(1, C), label="k")
        picked = data.draw(
            st.lists(st.integers(0, C - 1), min_size=k, max_size=k,
                     unique=True), label="picked")
        round_idx = data.draw(st.integers(0, 5), label="round")
        _assert_gather_equivalence(sizes, cohort_size, picked, round_idx)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_cohort_gather_equivalence_property():
        pass
