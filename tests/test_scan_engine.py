"""Scan experiment engine (ISSUE 2): three-engine trajectory parity,
seed-stable client schedules, edge cases, and dispatch/transfer counts."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_eval_program
from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import FLConfig, make_client_schedule, run_federated
from repro.fed.engine import make_experiment_program
from repro.models.cnn import (mlp_accuracy, mlp_apply, mlp_eval_program,
                              mlp_init, mlp_loss)

KEY = jax.random.key(0)


def _setup(algorithm, rounds=4, **cfg_kw):
    task = make_image_task(0, n=800, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=8, clients_per_round=4,
                   rounds=rounds, local_steps=4, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7)
    eval_prog = mlp_eval_program(jnp.asarray(task.x), jnp.asarray(task.y))
    return mlp_loss, params, ds, eval_prog, cfg, task


def _run(engine, loss_fn, params, ds, eval_prog, cfg, **kw):
    return run_federated(loss_fn, params, ds, None, cfg,
                         eval_program=eval_prog, engine=engine, **kw)


# ---------------------------------------------------------------------------
# the acceptance criterion: scan ≡ batched ≡ looped at fixed seed,
# for every algorithm family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", [
    "fedmrn", "fedmrns", "fedavg", "signsgd", "fedpm", "fedsparsify"])
def test_three_engine_trajectory_parity(algorithm):
    loss_fn, params, ds, eval_prog, cfg, _ = _setup(algorithm)
    hs = _run("scan", loss_fn, params, ds, eval_prog, cfg, chunk=2)
    hb = _run("batched", loss_fn, params, ds, eval_prog, cfg)
    hl = _run("looped", loss_fn, params, ds, eval_prog, cfg)
    # satellite: the seed-stable (R, K) schedule is shared by all engines
    np.testing.assert_array_equal(hs["schedule"], hb["schedule"])
    np.testing.assert_array_equal(hs["schedule"], hl["schedule"])
    for other in (hb, hl):
        np.testing.assert_allclose(hs["acc"], other["acc"], atol=1e-6)
        np.testing.assert_allclose(hs["local_loss"], other["local_loss"],
                                   atol=1e-5)
        assert hs["round"] == other["round"]
        assert (hs["uplink_bits_per_client"]
                == other["uplink_bits_per_client"])


def test_scan_error_feedback_parity():
    """Cross-round EF residual state flows through the scan carry exactly
    as through the batched engine's per-round state."""
    loss_fn, params, ds, eval_prog, cfg, _ = _setup(
        "fedmrn", rounds=5, error_feedback=True)
    hs = _run("scan", loss_fn, params, ds, eval_prog, cfg, chunk=2)
    hb = _run("batched", loss_fn, params, ds, eval_prog, cfg)
    np.testing.assert_allclose(hs["acc"], hb["acc"], atol=1e-6)
    np.testing.assert_allclose(hs["local_loss"], hb["local_loss"],
                               atol=1e-5)


# ---------------------------------------------------------------------------
# client-selection schedule (satellite)
# ---------------------------------------------------------------------------

def test_schedule_matches_legacy_rng_sequence():
    """The precomputed (R, K) schedule reproduces the legacy per-round
    interleaved ``rng.choice`` draws exactly."""
    cfg = FLConfig(num_clients=10, clients_per_round=4, rounds=7, seed=3)
    sched = make_client_schedule(cfg)
    assert sched.shape == (7, 4) and sched.dtype == np.int32
    rng = np.random.RandomState(3)
    for r in range(cfg.rounds):
        np.testing.assert_array_equal(
            sched[r], rng.choice(10, 4, replace=False))
    # seed-stability
    np.testing.assert_array_equal(sched, make_client_schedule(cfg))
    # rows are valid selections without replacement
    assert all(len(np.unique(row)) == 4 for row in sched)


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_scan_partial_trailing_chunk():
    """rounds % chunk != 0: trailing chunk is smaller, trajectory unchanged."""
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=5)
    h3 = _run("scan", loss_fn, params, ds, eval_prog, cfg, chunk=3)
    h1 = _run("scan", loss_fn, params, ds, eval_prog, cfg, chunk=None)
    assert h3["num_dispatches"] == 2          # 3 + 2
    assert h1["num_dispatches"] == 1
    np.testing.assert_allclose(h3["acc"], h1["acc"], atol=1e-7)
    np.testing.assert_allclose(h3["local_loss"], h1["local_loss"],
                               atol=1e-7)


def test_scan_eval_every_exceeds_rounds():
    """eval_every > rounds: only round 0 and the final round evaluate."""
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=3)
    hs = _run("scan", loss_fn, params, ds, eval_prog, cfg, eval_every=10)
    hb = _run("batched", loss_fn, params, ds, eval_prog, cfg, eval_every=10)
    assert hs["round"] == [0, 2] == hb["round"]
    np.testing.assert_allclose(hs["acc"], hb["acc"], atol=1e-6)
    assert np.isfinite(hs["final_acc"])


def test_scan_full_participation():
    """clients_per_round == num_clients: schedule rows are permutations."""
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=3)
    cfg = dataclasses.replace(cfg, clients_per_round=cfg.num_clients)
    hs = _run("scan", loss_fn, params, ds, eval_prog, cfg, chunk=2)
    hb = _run("batched", loss_fn, params, ds, eval_prog, cfg)
    assert all(len(np.unique(r)) == cfg.num_clients
               for r in hs["schedule"])
    np.testing.assert_allclose(hs["acc"], hb["acc"], atol=1e-6)


def test_scan_rejects_host_callback_data():
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=2)
    with pytest.raises(ValueError, match="FederatedDataset"):
        run_federated(loss_fn, params, lambda r, c: None, None, cfg,
                      eval_program=eval_prog, engine="scan")


def test_scan_requires_eval_program():
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=2)
    with pytest.raises(ValueError, match="eval_program"):
        run_federated(loss_fn, params, ds, lambda p: 0.0, cfg,
                      engine="scan")


# ---------------------------------------------------------------------------
# zero host transfers inside a chunk (acceptance)
# ---------------------------------------------------------------------------

def test_chunk_is_one_program_no_host_transfers():
    """A chunk is ONE jitted dispatch: the loss_fn traces a constant number
    of times regardless of R, the driver dispatches ⌈R/chunk⌉ programs, and
    no device→host transfer happens while chunks execute."""
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=6)
    traces = []

    def counting_loss(p, b):
        traces.append(1)
        return loss_fn(p, b)

    run_chunk, state, metrics = make_experiment_program(
        counting_loss, cfg, params, ds, eval_program=eval_prog,
        eval_every=2)
    schedule = jnp.asarray(make_client_schedule(cfg), jnp.int32)
    w = params
    with jax.transfer_guard_device_to_host("disallow"):
        for r0 in range(0, cfg.rounds, 3):
            w, state, metrics = run_chunk(
                w, state, metrics, jnp.int32(r0), schedule[r0:r0 + 3],
                n_rounds=3)
        jax.block_until_ready(metrics)
    # one trace per compiled chunk shape (fwd+bwd), NOT one per round
    assert len(traces) <= 4, f"loss_fn traced {len(traces)} times"
    acc = np.asarray(metrics["acc"])
    assert np.isfinite(acc[[0, 2, 4, 5]]).all()   # eval_every=2 + final
    assert np.isnan(acc[[1, 3]]).all()            # non-eval rounds stay NaN
    loss = np.asarray(metrics["loss"])
    assert np.isfinite(loss).all()
    bits = np.asarray(metrics["uplink_bits"])
    assert (bits > 0).all()


def test_history_num_dispatches_counts_chunks():
    loss_fn, params, ds, eval_prog, cfg, _ = _setup("fedmrn", rounds=7)
    hs = _run("scan", loss_fn, params, ds, eval_prog, cfg, chunk=3)
    assert hs["num_dispatches"] == math.ceil(7 / 3)


# ---------------------------------------------------------------------------
# the data + eval layers in isolation
# ---------------------------------------------------------------------------

def test_gather_matches_host_batch_fn():
    """In-program (vmapped, traced round) gather == host adapter batches."""
    _, _, ds, _, cfg, _ = _setup("fedmrn")
    batch_fn = ds.batch_fn(steps=3, batch=5)
    picked = jnp.asarray([1, 4, 6], jnp.int32)
    gathered = jax.jit(lambda r, p: ds.gather_batches(
        r, p, steps=3, batch=5))(jnp.int32(2), picked)
    for k, cid in enumerate([1, 4, 6]):
        xh, yh = batch_fn(2, cid)
        np.testing.assert_array_equal(np.asarray(gathered[0][k]),
                                      np.asarray(xh))
        np.testing.assert_array_equal(np.asarray(gathered[1][k]),
                                      np.asarray(yh))


def test_gather_respects_partition_membership():
    """Sampled examples always come from the picked client's partition."""
    task = make_image_task(1, n=300, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("noniid2", 1, task.y, 6, labels_per_client=2)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=5)
    for cid in range(6):
        xb, yb = ds.client_batch(jnp.int32(0), jnp.int32(cid),
                                 steps=4, batch=8)
        labels = np.unique(np.asarray(yb))
        allowed = np.unique(task.y[parts[cid]])
        assert set(labels) <= set(allowed)


def test_eval_program_matches_full_batch_accuracy():
    """Batched eval (with a wrap-padded remainder) == full-batch accuracy."""
    task = make_image_task(0, n=700, hw=8, n_classes=4, noise=0.5)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    x, y = jnp.asarray(task.x), jnp.asarray(task.y)
    full = float(mlp_accuracy(params, x, y))
    for bs in (64, 256, 700, 1000):   # 700 % 64 != 0 exercises the padding
        prog = make_eval_program(mlp_apply, x, y, batch_size=bs)
        assert float(jax.jit(prog)(params)) == pytest.approx(full, abs=1e-7)
