"""Golden-file regression of the RunResult/history contract (ISSUE 4):
``to_history()`` must keep ONE key schema — keys AND value types — across
every engine, including results coming out of sharded sweeps.  Schema
drift (a key added/removed/retyped anywhere) fails against the committed
``tests/golden/history_schema.json`` instead of silently forking the
engines' output formats again.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import (Experiment, ExperimentSpec, FLConfig, HISTORY_KEYS,
                       RunResult)
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "history_schema.json")

with open(GOLDEN_PATH) as f:
    GOLDEN = json.load(f)["keys"]


def _describe(value):
    """The golden type descriptor of one history value."""
    if isinstance(value, bool):          # bool is an int subclass — reject
        return "bool"
    if isinstance(value, str):
        return "str"
    if isinstance(value, (int, np.integer)):
        return "int"
    if isinstance(value, (float, np.floating)):
        return "float"
    if isinstance(value, np.ndarray):
        return f"ndarray[{value.dtype}]"
    if isinstance(value, (list, tuple)):
        inner = sorted({_describe(v) for v in value}) or ["empty"]
        return f"list[{','.join(inner)}]"
    return type(value).__name__


def _schema_of(hist):
    return {k: _describe(v) for k, v in hist.items()}


@pytest.fixture(scope="module")
def experiment():
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(jax.random.key(0), d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm="fedmrn", num_clients=8, clients_per_round=4,
                   rounds=2, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    return Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                     data=ds, config=cfg,
                                     eval_apply=mlp_apply))


def test_golden_file_matches_history_keys_constant():
    """The committed golden keys and the in-code schema constant agree —
    whichever one drifts first, this fires."""
    assert set(GOLDEN) == set(HISTORY_KEYS)


@pytest.mark.parametrize("engine", ["scan", "batched", "looped", "service"])
def test_engine_history_matches_golden_schema(experiment, engine):
    hist = experiment.run(engine=engine).to_history()
    assert _schema_of(hist) == GOLDEN, (
        f"engine={engine!r} drifted from tests/golden/history_schema.json "
        "— if the change is deliberate, update the golden file AND "
        "repro.fed.api.HISTORY_KEYS together")


def test_uplink_bits_round_is_measured_and_equal_across_engines(experiment):
    """Satellite (ISSUE 5): every engine reports the MEASURED per-round
    wire bits — K × the codec's encoded WireMsg size, identical across
    scan/batched/looped (the looped engine used to emit a precomputed
    ``[K * estimate] * R`` constant list)."""
    codec = experiment.codec()
    per_client = codec.wire_bits(experiment.spec.params).uplink_bits
    K, R = experiment.cfg.clients_per_round, experiment.cfg.rounds
    expected = [float(K * per_client)] * R
    for engine in ("scan", "batched", "looped"):
        hist = experiment.run(engine=engine).to_history()
        assert hist["uplink_bits_round"] == expected, engine
        assert hist["uplink_bits_per_client"] == per_client, engine


@pytest.mark.parametrize("sweep_kw", [
    dict(),                                    # vmapped
    dict(sharding="devices"),                  # shard_map over the seed mesh
])
def test_sweep_run_results_match_golden_schema(experiment, sweep_kw):
    """Sweep-produced RunResults — vmapped and device-sharded — emit the
    same golden history schema as single runs."""
    sweep = experiment.sweep(seeds=2, **sweep_kw)
    for run in sweep.runs:
        hist = run.to_history()
        assert _schema_of(hist) == GOLDEN
        # and the dict round-trips through the typed result unchanged
        back = RunResult.from_history(run.config, run.engine, hist)
        assert back.acc == run.acc
        assert _schema_of(back.to_history()) == GOLDEN
