"""Tests for the post-training compressor zoo and the FedMRN protocol."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedMRNConfig, NoiseConfig, client_local_update, make_compressor,
    server_aggregate, server_aggregate_updates, sgd_local_update,
    baseline_record, fedmrn_record,
)
from repro.core.compressors import REGISTRY, fwht, next_pow2

KEY = jax.random.key(0)


def _mktree(key, scale=0.01):
    ka, kb = jax.random.split(key)
    return {"w": scale * jax.random.normal(ka, (37, 11)),
            "b": scale * jax.random.normal(kb, (19,))}


class TestCompressors:
    @pytest.mark.parametrize("name", REGISTRY)
    def test_roundtrip_shapes_finite(self, name):
        u = _mktree(KEY)
        comp = make_compressor(name)
        out = comp(u, KEY)
        jax.tree_util.tree_map(
            lambda a, b: (np.testing.assert_array_equal(a.shape, b.shape),
                          np.isfinite(np.asarray(b)).all()), u, out)

    @pytest.mark.parametrize("name", ["stochsign", "terngrad", "qsgd"])
    def test_unbiased_compressors(self, name):
        """Stochastic quantizers are unbiased: mean over samples → u."""
        u = {"w": jnp.full((20_000,), 0.003)}
        comp = make_compressor(name)
        acc = np.zeros((20_000,))
        R = 30
        for i in range(R):
            acc += np.asarray(comp(u, jax.random.key(i))["w"])
        np.testing.assert_allclose(acc.mean() / R, 0.003, rtol=0.1)

    def test_topk_sparsity(self):
        u = {"w": jax.random.normal(KEY, (1000,))}
        comp = make_compressor("topk", topk_frac=0.03)
        out = np.asarray(comp(u, KEY)["w"])
        assert (out != 0).sum() <= 31  # ceil(30) + ties

    def test_fwht_involution(self):
        x = jax.random.normal(KEY, (256,))
        np.testing.assert_allclose(np.asarray(fwht(fwht(x))), np.asarray(x),
                                   atol=1e-5)

    def test_next_pow2(self):
        assert [next_pow2(i) for i in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]

    def test_drive_eden_better_than_sign(self):
        """Rotation-based 1-bit beats naive sign on L2 error (paper §2.3)."""
        k1, k2 = jax.random.split(KEY)
        u = {"w": jax.random.normal(k1, (4096,)) *
                  jnp.abs(jax.random.normal(k2, (4096,)))}  # heavy-tailed

        def err(name):
            out = make_compressor(name)(u, KEY)
            return float(jnp.sum((out["w"] - u["w"]) ** 2))

        assert err("drive") < err("signsgd")

    def test_wire_bits_accounting(self):
        rec = fedmrn_record(10_000)
        assert rec.uplink_bpp < 1.01 and rec.compression_x > 31
        fa = baseline_record("fedavg", 10_000, 2)
        assert fa.uplink_bpp == 32
        tk = baseline_record("topk", 10_000, 2)
        assert tk.uplink_bits > tk.uplink_bits_paper  # index overhead counted


# ---------------------------------------------------------------------------
# FedMRN protocol end-to-end on a toy quadratic objective
# ---------------------------------------------------------------------------

def quad_loss(params, batch):
    """|| (w - target) ||^2 with per-batch jitter, smooth and convex."""
    tgt, _ = batch
    d = jax.tree_util.tree_map(lambda p, t: p - t, params, tgt)
    return sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(d))


def _batches(target, S=8):
    # identical targets at every step; shaped (S, ...) for scan
    return (jax.tree_util.tree_map(
                lambda t: jnp.broadcast_to(t, (S,) + t.shape), target),
            jnp.zeros((S, 1)))


class TestFedMRNProtocol:
    @pytest.mark.parametrize("mode", ["binary", "signed"])
    def test_local_training_reduces_loss(self, mode):
        w = {"w": jnp.zeros((64,))}
        target = {"w": jnp.full((64,), 0.05)}
        cfg = FedMRNConfig(mask_mode=mode,
                           noise=NoiseConfig(alpha=2e-2), lr=0.05)
        res = client_local_update(
            quad_loss, w, _batches(target, S=16), cfg=cfg, base_seed=0,
            round_idx=0, client_id=0, train_key=KEY)
        losses = np.asarray(res.losses)
        assert losses[-1] < losses[0]

    def test_server_aggregation_moves_toward_target(self):
        """A few FedMRN rounds on the quadratic shrink the global error."""
        w = {"w": jnp.zeros((128,))}
        target = {"w": jnp.full((128,), 0.03)}
        cfg = FedMRNConfig(noise=NoiseConfig(alpha=1e-2), lr=0.05)
        err0 = float(quad_loss(w, (target, None)))
        # per-round progress is bounded by the noise magnitude alpha (each
        # param moves at most alpha per round) — 8 rounds suffice here
        for rnd in range(8):
            results, weights = [], []
            for cid in range(3):
                res = client_local_update(
                    quad_loss, w, _batches(target, S=16), cfg=cfg,
                    base_seed=0, round_idx=rnd, client_id=cid,
                    train_key=jax.random.fold_in(KEY, rnd * 10 + cid))
                results.append(res)
                weights.append(1.0)
            w = server_aggregate(w, results, weights, cfg=cfg)
        err = float(quad_loss(w, (target, None)))
        assert err < 0.25 * err0

    def test_fedavg_baseline_path(self):
        w = {"w": jnp.zeros((32,))}
        target = {"w": jnp.full((32,), 0.05)}
        u, losses = sgd_local_update(quad_loss, w, _batches(target), lr=0.1)
        w2 = server_aggregate_updates(w, [u, u], [1.0, 1.0])
        assert float(quad_loss(w2, (target, None))) < float(
            quad_loss(w, (target, None)))

    def test_ablation_flags_run(self):
        w = {"w": jnp.zeros((16,))}
        target = {"w": jnp.full((16,), 0.02)}
        for use_sm, use_pm in [(True, False), (False, True), (False, False)]:
            cfg = FedMRNConfig(noise=NoiseConfig(alpha=1e-2), lr=0.05,
                               use_sm=use_sm, use_pm=use_pm)
            res = client_local_update(
                quad_loss, w, _batches(target), cfg=cfg, base_seed=0,
                round_idx=0, client_id=0, train_key=KEY)
            assert np.isfinite(np.asarray(res.losses)).all()

    def test_error_feedback_residual(self):
        w = {"w": jnp.zeros((16,))}
        target = {"w": jnp.full((16,), 0.02)}
        cfg = FedMRNConfig(noise=NoiseConfig(alpha=1e-2), lr=0.05,
                           error_feedback=True)
        res = client_local_update(
            quad_loss, w, _batches(target), cfg=cfg, base_seed=0,
            round_idx=0, client_id=0, train_key=KEY)
        assert np.abs(np.asarray(res.residual["w"])).sum() > 0
