"""Service-tier fault injection (ISSUE 9): dropped / delayed / corrupt /
crashed / hung clients against the loopback coordinator.  The acceptance
bar: every fault-injected run terminates with a completed model or a
raised error — NEVER silent success — and the ServiceReport's accounting
balances exactly (aggregated uplinks == Σ participation; posted + dropped
+ rejected reconcile against dispatch counts)."""
import jax
import numpy as np
import pytest

from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import (AvailabilityTrace, Experiment, ExperimentSpec,
                       FaultPlan, FLConfig, ServiceConfig)
from repro.fed.service.client import ServiceError
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

KEY = jax.random.key(0)
R, C, K = 3, 8, 4


def _experiment(algorithm="fedmrn", rounds=R, trace=None, **cfg_kw):
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, C)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=C, clients_per_round=K,
                   rounds=rounds, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    return Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                     data=ds, config=cfg,
                                     eval_apply=mlp_apply,
                                     availability=trace))


# ---------------------------------------------------------------------------
# FaultPlan + ServiceConfig validation
# ---------------------------------------------------------------------------

def test_fault_plan_validates_bounds():
    FaultPlan(drop_uplinks=((0, 0),)).validate(rounds=3, num_slots=4)
    with pytest.raises(ValueError):
        FaultPlan(drop_uplinks=((3, 0),)).validate(rounds=3, num_slots=4)
    with pytest.raises(ValueError):
        FaultPlan(crash_slots=((0, 4),)).validate(rounds=3, num_slots=4)
    with pytest.raises(ValueError):
        FaultPlan(delay_uplinks=((0, 0, 0),)).validate(rounds=3,
                                                       num_slots=4)


def test_service_config_rejects_bad_degradation_knobs():
    with pytest.raises(ValueError, match="quorum"):
        ServiceConfig(mode="async", quorum=2).validate()
    with pytest.raises(ValueError, match="quorum"):
        ServiceConfig(mode="sync", quorum=0).validate()
    with pytest.raises(ValueError, match="run_timeout_s"):
        ServiceConfig(mode="sync", run_timeout_s=0.0).validate()


def test_quorum_above_k_refused_at_run():
    e = _experiment()
    with pytest.raises(ValueError, match="quorum"):
        e.run(engine="service",
              service=ServiceConfig(mode="sync", quorum=K + 1))


# ---------------------------------------------------------------------------
# the hung-worker satellite: join(timeout=) is not completion
# ---------------------------------------------------------------------------

def test_hung_worker_is_an_error_not_silent_success():
    """Regression: the runner used to join(timeout=) each worker and
    carry on, reporting success while a seat was still alive (leaked
    thread, silently missing uplinks).  A hung seat must raise."""
    e = _experiment()
    sc = ServiceConfig(mode="sync", quorum=K - 1, run_timeout_s=60.0,
                       timeout_s=2.0,
                       faults=FaultPlan(hang_slots=((0, 2),),
                                        hang_sleep_s=20.0))
    with pytest.raises(ServiceError, match="still alive"):
        e.run(engine="service", service=sc)


def test_hung_worker_recorded_when_allowed():
    e = _experiment()
    sc = ServiceConfig(mode="sync", quorum=K - 1, run_timeout_s=60.0,
                       timeout_s=2.0, allow_hung_workers=True,
                       faults=FaultPlan(hang_slots=((0, 2),),
                                        hang_sleep_s=20.0))
    res = e.run(engine="service", service=sc)
    rep = e.service_report
    # the seat is still asleep at join time, so its per-seat stats dict
    # was never returned — the thread-level hung_workers counter is the
    # authoritative record of the leak
    assert rep.hung_workers == 1
    assert np.isfinite(res.final_acc)
    # the hung seat's round still closed on the quorum of survivors
    assert all(p >= K - 1 for p in rep.participation)


# ---------------------------------------------------------------------------
# drops, corruption, crashes: terminate or raise, account exactly
# ---------------------------------------------------------------------------

def test_dropped_uplink_with_quorum_balances_accounting():
    e = _experiment()
    sc = ServiceConfig(mode="sync", quorum=K - 1, run_timeout_s=60.0,
                       faults=FaultPlan(drop_uplinks=((0, 0), (2, 3))))
    res = e.run(engine="service", service=sc)
    rep = e.service_report
    assert rep.client_faults["dropped"] == 2
    assert rep.n_uplinks == sum(rep.participation)
    assert tuple(rep.expected) == (K,) * R
    # posted messages either aggregated or were rejected with a status
    assert rep.client_faults["posted"] >= sum(rep.participation)
    assert (rep.client_faults["posted"] - sum(rep.participation)
            <= sum(rep.rejected.values()))
    assert np.isfinite(res.final_acc)


def test_dropped_uplink_without_quorum_times_out_loudly():
    """A sync barrier missing one uplink can never close its round: the
    bounded run must raise, not hang forever or return a partial model
    as if it were complete."""
    e = _experiment()
    sc = ServiceConfig(mode="sync", run_timeout_s=4.0, timeout_s=2.0,
                       faults=FaultPlan(drop_uplinks=((1, 0),)))
    with pytest.raises(ServiceError, match="timed out"):
        e.run(engine="service", service=sc)


def test_corrupt_frame_gets_400_and_never_crashes_the_coordinator():
    e = _experiment()
    sc = ServiceConfig(mode="sync", quorum=K - 1, run_timeout_s=60.0,
                       faults=FaultPlan(corrupt_uplinks=((0, 1), (2, 2))))
    res = e.run(engine="service", service=sc)
    rep = e.service_report
    assert rep.client_faults["corrupted"] == 2
    assert rep.rejected["bad_frame"] == 2
    assert rep.n_uplinks == sum(rep.participation)
    assert np.isfinite(res.final_acc)


def test_mid_round_crash_with_quorum_still_completes():
    e = _experiment()
    sc = ServiceConfig(mode="sync", quorum=K - 1, run_timeout_s=60.0,
                       faults=FaultPlan(crash_slots=((1, 3),)))
    res = e.run(engine="service", service=sc)
    rep = e.service_report
    assert rep.client_faults["crashed"] == 1
    # the crashed seat contributed nothing from round 1 on
    assert all(p >= K - 1 for p in rep.participation)
    assert rep.n_uplinks == sum(rep.participation)
    assert np.isfinite(res.final_acc)


def test_delayed_uplink_in_async_mode_lands_stale():
    e = _experiment()
    sc = ServiceConfig(mode="async", staleness_beta=0.5, min_fresh=K - 1,
                       run_timeout_s=60.0,
                       faults=FaultPlan(delay_uplinks=((0, 2, 1),)))
    res = e.run(engine="service", service=sc)
    rep = e.service_report
    assert rep.client_faults["delayed"] == 1
    entries = [s for row in rep.staleness for s in row]
    assert any(s["lag"] > 0 for s in entries)
    assert all(s["scale"] == 0.5 ** s["lag"] for s in entries)
    assert np.isfinite(res.final_acc)


# ---------------------------------------------------------------------------
# availability over the wire: service == scan under the same trace
# ---------------------------------------------------------------------------

def test_service_availability_parity_with_scan():
    kw = dict(availability="bernoulli", dropout=0.4)
    rs = _experiment(**kw).run(engine="scan")
    ev = _experiment(**kw)
    rv = ev.run(engine="service")
    np.testing.assert_allclose(np.asarray(rv.acc), np.asarray(rs.acc),
                               atol=1e-6)
    rep = ev.service_report
    assert rv.participation_round == rs.participation_round
    assert tuple(rep.participation) == rs.participation_round
    assert tuple(rep.expected) == rs.participation_round
    assert rep.client_faults["skipped"] == R * K - sum(rep.participation)
    assert rep.n_uplinks == sum(rep.participation)


def test_service_heterogeneous_local_steps():
    ls = AvailabilityTrace.heterogeneous_steps(0, C, choices=(1, 2, 4))
    tr = AvailabilityTrace.always(R, C, local_steps=ls)
    e = _experiment(trace=tr)
    res = e.run(engine="service")
    rep = e.service_report
    assert rep.n_uplinks == R * K == sum(rep.participation)
    assert np.isfinite(res.final_acc)


def test_history_schema_includes_participation_for_service():
    e = _experiment(availability="bernoulli", dropout=0.4)
    res = e.run(engine="service")
    hist = res.to_history()
    assert hist["participation_round"] == list(res.participation_round)
    assert min(res.participation_round) < K
