"""Unit tests: HLO analyzer, sharding rules, roofline math (no big mesh)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs, shape_applicable
from repro.sharding import hlo_analysis
from repro.sharding.roofline import active_params, model_flops

HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%c0, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  %ag = f32[8,16]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={1}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


class TestHLOAnalysis:
    def test_while_trip_multiplied(self):
        cost = hlo_analysis.analyze(HLO_SAMPLE)
        # dot: 2*8*8*8 = 1024 flops × 5 trips
        assert cost.flops == 1024 * 5

    def test_collective_ring_bytes(self):
        cost = hlo_analysis.analyze(HLO_SAMPLE)
        # all-gather out 8*16*4 bytes × (g-1)/g with g=2
        assert cost.by_collective["all-gather"] == pytest.approx(
            8 * 16 * 4 * 0.5)

    def test_shape_bytes(self):
        assert hlo_analysis.shape_bytes("f32[2,3]{1,0}") == 24
        assert hlo_analysis.shape_bytes("(s32[], bf16[4,4]{1,0})") == 4 + 32
        assert hlo_analysis.shape_bytes("pred[7]") == 7

    def test_known_trip_count_attr(self):
        txt = HLO_SAMPLE.replace(
            "body=%body",
            'body=%body, backend_config={"known_trip_count":{"n":"7"}}')
        cost = hlo_analysis.analyze(txt)
        assert cost.flops == 1024 * 7


class TestRooflineMath:
    def test_active_params_moe(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        from repro.models.registry import count_params
        total = count_params(cfg)
        act = active_params(cfg, total)
        assert act < total * 0.15          # a22b of 235b ≈ 9%
        assert act > total * 0.05

    def test_model_flops_kinds(self):
        cfg = get_config("llama3.2-1b")
        from repro.models.registry import count_params
        total = count_params(cfg)
        tr = model_flops(cfg, INPUT_SHAPES["train_4k"], total)
        pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"], total)
        dc = model_flops(cfg, INPUT_SHAPES["decode_32k"], total)
        assert tr == pytest.approx(6 * total * 256 * 4096)
        assert pf == pytest.approx(2 * total * 32 * 32768)
        assert dc == pytest.approx(2 * total * 128)

    def test_skip_matrix(self):
        """Exactly the 3 sub-quadratic archs run long_500k."""
        runs = [a for a in list_archs()
                if shape_applicable(get_config(a),
                                    INPUT_SHAPES["long_500k"])[0]]
        assert sorted(runs) == ["h2o-danube-1.8b", "rwkv6-3b", "zamba2-1.2b"]


class TestShardingRules:
    def test_param_specs_divisible(self):
        """Every sharded dim divides the mesh axis for every arch."""
        from repro.models.registry import param_specs
        from repro.sharding.rules import param_shardings
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        for arch in list_archs():
            cfg = get_config(arch)
            specs = param_specs(cfg)
            shard = param_shardings(specs, mesh, num_layers=cfg.num_layers,
                                    encoder_layers=cfg.encoder_layers,
                                    zero=True)
            # NamedSharding construction already validates mesh axes; check
            # leaf count parity
            assert len(jax.tree_util.tree_leaves(shard)) == len(
                jax.tree_util.tree_leaves(specs))
