"""Integration tests: federated engine, partitioners, optimizers, ckpt."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data import (make_image_task, make_partition, sample_local_batches)
from repro.fed import FLConfig, run_federated
from repro.models.cnn import mlp_accuracy, mlp_init, mlp_loss
from repro.optim import adamw, cosine_schedule, sgd

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# partitioners (paper §5.1.2)
# ---------------------------------------------------------------------------

class TestPartitioners:
    def setup_method(self):
        self.task = make_image_task(0, n=800, n_classes=8)

    @pytest.mark.parametrize("kind", ["iid", "noniid1", "noniid2"])
    def test_partition_covers_all(self, kind):
        parts = make_partition(kind, 0, self.task.y, 10)
        allidx = np.concatenate(parts)
        assert len(parts) == 10
        assert all(len(p) > 0 for p in parts)
        assert len(np.unique(allidx)) == len(allidx)  # disjoint

    def test_noniid2_label_restriction(self):
        parts = make_partition("noniid2", 0, self.task.y, 10,
                               labels_per_client=3)
        for p in parts:
            assert len(np.unique(self.task.y[p])) <= 3

    def test_noniid1_skew(self):
        """Dirichlet(0.1) must be more skewed than IID."""
        parts = make_partition("noniid1", 0, self.task.y, 10, alpha=0.1)
        sizes = np.array([len(p) for p in parts])
        assert sizes.std() > 5  # IID split would have std ~0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

class TestOptim:
    def _problem(self):
        w = {"x": jnp.array([5.0, -3.0])}
        grad_fn = jax.grad(lambda p: jnp.sum(p["x"] ** 2))
        return w, grad_fn

    @pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9),
                                     adamw(0.3)])
    def test_converges_on_quadratic(self, opt):
        w, grad_fn = self._problem()
        state = opt.init(w)
        for i in range(100):
            w, state = opt.update(w, grad_fn(w), state, jnp.int32(i))
        assert float(jnp.abs(w["x"]).max()) < 0.1

    def test_cosine_schedule(self):
        fn = cosine_schedule(1.0, 100, warmup=10)
        assert float(fn(jnp.int32(0))) == 0.0
        assert float(fn(jnp.int32(10))) == pytest.approx(1.0)
        assert float(fn(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.arange(3, dtype=jnp.int32)}}
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, tree)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = checkpoint.restore(path, like)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)),
        tree, out)


# ---------------------------------------------------------------------------
# end-to-end FL rounds on a small MLP/synthetic task
# ---------------------------------------------------------------------------

def _setup_fl(algorithm, rounds=6, alpha=3e-2):
    task = make_image_task(0, n=1200, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=8, clients_per_round=4,
                   rounds=rounds, local_steps=8, batch_size=32, lr=0.1,
                   noise_alpha=alpha)

    def batch_fn(rnd, cid):
        return sample_local_batches(rnd * 100 + cid, task.x, task.y,
                                    parts[cid], steps=cfg.local_steps,
                                    batch=cfg.batch_size)

    def eval_fn(p):
        return float(mlp_accuracy(p, jnp.asarray(task.x),
                                  jnp.asarray(task.y)))

    return mlp_loss, params, batch_fn, eval_fn, cfg


@pytest.mark.parametrize("algorithm", [
    "fedavg", "fedmrn", "fedmrns", "signsgd", "terngrad", "topk",
    "drive", "eden", "fedpm", "fedsparsify", "stochsign", "post_sm"])
def test_algorithms_improve_over_init(algorithm):
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl(algorithm)
    acc0 = eval_fn(params)
    hist = run_federated(loss_fn, params, batch_fn, eval_fn, cfg)
    assert np.isfinite(hist["final_acc"])
    # every algorithm must beat random-ish init on this easy task;
    # the model-compression baselines (fedpm/fedsparsify) are allowed to be
    # weak (that's the paper's point) but must still run and not regress
    # catastrophically below chance.
    floor = 0.3 if algorithm in ("fedpm", "fedsparsify") else max(
        acc0, 0.4)
    assert hist["final_acc"] >= floor, (
        f"{algorithm}: {hist['final_acc']:.3f} < {floor}")


def test_uplink_accounting_fedmrn_32x():
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl("fedmrn", rounds=2)
    hist = run_federated(loss_fn, params, batch_fn, eval_fn, cfg)
    bits = hist["uplink_bits_per_client"]
    assert bits / hist["params"] < 1.1          # ≈1 bpp
    cfg_avg = FLConfig(**{**cfg.__dict__, "algorithm": "fedavg"})
    hist_avg = run_federated(loss_fn, params, batch_fn, eval_fn, cfg_avg)
    assert hist_avg["uplink_bits_per_client"] / bits > 29  # ≈32x


def test_shared_noise_fedmrn_matches_per_client():
    """Beyond-paper shared-noise FedMRN converges like per-client noise."""
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl("fedmrn", rounds=6)
    import dataclasses
    hist_per = run_federated(loss_fn, params, batch_fn, eval_fn, cfg)
    cfg_shared = dataclasses.replace(cfg, shared_noise=True)
    hist_sh = run_federated(loss_fn, params, batch_fn, eval_fn, cfg_shared)
    assert hist_sh["final_acc"] > 0.8 * hist_per["final_acc"]
