"""Experiment API (ISSUE 3): algorithm plugin registry round-trips, typed
RunResult + unified history schema, vmapped multi-seed sweeps, and the
deprecated run_federated shim."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sgd_local_update, tree_num_params
from repro.core.comm import CommRecord
from repro.data import (make_federated_dataset, make_image_task,
                        make_partition, sample_local_batches)
from repro.fed import (ALGORITHMS, Algorithm, DenseCodec, Experiment,
                       ExperimentSpec, FLConfig, HISTORY_KEYS,
                       get_algorithm, list_algorithms, register_algorithm,
                       run_federated, template_of)
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

KEY = jax.random.key(0)

# the engine-independent history contract (golden copy — update BOTH this
# and repro.fed.api.HISTORY_KEYS deliberately when the schema changes)
GOLDEN_HISTORY_KEYS = {
    "algorithm", "engine", "acc", "round", "local_loss",
    "uplink_bits_per_client", "uplink_bits_round", "params", "schedule",
    "num_dispatches", "wall_s", "final_acc", "participation_round",
    "dp_epsilon", "dp_delta",
}


def _setup(algorithm="fedmrn", rounds=3, **cfg_kw):
    task = make_image_task(0, n=600, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=8, clients_per_round=4,
                   rounds=rounds, local_steps=3, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts,
                                x_test=task.x[:200], y_test=task.y[:200],
                                batch_seed=7)
    return mlp_loss, params, ds, cfg


def _experiment(algorithm="fedmrn", rounds=3, **cfg_kw):
    loss_fn, params, ds, cfg = _setup(algorithm, rounds, **cfg_kw)
    return Experiment(ExperimentSpec(
        loss_fn=loss_fn, params=params, data=ds, config=cfg,
        eval_apply=mlp_apply))           # eval auto-wired from test split


# ---------------------------------------------------------------------------
# the plugin registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_families():
    names = list_algorithms()
    for expected in ("fedmrn", "fedmrns", "fedavg", "fedpm", "fedsparsify",
                     "signsgd", "topk", "qsgd", "eden"):
        assert expected in names
    assert "none" not in names            # the identity compressor is not
    assert get_algorithm("fedmrn").name == "fedmrn"   # an FL algorithm


def test_unknown_algorithm_raises_with_listing():
    with pytest.raises(ValueError, match="registered"):
        get_algorithm("nope")
    loss_fn, params, ds, cfg = _setup()
    with pytest.raises(ValueError, match="registered"):
        Experiment(ExperimentSpec(
            loss_fn=loss_fn, params=params, data=ds,
            config=dataclasses.replace(cfg, algorithm="nope")))


def _toy_algorithm(name="toy_halfsgd"):
    """Third-party style plugin: FedAvg with a half-strength server step,
    built WITHOUT touching engine internals.  Its codec is a DenseCodec
    with a ``record`` override claiming a 16 bpp wire format (what the
    removed ``uplink_record`` field used to express)."""

    def make_body(loss_fn, cfg, params):
        def round_fn(seed, w, state, batches, picked, round_idx, weights):
            def per_client(b, cid):
                return sgd_local_update(loss_fn, w, b, lr=cfg.lr)

            updates, losses = jax.vmap(per_client)(batches, picked)
            wn = weights / jnp.sum(weights)
            agg = jax.tree_util.tree_map(
                lambda x: jnp.tensordot(wn, x, axes=1), updates)
            new_w = jax.tree_util.tree_map(lambda p, a: p + 0.5 * a, w, agg)
            return new_w, state, losses

        return round_fn

    def toy_codec(cfg, p):
        P = tree_num_params(p)
        return DenseCodec(template_of(p), name=name,
                          record=CommRecord(name, P, 16 * P, 16 * P,
                                            32 * P))

    return Algorithm(name=name, make_round_body=make_body, codec=toy_codec)


def test_custom_algorithm_registry_roundtrip():
    """Register a toy plugin, run it through the scan AND batched engines,
    and check the engines agree on its trajectory."""
    toy = register_algorithm(_toy_algorithm())
    try:
        loss_fn, params, ds, cfg = _setup()
        cfg = dataclasses.replace(cfg, algorithm="toy_halfsgd")
        assert "toy_halfsgd" in list_algorithms()
        exp = Experiment(ExperimentSpec(
            loss_fn=loss_fn, params=params, data=ds, config=cfg,
            eval_apply=mlp_apply))
        rs = exp.run(engine="scan")
        rb = exp.run(engine="batched")
        assert rs.algorithm == rb.algorithm == "toy_halfsgd"
        assert rs.uplink_bits_per_client == 16 * rs.num_params
        np.testing.assert_allclose(rs.acc, rb.acc, atol=1e-6)
        np.testing.assert_allclose(rs.local_loss, rb.local_loss, atol=1e-5)
        assert np.isfinite(rs.final_acc)
    finally:
        ALGORITHMS.pop("toy_halfsgd", None)


def test_register_duplicate_name_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm(_toy_algorithm(name="fedmrn"))


def test_spec_accepts_algorithm_instance():
    """An unregistered Algorithm instance auto-registers through the spec."""
    toy = _toy_algorithm(name="toy_spec_inline")
    try:
        loss_fn, params, ds, cfg = _setup()
        exp = Experiment(ExperimentSpec(
            loss_fn=loss_fn, params=params, data=ds, config=cfg,
            algorithm=toy, eval_apply=mlp_apply))
        assert exp.cfg.algorithm == "toy_spec_inline"
        assert "toy_spec_inline" in list_algorithms()
        assert np.isfinite(exp.run(engine="scan").final_acc)
    finally:
        ALGORITHMS.pop("toy_spec_inline", None)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overrides, match", [
    (dict(clients_per_round=9), "clients_per_round"),
    (dict(rounds=0), "rounds"),
    (dict(algorithm="topk", topk_frac=0.0), "topk_frac"),
    (dict(algorithm="qsgd", qsgd_bits=0), "qsgd_bits"),
    (dict(algorithm="fedmrn", noise_alpha=-1.0), "noise_alpha"),
])
def test_config_validation(overrides, match):
    loss_fn, params, ds, cfg = _setup()
    cfg = dataclasses.replace(cfg, **overrides)
    with pytest.raises(ValueError, match=match):
        Experiment(ExperimentSpec(loss_fn=loss_fn, params=params, data=ds,
                                  config=cfg, eval_apply=mlp_apply))


def test_eval_autowire_requires_test_split():
    loss_fn, params, ds, cfg = _setup()
    bare = dataclasses.replace(ds, x_test=None, y_test=None)
    exp = Experiment(ExperimentSpec(loss_fn=loss_fn, params=params,
                                    data=bare, config=cfg,
                                    eval_apply=mlp_apply))
    with pytest.raises(ValueError, match="test split"):
        exp.run()


def test_scan_requires_some_eval():
    loss_fn, params, ds, cfg = _setup()
    exp = Experiment(ExperimentSpec(loss_fn=loss_fn, params=params,
                                    data=ds, config=cfg))
    with pytest.raises(ValueError, match="eval_program"):
        exp.run(engine="scan")


def test_client_weights_length_validated():
    """A wrong-length weights vector must raise, not be clamped by the
    in-program gather (XLA clamps out-of-range indices silently)."""
    loss_fn, params, ds, cfg = _setup()
    with pytest.raises(ValueError, match="client_weights"):
        Experiment(ExperimentSpec(loss_fn=loss_fn, params=params, data=ds,
                                  config=cfg, eval_apply=mlp_apply,
                                  client_weights=(1.0, 2.0)))


def test_looped_engine_rejects_plugin_algorithms():
    register_algorithm(_toy_algorithm(name="toy_no_loop"))
    try:
        loss_fn, params, ds, cfg = _setup()
        exp = Experiment(ExperimentSpec(
            loss_fn=loss_fn, params=params, data=ds,
            config=dataclasses.replace(cfg, algorithm="toy_no_loop"),
            eval_apply=mlp_apply))
        with pytest.raises(ValueError, match="looped"):
            exp.run(engine="looped")
    finally:
        ALGORITHMS.pop("toy_no_loop", None)


def test_spec_rejects_host_callback_data():
    loss_fn, params, ds, cfg = _setup()
    with pytest.raises(ValueError, match="FederatedDataset"):
        Experiment(ExperimentSpec(loss_fn=loss_fn, params=params,
                                  data=lambda r, c: None, config=cfg))


# ---------------------------------------------------------------------------
# typed results: golden schema, identical across engines (satellite)
# ---------------------------------------------------------------------------

def test_history_schema_identical_across_engines():
    exp = _experiment()
    hists = {e: exp.run(engine=e).to_history()
             for e in ("scan", "batched", "looped")}
    for engine, hist in hists.items():
        assert set(hist) == GOLDEN_HISTORY_KEYS, engine
        assert hist["engine"] == engine
        # previously scan-only keys now exist (and are sane) everywhere
        assert len(hist["uplink_bits_round"]) == exp.cfg.rounds
        assert all(b > 0 for b in hist["uplink_bits_round"])
        assert hist["num_dispatches"] > 0
    assert HISTORY_KEYS == frozenset(GOLDEN_HISTORY_KEYS)


def test_legacy_host_callback_history_matches_schema():
    """The run_federated host-callback path records the same key set."""
    loss_fn, params, _, cfg = _setup()
    task = make_image_task(0, n=600, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)

    def batch_fn(rnd, cid):
        return sample_local_batches(rnd * 100 + cid, task.x, task.y,
                                    parts[cid], steps=cfg.local_steps,
                                    batch=cfg.batch_size)

    def eval_fn(p):
        return 0.5

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for engine in ("batched", "looped"):
            hist = run_federated(loss_fn, params, batch_fn, eval_fn, cfg,
                                 engine=engine)
            assert set(hist) == GOLDEN_HISTORY_KEYS, engine


def test_run_result_round_trips_and_is_frozen():
    exp = _experiment()
    res = exp.run()
    assert res.engine == "scan" and res.final_acc == res.acc[-1]
    assert res.total_uplink_bits == pytest.approx(
        sum(res.uplink_bits_round))
    with pytest.raises(dataclasses.FrozenInstanceError):
        res.engine = "other"
    hist = res.to_history()
    from repro.fed import RunResult
    back = RunResult.from_history(res.config, res.engine, hist)
    assert back.acc == res.acc and back.eval_rounds == res.eval_rounds
    assert back.num_dispatches == res.num_dispatches


# ---------------------------------------------------------------------------
# the deprecated shim (satellite)
# ---------------------------------------------------------------------------

def test_run_federated_shim_warns_and_matches_experiment():
    loss_fn, params, ds, cfg = _setup()
    exp = Experiment(ExperimentSpec(loss_fn=loss_fn, params=params,
                                    data=ds, config=cfg,
                                    eval_apply=mlp_apply))
    res = exp.run(engine="scan")
    eval_prog = exp.eval_program()
    with pytest.warns(DeprecationWarning, match="run_federated"):
        hist = run_federated(loss_fn, params, ds, None, cfg,
                             eval_program=eval_prog, engine="scan")
    np.testing.assert_allclose(hist["acc"], res.acc, atol=1e-6)
    np.testing.assert_allclose(hist["local_loss"], res.local_loss,
                               atol=1e-6)
    np.testing.assert_array_equal(hist["schedule"], res.schedule)
    assert set(hist) == GOLDEN_HISTORY_KEYS


# ---------------------------------------------------------------------------
# vmapped multi-seed sweeps (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_sweep_matches_independent_runs():
    """sweep(seeds=4) — ONE vmapped program — reproduces four independent
    single-seed runs to 1e-6, including cross-round EF state."""
    exp = _experiment(rounds=4, error_feedback=True)
    sweep = exp.sweep(seeds=4)
    assert sweep.vmapped and sweep.seeds == (0, 1, 2, 3)
    assert sweep.acc.shape[0] == 4
    for i, s in enumerate(sweep.seeds):
        solo = exp.run(seed=s)
        np.testing.assert_allclose(sweep.runs[i].acc, solo.acc, atol=1e-6)
        np.testing.assert_allclose(sweep.runs[i].local_loss,
                                   solo.local_loss, atol=1e-5)
        np.testing.assert_array_equal(sweep.runs[i].schedule,
                                      solo.schedule)
    # the seeds genuinely differ (schedules diverge at S=4, R=4 w.h.p.)
    assert any(not np.array_equal(sweep.runs[0].schedule,
                                  r.schedule) for r in sweep.runs[1:])
    mean, std = sweep.point.mean_std()
    assert mean == pytest.approx(float(sweep.final_acc.mean()))


def test_sweep_host_fallback_matches_vmapped():
    exp = _experiment(rounds=3)
    vm = exp.sweep(seeds=3)
    host = exp.sweep(seeds=3, vmapped=False)
    assert not host.vmapped
    for a, b in zip(vm.runs, host.runs):
        np.testing.assert_allclose(a.acc, b.acc, atol=1e-6)
        np.testing.assert_allclose(a.local_loss, b.local_loss, atol=1e-5)


def test_sweep_explicit_seed_list_and_chunking():
    exp = _experiment(rounds=4)
    sweep = exp.sweep(seeds=[11, 3], chunk=3)     # 3 + 1 trailing chunk
    assert sweep.seeds == (11, 3)
    assert all(r.num_dispatches == 2 for r in sweep.runs)
    solo = exp.run(seed=11)
    np.testing.assert_allclose(sweep.runs[0].acc, solo.acc, atol=1e-6)


def test_sweep_grid_host_loops_points_and_vmaps_seeds():
    exp = _experiment(rounds=2)
    sweep = exp.sweep(seeds=2, grid={"noise_alpha": [0.02, 0.05],
                                     "lr": [0.1]})
    assert len(sweep.points) == 2
    for point in sweep.points:
        assert len(point.runs) == 2
        assert np.isfinite(point.final_acc).all()
    rows = sweep.summary()
    assert rows[0]["noise_alpha"] == 0.02 and rows[1]["noise_alpha"] == 0.05
    assert all(r["seeds"] == 2 for r in rows)
    with pytest.raises(ValueError):              # multi-point convenience
        sweep.point                              # accessors must refuse
    with pytest.raises(ValueError, match="FLConfig"):
        exp.sweep(seeds=2, grid={"not_a_field": [1]})
    with pytest.raises(ValueError, match="seeds"):
        exp.sweep(seeds=2, grid={"seed": [1, 2]})   # seeds have their axis
    with pytest.raises(ValueError, match="num_clients"):
        # the dataset pins num_clients; an in-program gather would CLAMP
        exp.sweep(seeds=2, grid={"num_clients": [16]})
