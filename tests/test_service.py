"""Wire-true coordinator service (ISSUE 8): bit-exact serde round-trips
for every built-in codec's WireMsg, loopback-HTTP sync parity vs the
scan engine (K real client threads, measured bytes-on-wire ==
WireMsg.bits/8), the measured downlink CommRecord, and async
staleness-weighted rounds (scripted golden + e2e straggler run)."""
import dataclasses
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # hypothesis is a pinned requirement (requirements.txt) and the
    # serde property test is tier-1 in CI: REPRO_REQUIRE_HYPOTHESIS=1
    # there makes a missing install a hard failure instead of a skip.
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.core import tree_num_params
from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import (Experiment, ExperimentSpec, FLConfig, ServiceConfig,
                       WireMsg, algorithm_codec)
from repro.fed.service import serde
from repro.fed.service.runner import ServiceRunner
from repro.fed.service.server import Coordinator
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

KEY = jax.random.key(0)

# leaf sizes deliberately %32 != 0 so packed mask/quant words carry
# partial tails (the regression surface for framing/round-trip bugs)
TREE = {"w": jnp.zeros((33, 9)), "b": jnp.zeros((5,)),
        "deep": {"c": jnp.zeros((40, 7))}}
P = tree_num_params(TREE)

GOLDEN_STALENESS = os.path.join(os.path.dirname(__file__), "golden",
                                "service_staleness.json")


def _setup(algorithm="fedmrn", rounds=3, **cfg_kw):
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=8, clients_per_round=4,
                   rounds=rounds, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    return mlp_loss, params, ds, cfg


def _experiment(algorithm="fedmrn", rounds=3, **cfg_kw):
    loss_fn, params, ds, cfg = _setup(algorithm, rounds, **cfg_kw)
    return Experiment(ExperimentSpec(loss_fn=loss_fn, params=params,
                                     data=ds, config=cfg,
                                     eval_apply=mlp_apply))


def _tree_bitwise_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# serde: deterministic frames, bit-exact round-trips (satellite)
# ---------------------------------------------------------------------------

def _codec_msg(algorithm, **cfg_kw):
    """A REAL encoded message of the registered algorithm's codec."""
    cfg = FLConfig(algorithm=algorithm, **cfg_kw)
    codec = algorithm_codec(cfg, TREE)
    payload = dict(codec.template_payload(TREE))
    # PRNG-key leaves can't ride a tree_map over ShapeDtypeStructs
    keyish = [k for k in ("seed", "key") if k in payload]
    for k in keyish:
        payload.pop(k)
    vals = jax.tree_util.tree_map(
        lambda s: jax.random.normal(KEY, s.shape, jnp.float32), payload)
    if "mask" in vals:
        vals["mask"] = jax.tree_util.tree_map(
            lambda l: jax.random.bernoulli(
                KEY, 0.5, jnp.shape(l)).astype(jnp.float32),
            vals["mask"])
    if "seed" in keyish:
        # the 64-bit (shared-)noise seed rides the wire as key_data
        vals["seed"] = jax.random.key(42)
    if "key" in keyish:
        vals["key"] = jax.random.key(7)
    return codec, codec.encode(vals)


CODEC_CASES = [
    ("fedmrn", {}),                          # MaskCodec + per-client seed
    ("fedmrn", {"shared_noise": True}),      # MaskCodec + shared seed
    ("fedmrns", {}),                         # signed masks
    ("fedpm", {}),                           # seedless binary masks
    ("signsgd", {}),                         # SignCodec words + scales
    ("fedavg", {}),                          # DenseCodec f32
    ("topk", {"topk_frac": 0.25}),           # SparseCodec idx + values
    ("qsgd", {"qsgd_bits": 2}),              # QuantCodec, fields %32 != 0
    ("terngrad", {}),                        # QuantCodec log2(3) fields
]


@pytest.mark.parametrize("algorithm, cfg_kw", CODEC_CASES,
                         ids=[f"{a}{'+shared' if k.get('shared_noise') else ''}"
                              for a, k in CODEC_CASES])
def test_serde_roundtrip_bit_exact_per_codec(algorithm, cfg_kw):
    """dumps_msg → loads_msg is bit-exact for every built-in codec's
    encoded WireMsg, and the framed payload equals msg.bits/8 with the
    framing overhead accounted separately."""
    codec, msg = _codec_msg(algorithm, **cfg_kw)
    blob = serde.dumps_msg(msg, round=3, cid=5, weight=1.0, loss=0.25)
    back, meta = serde.loads_msg(blob)
    assert back.codec == msg.codec
    assert sorted(back.buffers) == sorted(msg.buffers)
    _tree_bitwise_equal(back.buffers, msg.buffers)
    assert (meta["round"], meta["cid"]) == (3, 5)
    # measured bytes-on-wire == the codec's claimed wire size
    assert serde.payload_bits(msg.buffers) == msg.bits
    assert len(blob) * 8 == msg.bits + serde.framing_bits(blob, msg.buffers)
    # determinism: same message -> byte-identical frame
    assert serde.dumps_msg(msg, round=3, cid=5, weight=1.0,
                           loss=0.25) == blob


def test_serde_tree_roundtrip_and_template_mismatch():
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    state = {"scores": jax.tree_util.tree_map(jnp.ones_like, params)}
    blob = serde.dumps_tree({"params": params, "state": state},
                            round=0, done=False)
    tree, meta = serde.loads_tree(
        blob, {"params": params, "state": state})
    _tree_bitwise_equal(tree["params"], params)
    _tree_bitwise_equal(tree["state"], state)
    assert meta == {"round": 0, "done": False}
    with pytest.raises(ValueError, match="mismatch"):
        serde.loads_tree(blob, {"params": params, "state": {}})
    # PRNG key leaves must be framed as key_data, never raw
    with pytest.raises(TypeError, match="key"):
        serde.dumps_tree({"k": jax.random.key(0)})


def test_serde_rejects_corrupt_frames():
    _, msg = _codec_msg("fedmrn")
    blob = serde.dumps_msg(msg, round=0, cid=0)
    with pytest.raises(ValueError, match="magic"):
        serde.unpack_frame(b"XXXX" + blob[4:])
    with pytest.raises(ValueError, match="truncated"):
        serde.unpack_frame(blob[:-3])
    with pytest.raises(ValueError, match="trailing"):
        serde.unpack_frame(blob + b"\x00")


if HAVE_HYPOTHESIS:
    _DTYPES = st.sampled_from(["<f4", "<f8", "<i4", "<i8", "<u4", "<i1",
                               "<u1", "<i2"])
    _SHAPES = st.lists(st.integers(0, 7), min_size=0, max_size=3)

    @st.composite
    def _frames(draw):
        n = draw(st.integers(0, 4))
        bufs = {}
        for i in range(n):
            name = draw(st.text(
                alphabet=st.characters(min_codepoint=33, max_codepoint=126),
                min_size=1, max_size=12)) + f"#{i}"
            dtype = np.dtype(draw(_DTYPES))
            shape = tuple(draw(_SHAPES))
            size = int(np.prod(shape, dtype=np.int64))
            raw = draw(st.binary(min_size=size * dtype.itemsize,
                                 max_size=size * dtype.itemsize))
            bufs[name] = np.frombuffer(raw, dtype=dtype).reshape(shape)
        meta = {"round": draw(st.integers(0, 2 ** 31 - 1)),
                "tag": draw(st.text(max_size=8))}
        return meta, bufs

    @settings(max_examples=50, deadline=None)
    @given(_frames())
    def test_serde_frame_roundtrip_property(frame):
        """Any dict of arrays (incl. 0-size, 0-dim, sub-word dtypes and
        arbitrary byte patterns — NaN payloads too) survives
        pack→unpack bit-exactly."""
        meta, bufs = frame
        blob = serde.pack_frame(meta, bufs)
        meta2, bufs2 = serde.unpack_frame(blob)
        assert meta2 == meta
        assert sorted(bufs2) == sorted(bufs)
        for k in bufs:
            assert bufs2[k].dtype == bufs[k].dtype
            assert bufs2[k].shape == bufs[k].shape
            np.testing.assert_array_equal(
                bufs2[k].view(np.uint8), bufs[k].view(np.uint8))


# ---------------------------------------------------------------------------
# sync parity: K clients over loopback HTTP == the scan engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm, cfg_kw", [
    ("fedmrn", {}),
    ("fedmrn", {"shared_noise": True}),
    ("fedmrns", {}),
    ("fedpm", {}),
    ("qsgd", {"qsgd_bits": 2}),
    ("signsgd", {}),
], ids=["fedmrn", "fedmrn+shared", "fedmrns", "fedpm", "qsgd", "signsgd"])
def test_service_sync_matches_scan(algorithm, cfg_kw):
    """The acceptance criterion: real bytes over a real socket, same
    trajectory to 1e-6, same MEASURED per-round wire bits."""
    exp = _experiment(algorithm, **cfg_kw)
    rs = exp.run(engine="scan")
    rv = exp.run(engine="service")
    assert rv.engine == "service"
    np.testing.assert_allclose(rv.acc, rs.acc, atol=1e-6)
    np.testing.assert_allclose(rv.local_loss, rs.local_loss, atol=1e-6)
    np.testing.assert_array_equal(rv.schedule, rs.schedule)
    np.testing.assert_allclose(rv.uplink_bits_round, rs.uplink_bits_round)
    rep = exp.service_report
    assert rep.mode == "sync"
    assert rep.n_uplinks == exp.cfg.rounds * exp.cfg.clients_per_round


def test_service_measured_uplink_bytes_equal_wiremsg_bits():
    """Every uplink byte that crossed the socket is accounted: payload
    == n_uplinks x per-client WireMsg.bits (frame overhead separate)."""
    exp = _experiment("fedmrn", shared_noise=True)
    exp.run(engine="service")
    rep = exp.service_report
    codec = algorithm_codec(exp.cfg, exp.spec.params)
    per_client = codec.measured_bits(exp.spec.params)
    assert rep.uplink_payload_bits == rep.n_uplinks * per_client
    assert rep.uplink_framing_bits > 0      # framing is real, and small
    assert rep.uplink_framing_bits < rep.uplink_payload_bits


def test_service_downlink_bits_are_measured():
    """CommRecord.downlink_bits out of a service run is the MEASURED
    serialized params payload of GET /v1/model — and it equals the
    analytic 32P figure exactly, with frame + algorithm state reported
    separately as overhead."""
    exp = _experiment("fedmrn")
    exp.run(engine="service")
    rep = exp.service_report
    P_model = tree_num_params(exp.spec.params)
    assert rep.comm.downlink_bits == rep.downlink_params_bits
    assert rep.downlink_params_bits == 32 * P_model     # == analytic
    assert rep.downlink_total_bits > rep.downlink_params_bits
    assert (rep.downlink_overhead_bits
            == rep.downlink_total_bits - rep.downlink_params_bits)
    # every worker pulls the model once per round
    K, R = exp.cfg.clients_per_round, exp.cfg.rounds
    assert rep.downlink_requests >= K * R


def test_service_history_matches_schema_and_monitoring_endpoints():
    from urllib.request import urlopen
    exp = _experiment("fedmrn")
    hist = exp.run(engine="service").to_history()
    from repro.fed import HISTORY_KEYS
    assert set(hist) == set(HISTORY_KEYS)
    assert hist["engine"] == "service"
    # the coordinator is gone after the run — its port must be closed
    with pytest.raises(OSError):
        urlopen(exp.service_report.base_url + "/v1/status", timeout=0.5)


def test_service_rejects_bad_configs():
    exp = _experiment("fedmrn")
    with pytest.raises(ValueError, match="service="):
        exp.run(engine="scan", service=ServiceConfig())
    with pytest.raises(ValueError, match="sync"):
        exp.run(engine="service",
                service=ServiceConfig(straggler_slots=(0,)))
    with pytest.raises(ValueError, match="staleness_beta"):
        ServiceConfig(mode="async", staleness_beta=0.0).validate()
    with pytest.raises(ValueError, match="min_fresh"):
        exp.run(engine="service",
                service=ServiceConfig(mode="async", min_fresh=99))


# ---------------------------------------------------------------------------
# async rounds: staleness weighting (golden + e2e)
# ---------------------------------------------------------------------------

def _scripted_coordinator(beta=0.5, rounds=3, min_fresh=2):
    """A Coordinator driven directly (no HTTP, no threads): slot 2 of
    every round posts one round late — fully deterministic arrivals."""
    loss_fn, params, ds, cfg = _setup("fedmrn", rounds=rounds,
                                      shared_noise=True)
    runner = ServiceRunner(loss_fn, cfg, params, ds,
                           eval_program=None, eval_every=1)
    service = ServiceConfig(mode="async", staleness_beta=beta,
                            min_fresh=min_fresh, straggler_slots=(2,))
    from repro.fed.engine import make_client_schedule
    schedule = make_client_schedule(cfg, cfg.seed)
    coord = Coordinator(
        codec=runner.codec, partial_fn=runner._partial,
        merge_fn=runner._merge, finalize_fn=runner._finalize,
        apply_fn=runner._apply, eval_fn=None, eval_rounds=(),
        params=params, state=runner._state0, schedule=schedule,
        seed=cfg.seed, service=service, algorithm=cfg.algorithm)
    return runner, coord, schedule, cfg


def _post(runner, coord, r, slot, schedule):
    """Compute slot's uplink against the coordinator's CURRENT model and
    frame it exactly like the worker loop does."""
    cid = int(schedule[r][slot])
    msg, agg_w, loss = runner._client_step(
        jnp.int32(coord.seed), coord.w, coord.state, jnp.int32(r),
        jnp.int32(cid), jnp.float32(1.0))
    body = serde.dumps_msg(msg, round=r, cid=cid, weight=float(agg_w),
                           loss=float(loss))
    return coord.handle_uplink(r, body)


def test_async_staleness_weights_golden():
    """Scripted arrival order → the staleness log (who aggregated when,
    at which beta^lag scale) and per-round measured bits match the
    committed golden file byte for byte."""
    runner, coord, schedule, cfg = _scripted_coordinator()
    # round 0: slots 0,1 arrive -> closes at min_fresh=2 (slot 2 defers)
    deferred = []
    for r in range(cfg.rounds):
        for stale_r, stale_body in deferred:    # last round's straggler
            code, _ = coord.handle_uplink(stale_r, stale_body)
            assert code == 200
        deferred = []
        cid = int(schedule[r][2])
        msg, agg_w, loss = runner._client_step(
            jnp.int32(coord.seed), coord.w, coord.state, jnp.int32(r),
            jnp.int32(cid), jnp.float32(1.0))
        deferred.append((r, serde.dumps_msg(
            msg, round=r, cid=cid, weight=float(agg_w), loss=float(loss))))
        for slot in (0, 1):
            code, resp = _post(runner, coord, r, slot, schedule)
            assert code == 200
    assert coord.done
    got = {
        "beta": coord.service.staleness_beta,
        "schedule": schedule.tolist(),
        "staleness": coord.staleness_log,
        "uplink_bits_round": [float(b) for b in coord.uplink_bits],
        "n_uplinks": coord.n_uplinks,
    }
    with open(GOLDEN_STALENESS) as f:
        golden = json.load(f)
    assert got == golden, (
        "async staleness semantics drifted from "
        "tests/golden/service_staleness.json — if deliberate, regenerate "
        "the golden file (tests/test_service.py::_scripted_coordinator)")
    # invariants the golden encodes: stale entries carry beta^lag
    for r, row in enumerate(coord.staleness_log):
        for s in row:
            assert s["scale"] == coord.service.staleness_beta ** s["lag"]
            assert s["lag"] == r - s["round_sent"]


def test_async_sync_equivalence_when_nobody_is_late():
    """mode='async' with everyone on time IS the synchronous barrier:
    identical trajectory to the sync service (and hence to scan)."""
    exp = _experiment("fedmrn")
    rs = exp.run(engine="scan")
    rv = exp.run(engine="service", service=ServiceConfig(mode="async"))
    np.testing.assert_allclose(rv.acc, rs.acc, atol=1e-6)
    rep = exp.service_report
    assert all(s["lag"] == 0 and s["scale"] == 1.0
               for row in rep.staleness for s in row)


def test_async_straggler_e2e_converges_with_weighted_stale_uplinks():
    """The e2e acceptance: a real straggler thread over loopback HTTP.
    Thread timing makes WHICH round a stale message lands in
    nondeterministic, so assert the timing-independent invariants:
    every aggregated message's scale is exactly beta^lag, stale traffic
    exists, message conservation holds, and the run still converges."""
    exp = _experiment("fedmrn", rounds=4)
    beta = 0.5
    sc = ServiceConfig(mode="async", staleness_beta=beta,
                       straggler_slots=(3,))
    rs = exp.run(engine="scan")
    rv = exp.run(engine="service", service=sc)
    rep = exp.service_report
    K, R = exp.cfg.clients_per_round, exp.cfg.rounds
    entries = [s for row in rep.staleness for s in row]
    assert all(s["scale"] == beta ** s["lag"] for s in entries)
    assert any(s["lag"] > 0 for s in entries)       # stale traffic existed
    # conservation: every round's straggler message either lands one
    # round late or is dropped when the run finishes mid-defer
    assert R * K - R <= len(entries) <= R * K
    assert np.isfinite(rv.final_acc)
    # staleness-weighted rounds still learn (vs the initial accuracy)
    assert rv.final_acc >= rs.acc[0] - 0.05


def test_async_rejects_integer_count_aggregation():
    """count_dtype partials cannot carry beta^lag scales — refused at
    construction instead of silently dropping staleness weights."""
    loss_fn, params, ds, cfg = _setup("fedmrn", shared_noise=True)
    runner = ServiceRunner(loss_fn, cfg, params, ds)
    codec = dataclasses.replace(runner.codec, count_dtype=jnp.int8)
    with pytest.raises(ValueError, match="count_dtype"):
        Coordinator(
            codec=codec, partial_fn=runner._partial,
            merge_fn=runner._merge, finalize_fn=runner._finalize,
            apply_fn=runner._apply, params=params, state=runner._state0,
            schedule=np.zeros((2, 4), np.int32), seed=0,
            service=ServiceConfig(mode="async"), algorithm="fedmrn")
