"""Pallas kernel validation: interpret=True vs pure-jnp oracles, with
shape/dtype sweeps per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitpack.bitpack import pack_bits_pallas, unpack_bits_pallas
from repro.kernels.bitpack.ref import pack_ref, unpack_ref
from repro.kernels.psm_mask.psm_mask import psm_fused
from repro.kernels.psm_mask.ref import psm_ref
from repro.kernels.psm_mask.ops import psm_apply, psm_apply_tree
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv_pallas
from repro.models.rwkv6 import _wkv_scan

KEY = jax.random.key(0)


class TestPSMKernel:
    @pytest.mark.parametrize("shape", [(8, 128), (64, 512), (5, 384),
                                       (256, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("mode", ["binary", "signed"])
    def test_matches_ref(self, shape, dtype, mode):
        k1, k2, k3, k4 = jax.random.split(KEY, 4)
        u = (0.01 * jax.random.normal(k1, shape)).astype(dtype)
        n = jax.random.uniform(k2, shape, jnp.float32,
                               minval=-0.01, maxval=0.01).astype(dtype)
        r_sm = jax.random.uniform(k3, shape, jnp.float32)
        r_pm = jax.random.uniform(k4, shape, jnp.float32)
        for prog in (0.0, 0.5, 1.0):
            got_u, got_m = psm_fused(u, n, r_sm, r_pm, prog, mode=mode,
                                     interpret=True)
            want_u, want_m = psm_ref(u, n, r_sm, r_pm, prog, mode=mode)
            np.testing.assert_allclose(
                np.asarray(got_u, np.float32),
                np.asarray(want_u, np.float32), atol=1e-6)
            np.testing.assert_array_equal(np.asarray(got_m),
                                          np.asarray(want_m))

    def test_arbitrary_shape_op(self):
        u = 0.01 * jax.random.normal(KEY, (3, 7, 11))
        n = jnp.full((3, 7, 11), 0.01)
        uhat_p, m_p = psm_apply(u, n, KEY, 0.7, use_pallas=True)
        uhat_r, m_r = psm_apply(u, n, KEY, 0.7, use_pallas=False)
        np.testing.assert_allclose(np.asarray(uhat_p), np.asarray(uhat_r),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(m_p), np.asarray(m_r))

    def test_tree_variant(self):
        tree_u = {"a": 0.01 * jax.random.normal(KEY, (17,)),
                  "b": 0.01 * jax.random.normal(KEY, (4, 9))}
        tree_n = jax.tree_util.tree_map(
            lambda x: jnp.full(x.shape, 0.01), tree_u)
        uhat, mask = psm_apply_tree(tree_u, tree_n, KEY, 1.0)
        for l in jax.tree_util.tree_leaves(uhat):
            assert np.isfinite(np.asarray(l)).all()
        for l in jax.tree_util.tree_leaves(mask):
            assert set(np.unique(np.asarray(l))) <= {0, 1}

    def test_kernel_unbiased_at_progress_one(self):
        """The fused kernel preserves the paper's unbiasedness property."""
        N = 100_000
        u = jnp.full((N // 128, 128), 0.004)
        n = jnp.full((N // 128, 128), 0.01)
        k1, k2 = jax.random.split(KEY)
        r_sm = jax.random.uniform(k1, u.shape, jnp.float32)
        r_pm = jax.random.uniform(k2, u.shape, jnp.float32)
        uhat, _ = psm_fused(u, n, r_sm, r_pm, 1.0, mode="binary",
                            interpret=True)
        assert abs(float(jnp.mean(uhat)) - 0.004) < 3e-4


class TestBitpackKernel:
    @pytest.mark.parametrize("shape", [(8, 128), (3, 32), (16, 4096),
                                       (1, 64), (9, 224)])
    def test_pack_matches_ref(self, shape):
        bits = jax.random.bernoulli(KEY, 0.5, shape).astype(jnp.int8)
        got = pack_bits_pallas(bits, interpret=True)
        want = pack_ref(bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("shape", [(8, 4), (3, 1), (16, 128)])
    def test_unpack_roundtrip(self, shape):
        words = jax.random.randint(
            KEY, shape, 0, 2**31 - 1).astype(jnp.uint32)
        bits = unpack_bits_pallas(words, interpret=True)
        np.testing.assert_array_equal(np.asarray(bits),
                                      np.asarray(unpack_ref(words)))
        back = pack_bits_pallas(bits, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(words))

    def test_wire_width_is_one_bit(self):
        bits = jnp.ones((4, 320), jnp.int8)
        words = pack_bits_pallas(bits, interpret=True)
        assert words.size * 32 == bits.size


class TestRWKV6Kernel:
    @pytest.mark.parametrize("B,T,H,hd", [(1, 8, 1, 16), (2, 16, 3, 32),
                                          (2, 33, 2, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_model_scan(self, B, T, H, hd, dtype):
        ks = jax.random.split(KEY, 5)
        r = (0.5 * jax.random.normal(ks[0], (B, T, H, hd))).astype(dtype)
        k = (0.5 * jax.random.normal(ks[1], (B, T, H, hd))).astype(dtype)
        v = (0.5 * jax.random.normal(ks[2], (B, T, H, hd))).astype(dtype)
        w = jax.nn.sigmoid(
            jax.random.normal(ks[3], (B, T, H, hd))).astype(dtype)
        u = 0.3 * jax.random.normal(ks[4], (H, hd))
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        out_k, s_k = wkv_pallas(r, k, v, w, u, s0, interpret=True)
        out_r, s_r = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w.astype(jnp.float32),
                               u, s0)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=tol, rtol=tol)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   atol=tol, rtol=tol)

    def test_state_carry_composes(self):
        """Running two halves with carried state == one full pass."""
        B, T, H, hd = 1, 16, 2, 32
        ks = jax.random.split(KEY, 5)
        mk = lambda i: 0.5 * jax.random.normal(ks[i], (B, T, H, hd))
        r, k, v = mk(0), mk(1), mk(2)
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
        u = 0.3 * jax.random.normal(ks[4], (H, hd))
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        full, s_full = wkv_pallas(r, k, v, w, u, s0, interpret=True)
        h1, s_mid = wkv_pallas(r[:, :8], k[:, :8], v[:, :8], w[:, :8],
                               u, s0, interpret=True)
        h2, s_end = wkv_pallas(r[:, 8:], k[:, 8:], v[:, 8:], w[:, 8:],
                               u, s_mid, interpret=True)
        np.testing.assert_allclose(np.asarray(full),
                                   np.concatenate([h1, h2], axis=1),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_end),
                                   atol=1e-5)
