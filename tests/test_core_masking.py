"""Unit + property tests for the FedMRN core (noise, masking, packing)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # hypothesis is a pinned requirement (requirements.txt) and the
    # property tests are tier-1 in CI: REPRO_REQUIRE_HYPOTHESIS=1 there
    # makes a missing install a hard failure instead of a skip.  The
    # skip survives only for bare containers that cannot pip install.
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.core import (
    NoiseConfig, client_round_key, gen_noise,
    mask_prob_binary, mask_prob_signed, sample_mask, deterministic_mask,
    stochastic_masking, progressive_stochastic_masking, clip_to_noise,
    pack_bits, unpack_bits, tree_pack, tree_unpack, tree_num_params,
    tree_psm, tree_sample_mask, tree_masked_noise,
)

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# noise generator G(s)
# ---------------------------------------------------------------------------

class TestNoise:
    def test_seed_determinism(self):
        """Server regenerating G(s) from the seed matches the client exactly."""
        tree = {"a": jnp.zeros((17, 5)), "b": jnp.zeros((3,))}
        k = client_round_key(42, 3, 7)
        n1 = gen_noise(k, tree, NoiseConfig())
        n2 = gen_noise(client_round_key(42, 3, 7), tree, NoiseConfig())
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(a, b), n1, n2)

    def test_distinct_clients_distinct_noise(self):
        tree = {"a": jnp.zeros((64,))}
        n1 = gen_noise(client_round_key(0, 1, 1), tree, NoiseConfig())
        n2 = gen_noise(client_round_key(0, 1, 2), tree, NoiseConfig())
        assert not np.allclose(n1["a"], n2["a"])

    @pytest.mark.parametrize("dist", ["uniform", "gauss", "bernoulli"])
    def test_distributions(self, dist):
        tree = jnp.zeros((4096,))
        n = gen_noise(KEY, tree, NoiseConfig(dist=dist, alpha=1e-2))
        n = np.asarray(n)
        if dist == "uniform":
            assert n.min() >= -1e-2 and n.max() <= 1e-2
            assert abs(n.mean()) < 1e-3
        elif dist == "bernoulli":
            assert set(np.unique(np.abs(n))) == {np.float32(1e-2)}
        else:
            assert abs(n.std() - 1e-2) < 1e-3

    def test_bad_dist_raises(self):
        with pytest.raises(ValueError):
            NoiseConfig(dist="cauchy")


# ---------------------------------------------------------------------------
# masking probabilities & unbiasedness (the paper's Eq. 6/7 property)
# ---------------------------------------------------------------------------

class TestMaskingMath:
    def test_prob_binary_in_range(self):
        u = jnp.array([-1.0, 0.0, 0.5, 2.0])
        n = jnp.array([1.0, 1.0, 1.0, 1.0])
        p = mask_prob_binary(u, n)
        assert (np.asarray(p) == [0.0, 0.0, 0.5, 1.0]).all()

    def test_prob_signed(self):
        u = jnp.array([-1.0, 0.0, 1.0])
        n = jnp.array([1.0, 1.0, 1.0])
        p = mask_prob_signed(u, n)
        assert (np.asarray(p) == [0.0, 0.5, 1.0]).all()

    @pytest.mark.parametrize("mode", ["binary", "signed"])
    def test_sm_unbiased(self, mode):
        """E[n·M(u,n) − u] = 0 when u/n is in the feasible interval."""
        N = 200_000
        n = jnp.full((N,), 0.01)
        u = jnp.full((N,), 0.004 if mode == "binary" else -0.004)
        m = sample_mask(u, n, KEY, mode=mode)
        est = np.asarray(n * m.astype(n.dtype))
        np.testing.assert_allclose(est.mean(), float(u[0]), atol=3e-4)

    def test_dm_biased(self):
        """DM ignores magnitude: u=0.1n still maps to full n — the flaw SM fixes."""
        n = jnp.full((1000,), 0.01)
        u = 0.1 * n
        m = deterministic_mask(u, n, mode="binary")
        est = np.asarray(n * m.astype(n.dtype)).mean()
        assert est == pytest.approx(0.01)          # biased: 10x too large
        m_sm = sample_mask(u, n, KEY, mode="binary")
        est_sm = np.asarray(n * m_sm.astype(n.dtype)).mean()
        assert abs(est_sm - 0.001) < 3e-4          # SM: unbiased

    @pytest.mark.parametrize("mode", ["binary", "signed"])
    def test_clip_to_noise_interval(self, mode):
        n = jnp.array([0.01, -0.01])
        u = jnp.array([5.0, -5.0])
        bar = np.asarray(clip_to_noise(u, n, mode=mode))
        assert (np.abs(bar) <= 0.01 + 1e-9).all()

    def test_ste_gradient_is_identity(self):
        """∂S/∂u = 1 (Eq. 9): gradient flows through masking unchanged."""
        u = jnp.ones((8,)) * 0.003
        n = jnp.full((8,), 0.01)

        def f(u_):
            return jnp.sum(stochastic_masking(u_, n, KEY, mode="binary") ** 2)

        g = jax.grad(f)(u)
        hat = stochastic_masking(u, n, KEY, mode="binary")
        np.testing.assert_allclose(np.asarray(g), np.asarray(2 * hat),
                                   rtol=1e-6)

    def test_psm_progress_zero_is_clip(self):
        u = jnp.ones((64,)) * 0.02
        n = jnp.full((64,), 0.01)
        out = progressive_stochastic_masking(u, n, KEY, progress=0.0,
                                             mode="binary")
        np.testing.assert_allclose(np.asarray(out), 0.01)  # clipped to n

    def test_psm_progress_one_is_sm(self):
        u = jnp.ones((4096,)) * 0.5e-2
        n = jnp.full((4096,), 1e-2)
        out = np.asarray(progressive_stochastic_masking(
            u, n, KEY, progress=1.0, mode="binary"))
        assert set(np.unique(out)) <= {np.float32(0.0), np.float32(1e-2)}

    def test_signed_binary_equivalence(self):
        """G⊙m_s = 2G⊙m − G for m = (m_s+1)/2 (paper §3.1 identity)."""
        g = jax.random.normal(KEY, (128,))
        ms = jnp.where(jax.random.bernoulli(KEY, 0.5, (128,)), 1, -1)
        m = (ms + 1) // 2
        lhs = g * ms
        rhs = 2 * g * m - g
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

if not HAVE_HYPOTHESIS:
    @pytest.mark.skip(reason="hypothesis missing — pinned in "
                             "requirements.txt; REQUIRED in CI "
                             "(REPRO_REQUIRE_HYPOTHESIS=1 raises instead)")
    class TestProperties:
        """Stubs so the property tests surface as SKIPPED, not vanish."""

        def test_probability_always_valid(self):
            pass

        def test_mask_values_in_domain(self):
            pass

        def test_psm_output_within_noise_bounds(self):
            pass

        def test_pack_unpack_roundtrip(self):
            pass

        def test_tree_pack_roundtrip(self):
            pass
else:
    @st.composite
    def u_and_n(draw):
        size = draw(st.integers(1, 257))
        alpha = draw(st.sampled_from([1e-3, 1e-2, 1.0]))
        seed = draw(st.integers(0, 2**31 - 1))
        k = jax.random.key(seed)
        ku, kn = jax.random.split(k)
        u = alpha * jax.random.normal(ku, (size,))
        n = jax.random.uniform(kn, (size,), minval=-alpha, maxval=alpha)
        return u, n

    class TestProperties:
        @settings(max_examples=25, deadline=None)
        @given(u_and_n())
        def test_probability_always_valid(self, un):
            u, n = un
            for p in (mask_prob_binary(u, n), mask_prob_signed(u, n)):
                p = np.asarray(p)
                assert (np.isfinite(p).all() and (p >= 0).all()
                        and (p <= 1).all())

        @settings(max_examples=25, deadline=None)
        @given(u_and_n(), st.sampled_from(["binary", "signed"]))
        def test_mask_values_in_domain(self, un, mode):
            u, n = un
            m = np.asarray(sample_mask(u, n, KEY, mode=mode))
            dom = {0, 1} if mode == "binary" else {-1, 1}
            assert set(np.unique(m)) <= dom

        @settings(max_examples=25, deadline=None)
        @given(u_and_n(), st.sampled_from(["binary", "signed"]),
               st.floats(0.0, 1.0))
        def test_psm_output_within_noise_bounds(self, un, mode, progress):
            """PSM forward values never leave the noise envelope: every
            element of û is in [min(0,n), max(0,n)] (binary) resp.
            [-|n|, |n|] (signed), whatever the progress."""
            u, n = un
            hat = np.asarray(progressive_stochastic_masking(
                u, n, KEY, progress=progress, mode=mode))
            n_ = np.asarray(n)
            lo = np.minimum(0.0, n_) if mode == "binary" else -np.abs(n_)
            hi = np.maximum(0.0, n_) if mode == "binary" else np.abs(n_)
            eps = 1e-6
            assert (hat >= lo - eps).all() and (hat <= hi + eps).all()

        @settings(max_examples=25, deadline=None)
        @given(st.integers(1, 2048), st.integers(0, 2**31 - 1))
        def test_pack_unpack_roundtrip(self, n_bits, seed):
            bits = np.asarray(
                jax.random.bernoulli(jax.random.key(seed), 0.5, (n_bits,))
            ).astype(np.int8)
            words = pack_bits(jnp.asarray(bits))
            rec = np.asarray(unpack_bits(words, n_bits))
            np.testing.assert_array_equal(rec, bits)
            assert words.size == (n_bits + 31) // 32

        @settings(max_examples=10, deadline=None)
        @given(st.integers(0, 2**31 - 1),
               st.sampled_from(["binary", "signed"]))
        def test_tree_pack_roundtrip(self, seed, mode):
            k = jax.random.key(seed)
            tree = {"w": jnp.zeros((13, 7)), "b": jnp.zeros((5,)),
                    "n": {"x": jnp.zeros((1,))}}
            noise = gen_noise(k, tree, NoiseConfig())
            u = jax.tree_util.tree_map(lambda n: 0.3 * n, noise)
            m = tree_sample_mask(u, noise, k, mode=mode)
            words = tree_pack(m, mode=mode)
            m2 = tree_unpack(words, tree, mode=mode)
            jax.tree_util.tree_map(
                lambda a, b: np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b)), m, m2)
            assert words.size * 32 >= tree_num_params(tree)


# ---------------------------------------------------------------------------
# end-to-end client→server exactness
# ---------------------------------------------------------------------------

def test_wire_roundtrip_reconstruction():
    """Server's G(s)⊙m from (mask, seed) equals the client's û exactly."""
    tree = {"w": jnp.zeros((33, 9)), "b": jnp.zeros((4,))}
    seed_key = client_round_key(7, 2, 5)
    noise = gen_noise(seed_key, tree, NoiseConfig())
    u = jax.tree_util.tree_map(lambda n: 0.5 * n, noise)
    m = tree_sample_mask(u, noise, KEY, mode="binary")
    client_uhat = tree_masked_noise(noise, m)

    # --- wire: packed mask + seed only -------------------------------------
    words = tree_pack(m, mode="binary")
    server_noise = gen_noise(seed_key, tree, NoiseConfig())
    server_m = tree_unpack(words, tree, mode="binary")
    server_uhat = tree_masked_noise(server_noise, server_m)

    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        client_uhat, server_uhat)
