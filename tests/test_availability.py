"""Availability tier (ISSUE 9 tentpole): seeded dropout traces compose
with the client schedule on EVERY engine — all-available runs stay
bitwise identical to the undegraded path, d dropped clients aggregate
exactly the K−d survivors (parity vs the genuinely-subsetting looped
reference), the codec partial protocol is degradation-exact per codec
(binary AND signed mask counts — the 2c−K fixup must use the valid
count), and the dormant Dirichlet partitioner is wired + guarded."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.data.synthetic import partition_dirichlet
from repro.fed import (AvailabilityTrace, Experiment, ExperimentSpec,
                       FLConfig, algorithm_codec, make_availability,
                       make_client_schedule)
from repro.fed.availability import check_engine_support
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

KEY = jax.random.key(0)
R, C, K = 3, 8, 4


def _experiment(algorithm="fedmrn", rounds=R, trace=None, **cfg_kw):
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, C)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=C, clients_per_round=K,
                   rounds=rounds, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    return Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                     data=ds, config=cfg,
                                     eval_apply=mlp_apply,
                                     availability=trace))


# ---------------------------------------------------------------------------
# the trace generators
# ---------------------------------------------------------------------------

def test_traces_are_seed_deterministic():
    a = AvailabilityTrace.bernoulli(5, rounds=20, num_clients=16,
                                    dropout=0.4)
    b = AvailabilityTrace.bernoulli(5, rounds=20, num_clients=16,
                                    dropout=0.4)
    c = AvailabilityTrace.bernoulli(6, rounds=20, num_clients=16,
                                    dropout=0.4)
    np.testing.assert_array_equal(a.avail, b.avail)
    assert not np.array_equal(a.avail, c.avail)
    m = AvailabilityTrace.markov(5, rounds=20, num_clients=16,
                                 dropout=0.4, churn=0.7)
    m2 = AvailabilityTrace.markov(5, rounds=20, num_clients=16,
                                  dropout=0.4, churn=0.7)
    np.testing.assert_array_equal(m.avail, m2.avail)


def test_markov_stationary_rate_matches_dropout():
    tr = AvailabilityTrace.markov(0, rounds=4000, num_clients=16,
                                  dropout=0.3, churn=0.5)
    assert abs(1.0 - tr.avail.mean() - 0.3) < 0.03


def test_valid_for_aligns_with_schedule():
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                   rounds=R, local_steps=1, batch_size=4)
    schedule = make_client_schedule(cfg)
    tr = AvailabilityTrace.bernoulli(0, rounds=R, num_clients=C,
                                     dropout=0.5)
    valid = tr.valid_for(schedule)
    assert valid.shape == (R, K) and valid.dtype == np.float32
    for r in range(R):
        for k, cid in enumerate(schedule[r]):
            assert valid[r, k] == float(tr.avail[r, int(cid)])


def test_make_availability_from_config():
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                   rounds=R, local_steps=1, batch_size=4,
                   availability="bernoulli", dropout=0.4)
    tr = make_availability(cfg)
    assert tr.kind == "bernoulli" and tr.avail.shape == (R, C)
    assert make_availability(
        FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                 rounds=R, local_steps=1, batch_size=4)) is None


def _check_resample_property(seed, dropout):
    """Ji et al. 2020 dynamic sampling: after resampling, every slot
    whose client is available keeps it; dropped slots are refilled from
    available non-scheduled spares when any exist."""
    cfg = FLConfig(algorithm="fedmrn", num_clients=16, clients_per_round=6,
                   rounds=4, local_steps=1, batch_size=4, seed=seed)
    schedule = make_client_schedule(cfg)
    tr = AvailabilityTrace.bernoulli(seed, rounds=4, num_clients=16,
                                     dropout=dropout)
    out = tr.resample_schedule(schedule, seed)
    for r in range(4):
        assert len(set(out[r].tolist())) == len(out[r])   # no duplicates
        dead = [k for k in range(6) if not tr.avail[r, schedule[r][k]]]
        spares = [c for c in range(16)
                  if tr.avail[r, c] and c not in schedule[r].tolist()]
        refilled = 0
        for k in range(6):
            if tr.avail[r, schedule[r][k]]:
                assert out[r][k] == schedule[r][k]        # survivors kept
            elif out[r][k] != schedule[r][k]:
                assert tr.avail[r, out[r][k]]     # replacement available
                assert out[r][k] in spares        # drawn from the spares
                refilled += 1
        # exactly as many dead slots refilled as spares allowed; the
        # rest keep the dropped client and stay masked invalid
        assert refilled == min(len(dead), len(spares))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), dropout=st.floats(0.0, 0.8))
    def test_resample_only_schedules_available_spares(seed, dropout):
        _check_resample_property(seed, dropout)
else:
    def test_resample_only_schedules_available_spares():
        # hypothesis unavailable: a fixed handful of cases instead of a
        # skip — the property still runs in minimal environments
        for seed, dropout in [(0, 0.0), (1, 0.3), (7, 0.6), (42, 0.8)]:
            _check_resample_property(seed, dropout)


# ---------------------------------------------------------------------------
# engine semantics
# ---------------------------------------------------------------------------

def test_all_available_trace_is_bitwise_identical():
    """availability='always' must trace the EXACT program the undegraded
    run traces — acc, loss and bits bitwise equal, not just close."""
    base = _experiment().run(engine="scan")
    always = _experiment(availability="always").run(engine="scan")
    np.testing.assert_array_equal(np.asarray(base.acc),
                                  np.asarray(always.acc))
    np.testing.assert_array_equal(np.asarray(base.local_loss),
                                  np.asarray(always.local_loss))
    assert always.participation_round == (K,) * R


@pytest.mark.parametrize("engine", ["scan", "batched", "cohort"])
@pytest.mark.parametrize("algorithm", ["fedmrn", "fedmrns", "fedpm"])
def test_dropped_clients_match_survivors_only_reference(engine, algorithm):
    """d dropped clients must aggregate exactly the K−d survivors: the
    masked fused engines reproduce the looped reference, which GENUINELY
    subsets the round (no masked zero-weight rows)."""
    kw = dict(availability="bernoulli", dropout=0.4)
    ref = _experiment(algorithm, **kw).run(engine="looped")
    got = _experiment(algorithm, **kw).run(engine=engine)
    assert got.participation_round == ref.participation_round
    assert min(ref.participation_round) < K      # the trace really drops
    np.testing.assert_allclose(np.asarray(got.acc), np.asarray(ref.acc),
                               atol=1e-6)


def test_shared_noise_int_counts_on_cohort_matches_reference():
    kw = dict(availability="bernoulli", dropout=0.4, shared_noise=True,
              int_mask_agg=True)
    ref = _experiment("fedmrn", availability="bernoulli", dropout=0.4,
                      shared_noise=True).run(engine="looped")
    got = _experiment("fedmrn", **kw).run(engine="cohort")
    np.testing.assert_allclose(np.asarray(got.acc), np.asarray(ref.acc),
                               atol=1e-6)


def test_resample_refills_dropped_slots():
    plain = _experiment(availability="bernoulli", dropout=0.4)
    res = _experiment(availability="bernoulli", dropout=0.4,
                      avail_resample=True)
    rp = plain.run(engine="scan")
    rr = res.run(engine="scan")
    assert sum(rr.participation_round) >= sum(rp.participation_round)


def test_zero_survivor_round_raises_not_silent():
    tr = AvailabilityTrace("bernoulli",
                           np.zeros((R, C), bool))      # everyone down
    with pytest.raises(ValueError, match="zero surviving"):
        _experiment(trace=tr).run(engine="scan")


def test_int_mask_agg_refused_on_masked_engines():
    e = _experiment(availability="bernoulli", dropout=0.3,
                    shared_noise=True, int_mask_agg=True)
    with pytest.raises(ValueError, match="int_mask_agg"):
        e.run(engine="scan")


def test_error_feedback_refused_under_dropout():
    e = _experiment(availability="bernoulli", dropout=0.3,
                    error_feedback=True)
    with pytest.raises(ValueError, match="error_feedback"):
        e.run(engine="scan")


def test_hetero_local_steps_is_service_only():
    ls = AvailabilityTrace.heterogeneous_steps(0, C, choices=(1, 2))
    tr = AvailabilityTrace.always(R, C, local_steps=ls)
    with pytest.raises(ValueError, match="service"):
        _experiment(trace=tr).run(engine="scan")
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                   rounds=R, local_steps=1, batch_size=4)
    check_engine_support(cfg, tr, "service")             # allowed


def test_participation_round_survives_history_roundtrip():
    res = _experiment(availability="bernoulli", dropout=0.4
                      ).run(engine="scan")
    hist = res.to_history()
    assert hist["participation_round"] == list(res.participation_round)
    from repro.fed.api import RunResult
    back = RunResult.from_history(res.config, res.engine, hist)
    assert back.participation_round == res.participation_round


def test_sweep_grid_dropout_point_matches_direct_run():
    """The ROADMAP 4(b) deliverable: accuracy-vs-dropout from ONE
    Experiment.sweep — each (dropout, seed) cell equals the standalone
    run at that config."""
    import dataclasses
    e = _experiment()
    res = e.sweep(seeds=[0, 1], grid={"availability": ["bernoulli"],
                                      "dropout": [0.0, 0.4]})
    pt = [p for p in res.points
          if dict(p.overrides)["dropout"] == 0.4][0]
    direct = _experiment(availability="bernoulli", dropout=0.4,
                         seed=1).run(engine="scan")
    np.testing.assert_allclose(np.asarray(pt.runs[1].acc),
                               np.asarray(direct.acc), atol=1e-6)
    assert pt.runs[1].participation_round == direct.participation_round


# ---------------------------------------------------------------------------
# codec degraded partials: masked == survivors-only, per codec (satellite)
# ---------------------------------------------------------------------------

TREE = {"w": jnp.zeros((33, 9)), "b": jnp.zeros((5,)),
        "deep": {"c": jnp.zeros((40, 7))}}

CODEC_CASES = [
    ("fedmrn", {}),                          # per-client noise, binary
    ("fedmrn", {"shared_noise": True}),      # shared seed count path
    ("fedmrns", {}),                         # SIGNED masks (2c−K fixup)
    ("fedmrns", {"shared_noise": True}),
    ("fedpm", {}),                           # seedless binary counts
    ("signsgd", {}),
    ("fedavg", {}),
    ("topk", {"topk_frac": 0.25}),
    ("qsgd", {"qsgd_bits": 2}),
]


def _stacked_payload(codec, k):
    """K random client payloads in the codec's stacked layout."""
    payload = dict(codec.template_payload(TREE))
    keyish = [n for n in ("seed", "key") if n in payload]
    for n in keyish:
        payload.pop(n)
    vals = jax.tree_util.tree_map(
        lambda s: jax.random.normal(KEY, (k,) + s.shape, jnp.float32),
        payload)
    if "mask" in vals:
        vals["mask"] = jax.tree_util.tree_map(
            lambda l: jax.random.bernoulli(KEY, 0.5, jnp.shape(l)
                                           ).astype(jnp.float32),
            vals["mask"])
    if "seed" in keyish:
        vals["seed"] = jax.random.split(jax.random.key(42), k)
    if "key" in keyish:
        vals["key"] = jax.random.split(jax.random.key(7), k)
    return vals


def _subset_msg(msg, keep):
    """Survivor-only stacked message: row-subset every buffer."""
    from repro.fed import WireMsg
    return WireMsg(msg.codec, {n: b[np.asarray(keep)]
                               for n, b in msg.buffers.items()})


@pytest.mark.parametrize("algorithm,cfg_kw", CODEC_CASES,
                         ids=[f"{a}-{'-'.join(k) or 'default'}"
                              for a, k in CODEC_CASES])
def test_degraded_partial_equals_survivors_only(algorithm, cfg_kw):
    k = 4
    cfg = FLConfig(algorithm=algorithm, **cfg_kw)
    codec = algorithm_codec(cfg, TREE)
    msg = codec.encode_stacked(_stacked_payload(codec, k))
    weights = jnp.asarray([1.0, 2.0, 1.5, 0.5], jnp.float32)
    valid = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)
    keep = np.asarray([0, 2])
    masked = codec.finalize_partial(
        codec.partial_aggregate(msg, weights, valid=valid))
    survivors = codec.finalize_partial(
        codec.partial_aggregate(_subset_msg(msg, keep), weights[keep]))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6), masked, survivors)


@pytest.mark.parametrize("mode", ["binary", "signed"])
def test_degraded_integer_count_partial_is_exact(mode):
    """The count path (int_mask_agg wire format): masked integer counts
    must EXACTLY equal survivor-only counts — in signed mode the raw
    masked sum is 2c − K and the (K − n) fixup restores Σ±1 over the n
    valid rows; using K instead of n here is the classic silent bug."""
    import dataclasses as dc
    k = 4
    algorithm = "fedpm" if mode == "binary" else "fedmrns"
    cfg_kw = {} if mode == "binary" else {"shared_noise": True}
    cfg = FLConfig(algorithm=algorithm, **cfg_kw)
    codec = dc.replace(algorithm_codec(cfg, TREE), count_dtype=jnp.int8)
    assert codec.count_aggregatable
    msg = codec.encode_stacked(_stacked_payload(codec, k))
    ones = jnp.ones((k,), jnp.float32)
    valid = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)
    keep = np.asarray([0, 2])
    masked = codec.partial_aggregate(msg, ones, valid=valid)
    survivors = codec.partial_aggregate(_subset_msg(msg, keep), ones[keep])
    assert int(masked["n"]) == int(survivors["n"]) == 2
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        masked["counts"], survivors["counts"])


# ---------------------------------------------------------------------------
# the dormant Dirichlet partitioner: wired + guarded (satellites)
# ---------------------------------------------------------------------------

def test_dirichlet_rejects_fewer_samples_than_clients():
    with pytest.raises(ValueError, match="at least one"):
        partition_dirichlet(0, np.zeros((3,), np.int32), 8)


def test_dirichlet_small_alpha_never_leaves_a_client_empty():
    """alpha → 0 concentrates every label on one client; the repair loop
    must terminate with every client non-empty (and raise, not hang or
    IndexError, when repair is impossible)."""
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 4, size=64).astype(np.int32)
    for seed in range(10):
        parts = partition_dirichlet(seed, labels, 16, alpha=1e-3)
        sizes = [len(p) for p in parts]
        assert min(sizes) >= 1 and sum(sizes) == 64


def test_dirichlet_alpha_controls_skew():
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 8, size=4000).astype(np.int32)

    def label_entropy(parts):
        hs = []
        for p in parts:
            counts = np.bincount(labels[p], minlength=8).astype(float)
            q = counts / counts.sum()
            q = q[q > 0]
            hs.append(float(-(q * np.log(q)).sum()))
        return float(np.mean(hs))

    skewed = label_entropy(partition_dirichlet(0, labels, 8, alpha=0.05))
    uniform = label_entropy(partition_dirichlet(0, labels, 8, alpha=100.0))
    assert skewed < uniform - 0.5


def test_scenarios_wire_dirichlet_into_spec():
    from repro.fed import make_synthetic_spec
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                   rounds=2, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2)
    spec = make_synthetic_spec(cfg, partition="noniid1", alpha=0.1,
                               n=400, hw=8, n_classes=4)
    res = Experiment(spec).run(engine="scan")
    assert np.isfinite(res.final_acc)


def test_dropout_curve_is_one_sweep():
    from repro.fed import dropout_curve, make_synthetic_spec
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                   rounds=2, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2)
    spec = make_synthetic_spec(cfg, n=400, hw=8, n_classes=4)
    curve = dropout_curve(spec, dropouts=(0.0, 0.4), seeds=[0, 1])
    assert set(curve["points"]) == {"0", "0.4"}
    clean = curve["points"]["0"]["participation_round"]
    degraded = curve["points"]["0.4"]["participation_round"]
    assert all(p == [K, K] for p in clean)
    assert any(min(p) < K for p in degraded)
