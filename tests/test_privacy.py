"""Distributed DP over the mask-count wire (ISSUE 10): seeded discrete
mechanisms, the clip-equals-popcount invariant (hypothesis, ref ≡
pallas-interpret), RDP accounting at the TRUE recorded participation,
noise-exactly-once under any partial split, five-engine parity of the
DP release, the coordinator's (ε, δ) reporting, and the guard rails on
configurations the count release cannot honour."""
import dataclasses
import math
import os
from functools import reduce

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # hypothesis is a pinned requirement (requirements.txt) and the
    # clip property test is tier-1 in CI: REPRO_REQUIRE_HYPOTHESIS=1
    # there makes a missing install a hard failure instead of a skip.
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.core import NoiseConfig, client_round_key, tree_num_params
from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import (AvailabilityTrace, Experiment, ExperimentSpec,
                       FLConfig, MaskCodec, PrivacyConfig, ServiceConfig,
                       WireMsg, dp_epsilon_schedule, make_client_schedule,
                       template_of)
from repro.fed.privacy import (binomial_trials, clip_counts,
                               discrete_gaussian, dp_mask_mode,
                               dp_noise_tree, eps_from_rdp, epsilon_after,
                               rdp_round, round_epsilons, sigma_normalized,
                               symmetric_binomial)
from repro.fed.service import serde
from repro.fed.service.runner import ServiceRunner
from repro.fed.service.server import Coordinator
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

KEY = jax.random.key(0)

# leaf sizes deliberately %32 != 0 so packed counts carry partial tails
TREE = {"w": jnp.zeros((33, 9)), "b": jnp.zeros((5,)),
        "deep": {"c": jnp.zeros((40, 7))}}
P = tree_num_params(TREE)

PRIV = PrivacyConfig(noise_multiplier=1.0, delta=1e-5)

R, C, K = 3, 8, 4


def _tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def _random_mask(key, mode, tree=TREE):
    vals = jax.tree_util.tree_map(
        lambda l: jax.random.bernoulli(key, 0.5, l.shape), tree)
    if mode == "signed":
        return jax.tree_util.tree_map(
            lambda m: (2 * m.astype(jnp.int8) - 1), vals)
    return jax.tree_util.tree_map(lambda m: m.astype(jnp.int8), vals)


def _stacked_msg(codec, mode, n_clients):
    masks = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[_random_mask(jax.random.key(i), mode) for i in range(n_clients)])
    payload = {"mask": masks}
    if codec.carries_seed:
        payload["seed"] = jnp.stack([client_round_key(0, 0, 0)] * n_clients)
    return codec.encode_stacked(payload)


def _slice_msg(msg, a, b):
    return WireMsg(msg.codec, {k: v[a:b] for k, v in msg.buffers.items()})


def _experiment(algorithm="fedmrn", rounds=R, trace=None, **cfg_kw):
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, C)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=C, clients_per_round=K,
                   rounds=rounds, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    return Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                     data=ds, config=cfg,
                                     eval_apply=mlp_apply,
                                     availability=trace))


# ---------------------------------------------------------------------------
# mechanisms: seeded, integer, the advertised moments
# ---------------------------------------------------------------------------

def test_symmetric_binomial_moments_and_determinism():
    n = 8
    z = symmetric_binomial(KEY, (40000,), n)
    assert z.dtype == jnp.int32
    x = np.asarray(z, np.float64)
    assert abs(x.mean()) < 0.05                     # centered at 0
    np.testing.assert_allclose(x.var(), n / 4.0, rtol=0.05)
    assert int(np.abs(x).max()) <= n // 2           # bounded support
    np.testing.assert_array_equal(
        np.asarray(symmetric_binomial(KEY, (40000,), n)), np.asarray(z))
    with pytest.raises(ValueError, match="even"):
        symmetric_binomial(KEY, (4,), 7)
    with pytest.raises(ValueError, match="even"):
        symmetric_binomial(KEY, (4,), 0)


def test_symmetric_binomial_masks_the_last_word():
    """n = 40 uses 2 uint32 words with only 8 live trials in the second:
    an unmasked tail would inflate the variance to 64/4."""
    z = np.asarray(symmetric_binomial(KEY, (40000,), 40), np.float64)
    np.testing.assert_allclose(z.var(), 10.0, rtol=0.05)


def test_discrete_gaussian_moments_and_determinism():
    sigma = 3.0
    z = discrete_gaussian(KEY, (40000,), sigma)
    assert z.dtype == jnp.int32
    x = np.asarray(z, np.float64)
    assert abs(x.mean()) < 0.08
    np.testing.assert_allclose(x.std(), sigma, rtol=0.05)
    np.testing.assert_array_equal(
        np.asarray(discrete_gaussian(KEY, (40000,), sigma)), np.asarray(z))
    with pytest.raises(ValueError, match="positive"):
        discrete_gaussian(KEY, (4,), 0.0)


def test_binomial_trials_never_under_noise():
    """n is rounded UP to even, so the realized σ_eff = √n/2 ≥ z·Δ₂ and
    the accountant's normalized scale is ≥ the configured multiplier."""
    for z in (0.3, 0.5, 1.0, 1.3, 2.7):
        for mode in ("binary", "signed"):
            for adj in ("client", "entry"):
                p = PrivacyConfig(mechanism="binomial",
                                  noise_multiplier=z, adjacency=adj)
                n = binomial_trials(p, mode, P)
                assert n >= 2 and n % 2 == 0
                assert math.sqrt(n) / 2.0 >= p.sigma(mode, P) - 1e-12
                assert sigma_normalized(p, mode, P) >= z - 1e-12


def test_dp_noise_tree_per_leaf_streams_differ():
    tree = dp_noise_tree(KEY, TREE, PRIV, "binary")
    again = dp_noise_tree(KEY, TREE, PRIV, "binary")
    _tree_equal(tree, again)                        # one key → one tree
    leaves = jax.tree_util.tree_leaves(tree)
    assert all(l.dtype == jnp.int32 for l in leaves)
    flat = [np.asarray(l).reshape(-1)[:5].tolist() for l in leaves]
    assert len({tuple(f) for f in flat}) == len(flat)   # fold_in(i) split


def test_clip_counts_bounds():
    x = {"a": jnp.asarray([-5, -1, 0, 1, 5], jnp.int32)}
    np.testing.assert_array_equal(
        np.asarray(clip_counts(x, 2, "binary")["a"]), [0, 0, 0, 1, 2])
    np.testing.assert_array_equal(
        np.asarray(clip_counts(x, 2, "signed")["a"]), [-2, -1, 0, 1, 2])


# ---------------------------------------------------------------------------
# config: validation, sensitivity, family support
# ---------------------------------------------------------------------------

def test_privacy_config_validation():
    PRIV.validate()                                  # the default is legal
    for bad in (PrivacyConfig(mechanism="laplace"),
                PrivacyConfig(noise_multiplier=0.0),
                PrivacyConfig(noise_multiplier=-1.0),
                PrivacyConfig(clip=0),
                PrivacyConfig(clip=1.5),
                PrivacyConfig(delta=0.0),
                PrivacyConfig(delta=1.0),
                PrivacyConfig(adjacency="user")):
        with pytest.raises(ValueError):
            bad.validate()


def test_sensitivity_binary_vs_signed():
    p = PrivacyConfig(clip=3)
    assert p.sensitivity("binary") == 3              # [0, c] per entry
    assert p.sensitivity("signed") == 6              # [−c, c] per entry
    assert p.sigma("signed", 1) == 6.0               # d=1: Δ₂ = Δ
    assert dp_mask_mode("fedmrns") == "signed"
    assert dp_mask_mode("fedmrn") == "binary"
    assert dp_mask_mode("fedpm") == "binary"


def test_vector_sensitivity_accounting():
    """REVIEW pin: the release is d-dimensional and the default
    adjacency protects a client's WHOLE mask — Δ₂ = Δ·√d, the σ the
    mechanism adds is z·Δ₂, and the accountant normalizes by Δ₂ (NOT
    the per-entry Δ, which would under-report ε by ~d in the RDP
    exponent)."""
    d = 641
    p = PrivacyConfig(noise_multiplier=1.5, clip=2)
    assert p.l2_sensitivity("binary", d) == pytest.approx(
        2.0 * math.sqrt(d))
    assert p.l2_sensitivity("signed", d) == pytest.approx(
        4.0 * math.sqrt(d))
    assert p.sigma("binary", d) == pytest.approx(3.0 * math.sqrt(d))
    # entry adjacency: Δ₂ = Δ, independent of d — the weaker opt-in
    e = dataclasses.replace(p, adjacency="entry")
    assert e.l2_sensitivity("binary", d) == 2.0
    assert e.sigma("binary", 10**6) == 3.0
    # discrete Gaussian: σ calibrated to z·Δ₂ → σ_n is exactly z for
    # ANY d and either adjacency (the noise, not the ε, pays for √d)
    for d_ in (1, 7, d):
        assert sigma_normalized(p, "binary", d_) == pytest.approx(1.5)
        assert sigma_normalized(e, "binary", d_) == pytest.approx(1.5)
    # binomial: realized σ_eff = √n/2 over the SAME Δ₂
    b = PrivacyConfig(mechanism="binomial", noise_multiplier=0.7)
    n = binomial_trials(b, "binary", d)
    assert sigma_normalized(b, "binary", d) == pytest.approx(
        math.sqrt(n) / 2.0 / math.sqrt(d))
    assert sigma_normalized(b, "binary", d) >= 0.7
    with pytest.raises(ValueError, match="num_params"):
        p.l2_sensitivity("binary", 0)


def test_dp_noise_magnitude_scales_with_vector_sensitivity():
    """The draw the codec actually adds realizes σ = z·Δ·√d under the
    default client adjacency, and σ = z·Δ under entry adjacency."""
    big = {"x": jnp.zeros((200, 50))}                   # d = 10_000
    z = np.asarray(dp_noise_tree(KEY, big, PrivacyConfig(), "binary")["x"],
                   np.float64)
    np.testing.assert_allclose(z.std(), 100.0, rtol=0.05)   # √d = 100
    ze = np.asarray(dp_noise_tree(
        KEY, big, PrivacyConfig(adjacency="entry"), "binary")["x"],
        np.float64)
    np.testing.assert_allclose(ze.std(), 1.0, rtol=0.05)


def test_family_support_guards():
    with pytest.raises(ValueError, match="count-aggregatable"):
        FLConfig(algorithm="fedavg", privacy=PRIV).validate()
    with pytest.raises(ValueError, match="count-aggregatable"):
        FLConfig(algorithm="signsgd", privacy=PRIV).validate()
    with pytest.raises(ValueError, match="shared_noise"):
        FLConfig(algorithm="fedmrn", privacy=PRIV).validate()
    FLConfig(algorithm="fedmrn", shared_noise=True,
             privacy=PRIV).validate()
    FLConfig(algorithm="fedmrns", shared_noise=True,
             privacy=PRIV).validate()
    FLConfig(algorithm="fedpm", privacy=PRIV).validate()


# ---------------------------------------------------------------------------
# accountant: composition, subsampling, dropout discounting
# ---------------------------------------------------------------------------

def test_epsilon_is_cumulative_and_finite():
    eps = round_epsilons(PRIV, [4] * 6, 8, "binary", P)
    assert np.all(np.isfinite(eps)) and np.all(eps > 0)
    assert np.all(np.diff(eps) > 0)                  # each round spends


def test_subsampling_amplifies():
    sub = round_epsilons(PRIV, [4] * 5, 8, "binary", P)
    full = round_epsilons(PRIV, [8] * 5, 8, "binary", P)
    assert np.all(sub < full)


def test_more_noise_less_epsilon():
    lo = round_epsilons(PrivacyConfig(noise_multiplier=0.5),
                        [4] * 5, 8, "binary", P)
    hi = round_epsilons(PrivacyConfig(noise_multiplier=2.0),
                        [4] * 5, 8, "binary", P)
    assert np.all(hi < lo)


def test_dropout_rounds_spend_less():
    clean = round_epsilons(PRIV, [4, 4, 4], 8, "binary", P)
    degraded = round_epsilons(PRIV, [4, 2, 4], 8, "binary", P)
    assert degraded[0] == clean[0]                   # same first round
    assert degraded[-1] < clean[-1]                  # q=2/8 < q=4/8
    assert epsilon_after(PRIV, [4, 2, 4], 8, "binary", P) == degraded[-1]
    assert epsilon_after(PRIV, [], 8, "binary", P) == math.inf


def test_binomial_accounted_at_realized_sigma():
    """z=1 binary client adjacency: σ² = d, so n = 4d exactly (even),
    σ_eff = √(4d)/2 = √d = σ — the binomial column must equal the
    discrete-Gaussian one."""
    b = round_epsilons(PrivacyConfig(mechanism="binomial"),
                       [4] * 4, 8, "binary", P)
    g = round_epsilons(PrivacyConfig(mechanism="discrete_gaussian"),
                       [4] * 4, 8, "binary", P)
    np.testing.assert_allclose(b, g, rtol=1e-12)


def test_accountant_input_validation():
    with pytest.raises(ValueError, match="sampling rate"):
        rdp_round(1.5, 1.0)
    with pytest.raises(ValueError, match="delta"):
        eps_from_rdp(np.zeros(3), 0.0, orders=(2, 3, 4))
    with pytest.raises(ValueError, match="num_clients"):
        round_epsilons(PRIV, [4], 0, "binary", P)
    with pytest.raises(ValueError, match="num_params"):
        round_epsilons(PRIV, [4], 8, "binary", 0)
    np.testing.assert_array_equal(rdp_round(0.0, 1.0),
                                  np.zeros(len(rdp_round(0.0, 1.0))))


# ---------------------------------------------------------------------------
# codec: noise exactly once, split/pool-order invariance
# ---------------------------------------------------------------------------

def _dp_codec(mode, count_dtype=None, privacy=PRIV, shared=True):
    kw = dict(noise=NoiseConfig(alpha=0.1), shared_noise=True) if shared \
        else dict(noise=None)
    return MaskCodec(template_of(TREE), name="m", mode=mode,
                     count_dtype=count_dtype, privacy=privacy, **kw)


@pytest.mark.parametrize("mode", ["binary", "signed"])
@pytest.mark.parametrize("count_dtype", [None, jnp.int8])
def test_dp_split_invariance(mode, count_dtype):
    """Full-stack aggregate ≡ any cohort split ≡ per-client pooling —
    the single per-round draw lands on the merged integers whichever way
    they arrive, including through an int8 count partial."""
    codec = _dp_codec(mode, count_dtype)
    n_clients = 6
    msg = _stacked_msg(codec, mode, n_clients)
    w = jnp.ones((n_clients,), jnp.float32)
    r = jnp.int32(2)
    full = codec.aggregate(msg, w, round_idx=r)
    for cuts in ((2, 6), (3, 6), (1, 2, 3, 4, 5, 6)):
        lo = 0
        parts = []
        for hi in cuts:
            parts.append(codec.partial_aggregate(
                _slice_msg(msg, lo, hi), w[lo:hi], round_idx=r))
            lo = hi
        out = codec.finalize_partial(reduce(codec.merge_partials, parts))
        _tree_equal(out, full)


def test_dp_noise_is_round_keyed_and_actually_applied():
    codec = _dp_codec("binary")
    plain = _dp_codec("binary", privacy=None)
    msg = _stacked_msg(codec, "binary", 6)
    w = jnp.ones((6,), jnp.float32)
    r0 = codec.aggregate(msg, w, round_idx=jnp.int32(0))
    r0_again = codec.aggregate(msg, w, round_idx=jnp.int32(0))
    _tree_equal(r0, r0_again)                        # deterministic draw
    r1 = codec.aggregate(msg, w, round_idx=jnp.int32(1))
    base = plain.aggregate(msg, w)
    diffs = jax.tree_util.tree_map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))), r0, r1)
    assert any(jax.tree_util.tree_leaves(diffs))     # fold_in(round) moves
    noised = jax.tree_util.tree_map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))), r0, base)
    assert any(jax.tree_util.tree_leaves(noised))    # noise ≠ identity


def test_dp_codec_guards():
    per_client = MaskCodec(template_of(TREE), name="m",
                           noise=NoiseConfig(alpha=0.1),
                           shared_noise=False, privacy=PRIV)
    msg = _stacked_msg(per_client, "binary", 3)
    w = jnp.ones((3,), jnp.float32)
    with pytest.raises(ValueError, match="count-aggregatable"):
        per_client.partial_aggregate(msg, w, round_idx=jnp.int32(0))
    shared = _dp_codec("binary")
    msg = _stacked_msg(shared, "binary", 3)
    with pytest.raises(ValueError, match="round_idx"):
        shared.partial_aggregate(msg, w)


def _clipped_count_property(mode, n, n_clients, clip, seed):
    """The packed popcount partial (with the signed 2c−K fixup baked into
    unpack) IS Σ_k clip_counts(m_k): one mask entry never exceeds the
    sensitivity bound, for any clip ≥ 1, any %32 tail length, on the ref
    and pallas-interpret backends bitwise alike."""
    tree = {"x": jnp.zeros((n,))}
    masks = [_random_mask(jax.random.fold_in(jax.random.key(seed), i),
                          mode, tree) for i in range(n_clients)]
    expected = np.zeros((n,), np.int64)
    for m in masks:
        contrib = np.asarray(clip_counts(m, clip, mode)["x"], np.int64)
        assert np.abs(contrib).max(initial=0) <= clip
        np.testing.assert_array_equal(contrib, np.asarray(m["x"]))
        expected += contrib
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *masks)
    outs = []
    for backend in ("ref", "pallas"):
        codec = MaskCodec(template_of(tree), name="m", mode=mode,
                          backend=backend,
                          privacy=PrivacyConfig(clip=clip))
        part = codec.partial_aggregate(
            codec.encode_stacked({"mask": stacked}),
            jnp.ones((n_clients,), jnp.float32), round_idx=jnp.int32(0))
        outs.append(np.asarray(part["counts"]["x"], np.int64))
        np.testing.assert_array_equal(outs[-1], expected)
    np.testing.assert_array_equal(outs[0], outs[1])


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(mode=st.sampled_from(["binary", "signed"]),
           n=st.integers(min_value=1, max_value=97),
           n_clients=st.integers(min_value=1, max_value=5),
           clip=st.integers(min_value=1, max_value=3),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_partial_counts_are_clipped_per_client_sums(mode, n, n_clients,
                                                        clip, seed):
        _clipped_count_property(mode, n, n_clients, clip, seed)

else:

    @pytest.mark.skip(reason="hypothesis missing — pinned in "
                             "requirements.txt; install to run "
                             "(REPRO_REQUIRE_HYPOTHESIS=1 raises instead)")
    def test_partial_counts_are_clipped_per_client_sums():
        pass


@pytest.mark.parametrize("mode", ["binary", "signed"])
def test_partial_counts_clip_property_pinned_cases(mode):
    """The property at a fixed grid (runs even without hypothesis)."""
    for n, n_clients, clip, seed in ((1, 1, 1, 0), (33, 3, 1, 1),
                                     (97, 5, 3, 2), (64, 4, 2, 3)):
        _clipped_count_property(mode, n, n_clients, clip, seed)


# ---------------------------------------------------------------------------
# engines: one DP release, five identical executions
# ---------------------------------------------------------------------------

def test_dp_parity_across_all_five_engines():
    """scan ≡ batched ≡ looped ≡ cohort ≡ service under privacy=: same
    accuracies (1e-6), same ε schedule, same measured wire bits — and
    the service report agrees with the in-process engines."""
    runs, service_report = {}, None
    for eng in ("scan", "batched", "looped", "cohort", "service"):
        exp = _experiment("fedmrn", shared_noise=True, privacy=PRIV)
        kw = {"cohort_size": 3} if eng == "cohort" else {}
        runs[eng] = exp.run(engine=eng, **kw)
        if eng == "service":
            service_report = exp.service_report
    ref = runs["scan"]
    assert all(math.isfinite(e) for e in ref.dp_epsilon)
    assert list(ref.dp_epsilon) == sorted(ref.dp_epsilon)
    expected = dp_epsilon_schedule(_experiment(
        "fedmrn", shared_noise=True, privacy=PRIV).cfg, [K] * R,
        ref.num_params)
    assert ref.dp_epsilon == expected[0]
    assert ref.dp_delta == expected[1] == PRIV.delta
    for eng, res in runs.items():
        np.testing.assert_allclose(np.asarray(res.acc),
                                   np.asarray(ref.acc), atol=1e-6,
                                   err_msg=f"engine={eng}")
        assert res.dp_epsilon == ref.dp_epsilon, eng
        assert res.dp_delta == ref.dp_delta, eng
        assert res.uplink_bits_round == ref.uplink_bits_round, eng
    assert service_report.dp_epsilon == ref.dp_epsilon
    assert service_report.dp_delta == ref.dp_delta
    assert service_report.comm.dp_epsilon == ref.dp_epsilon[-1]


def test_fedpm_dp_parity_scan_vs_looped():
    a = _experiment("fedpm", privacy=PRIV).run(engine="scan")
    b = _experiment("fedpm", privacy=PRIV).run(engine="looped")
    np.testing.assert_allclose(np.asarray(a.acc), np.asarray(b.acc),
                               atol=1e-6)
    assert a.dp_epsilon == b.dp_epsilon
    assert all(math.isfinite(e) for e in a.dp_epsilon)


def test_fedmrns_binomial_end_to_end():
    priv = PrivacyConfig(mechanism="binomial", noise_multiplier=1.0)
    res = _experiment("fedmrns", shared_noise=True,
                      privacy=priv).run(engine="scan")
    assert all(math.isfinite(e) for e in res.dp_epsilon)
    cfg = FLConfig(algorithm="fedmrns", num_clients=C,
                   clients_per_round=K, rounds=R, shared_noise=True,
                   privacy=priv)
    assert res.dp_epsilon == dp_epsilon_schedule(cfg, [K] * R,
                                                 res.num_params)[0]


def test_dropout_discounts_the_recorded_spend():
    """Degraded rounds are accounted at the SURVIVOR count the engine
    recorded, so the ε column matches dp_epsilon_schedule at the true
    participation — and never exceeds the clean schedule."""
    trace = AvailabilityTrace.bernoulli(3, rounds=R, num_clients=C,
                                        dropout=0.4)
    exp = _experiment("fedmrn", shared_noise=True, privacy=PRIV,
                      trace=trace)
    res = exp.run(engine="looped")
    assert sum(res.participation_round) < K * R     # the trace does drop
    assert res.dp_epsilon == dp_epsilon_schedule(
        exp.cfg, res.participation_round, res.num_params)[0]
    clean = dp_epsilon_schedule(exp.cfg, [K] * R, res.num_params)[0]
    assert res.dp_epsilon[-1] < clean[-1]


def test_disabled_path_reports_infinite_epsilon():
    res = _experiment("fedmrn", shared_noise=True).run(engine="scan")
    assert res.dp_epsilon == (math.inf,) * R
    assert res.dp_delta == 0.0
    hist = res.to_history()
    assert hist["dp_epsilon"] == [math.inf] * R
    assert hist["dp_delta"] == 0.0


# ---------------------------------------------------------------------------
# engine guards: configurations the count release cannot honour
# ---------------------------------------------------------------------------

def test_scan_and_batched_reject_dropout_under_privacy():
    trace = AvailabilityTrace.bernoulli(3, rounds=R, num_clients=C,
                                        dropout=0.4)
    for eng in ("scan", "batched"):
        with pytest.raises(ValueError, match="privacy"):
            _experiment("fedmrn", shared_noise=True, privacy=PRIV,
                        trace=trace).run(engine=eng)


def test_pod_round_rejects_privacy():
    from repro.fed.sharded import PodRoundSpec, make_pod_round
    cfg = FLConfig(algorithm="fedmrn", shared_noise=True, privacy=PRIV)
    with pytest.raises(ValueError, match="make_pod_round"):
        make_pod_round("fedmrn", None, PodRoundSpec(config=cfg),
                       loss_fn=None, p_specs=None, batch_specs=None)


def test_async_service_rejects_privacy():
    exp = _experiment("fedmrn", shared_noise=True, privacy=PRIV)
    with pytest.raises(ValueError, match="sync"):
        exp.run(engine="service", service=ServiceConfig(mode="async"))


# ---------------------------------------------------------------------------
# coordinator: (ε, δ) in /v1/metrics as rounds close
# ---------------------------------------------------------------------------

def _scripted_sync_coordinator(**cfg_kw):
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, C)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm="fedmrn", num_clients=C, clients_per_round=K,
                   rounds=R, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    runner = ServiceRunner(mlp_loss, cfg, params, ds,
                           eval_program=None, eval_every=1)
    schedule = make_client_schedule(cfg, cfg.seed)
    coord = Coordinator(
        codec=runner.codec, partial_fn=runner._partial,
        merge_fn=runner._merge, finalize_fn=runner._finalize,
        apply_fn=runner._apply, eval_fn=None, eval_rounds=(),
        params=params, state=runner._state0, schedule=schedule,
        seed=cfg.seed, service=ServiceConfig(mode="sync"),
        algorithm=cfg.algorithm, num_clients=cfg.num_clients)
    return runner, coord, schedule, cfg


def _post(runner, coord, r, slot, schedule):
    cid = int(schedule[r][slot])
    msg, agg_w, loss = runner._client_step(
        jnp.int32(coord.seed), coord.w, coord.state, jnp.int32(r),
        jnp.int32(cid), jnp.float32(1.0))
    body = serde.dumps_msg(msg, round=r, cid=cid, weight=float(agg_w),
                           loss=float(loss))
    return coord.handle_uplink(r, body)


def test_coordinator_metrics_report_cumulative_epsilon():
    runner, coord, schedule, cfg = _scripted_sync_coordinator(
        shared_noise=True, privacy=PRIV)
    m = coord.metrics()
    assert m["dp_epsilon_round"] == [None] * R       # nothing closed yet
    assert m["dp_delta"] == PRIV.delta
    expected = dp_epsilon_schedule(cfg, [K] * R,
                                   tree_num_params(coord.w))[0]
    for r in range(R):
        for slot in range(K):
            code, _ = _post(runner, coord, r, slot, schedule)
            assert code == 200
        col = coord.metrics()["dp_epsilon_round"]
        assert col[:r + 1] == pytest.approx(list(expected[:r + 1]))
        assert col[r + 1:] == [None] * (R - r - 1)
    assert coord.done


def test_coordinator_metrics_without_privacy_are_none():
    runner, coord, schedule, cfg = _scripted_sync_coordinator(
        shared_noise=True)
    m = coord.metrics()
    assert m["dp_epsilon_round"] is None
    assert m["dp_delta"] is None
