"""Fused mask-uplink kernel (ISSUE 6).

Asserts the fused ``mask_uplink`` pass ≡ the staged ``tree_psm-style
sample → tree_pack_stacked → tree_unpack_counts`` composition (packed
words, counts, aggregates, STE gradients) at lengths NOT divisible by
128 or 32, that ref ≡ pallas-interpret, that the fused program
materializes neither the mask tree nor an unpacked bit tensor outside
the kernel, and that fedmrn/fedpm codec trajectories are unchanged at a
fixed seed.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # hypothesis is a pinned requirement (requirements.txt); CI sets
    # REPRO_REQUIRE_HYPOTHESIS=1 so a missing install fails instead of
    # silently skipping the property tests.
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.core import mix_add, use_backend
from repro.core.masking import (tree_bernoulli_stacked, tree_mask_uplink,
                                tree_sample_mask_stacked)
from repro.core.packing import (tree_pack_stacked, tree_unpack_counts,
                                tree_unpack_counts_apply)
from repro.kernels.mask_uplink import ops as mops
from repro.kernels.psm_mask.ops import _psm_ste_core

KEY = jax.random.key(0)

# two-leaf tree with sizes divisible by neither 128 nor 32
LEAF_SHAPES = {"a": (47,), "b": (13, 7)}


def _stack_tree(key, K, scale=0.01):
    ks = jax.random.split(key, len(LEAF_SHAPES))
    return {name: scale * jax.random.normal(k, (K,) + shp)
            for k, (name, shp) in zip(ks, LEAF_SHAPES.items())}


def _template():
    return {name: jax.ShapeDtypeStruct(shp, jnp.float32)
            for name, shp in LEAF_SHAPES.items()}


def _flat(tree, K=None):
    leaves = jax.tree_util.tree_leaves(tree)
    if K is None:
        return np.concatenate([np.asarray(l).reshape(-1) for l in leaves])
    return np.concatenate(
        [np.asarray(l).reshape(K, -1) for l in leaves], axis=1)


# ---------------------------------------------------------------------------
# property: fused ≡ staged composition, ref ≡ pallas-interpret
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), K=st.integers(1, 9),
           mode=st.sampled_from(["binary", "signed"]))
    def test_fused_equals_staged_pipeline(seed, K, mode):
        key = jax.random.key(seed)
        ku, kn, kk = jax.random.split(key, 3)
        u = _stack_tree(ku, K)
        n = _stack_tree(kn, K)
        keys = jax.random.split(kk, K)
        w = jnp.linspace(0.5, 1.5, K)

        # the staged three-kernel pipeline on the ref backend
        masks = tree_sample_mask_stacked(u, n, keys, mode=mode)
        words_staged = tree_pack_stacked(masks, mode=mode, backend="ref")
        counts_staged = tree_unpack_counts(
            words_staged, _template(), mode=mode, dtype=jnp.int32,
            backend="ref")

        up_ref = tree_mask_uplink(u, n, keys, w, mode=mode, backend="ref")
        up_pal = tree_mask_uplink(u, n, keys, w, mode=mode,
                                  backend="pallas")

        # packed wire rows: all three bitwise equal
        np.testing.assert_array_equal(np.asarray(words_staged),
                                      np.asarray(up_ref.words))
        np.testing.assert_array_equal(np.asarray(up_ref.words),
                                      np.asarray(up_pal.words))
        # counts: exact integers on every route
        np.testing.assert_array_equal(_flat(counts_staged),
                                      np.asarray(up_ref.counts))
        np.testing.assert_array_equal(np.asarray(up_ref.counts),
                                      np.asarray(up_pal.counts))
        # Σ_k w_k n_k⊙m_k: fused vs staged masked-noise tensordot
        hat = jax.tree_util.tree_map(
            lambda nl, ml: nl * ml.astype(nl.dtype), n, masks)
        wsum_staged = jnp.tensordot(w, jnp.asarray(_flat(hat, K)), axes=1)
        np.testing.assert_allclose(np.asarray(up_ref.wsum),
                                   np.asarray(wsum_staged),
                                   rtol=2e-6, atol=1e-12)
        np.testing.assert_allclose(np.asarray(up_pal.wsum),
                                   np.asarray(up_ref.wsum),
                                   rtol=2e-6, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), K=st.integers(1, 6))
    def test_fused_prob_mode_equals_bernoulli_draw(seed, K):
        """FedPM: the fused prob-mode draw is the per-leaf Bernoulli."""
        key = jax.random.key(seed)
        kp, kk = jax.random.split(key)
        probs = jax.tree_util.tree_map(jax.nn.sigmoid, _stack_tree(kp, K))
        keys = jax.random.split(kk, K)
        masks = tree_bernoulli_stacked(probs, keys)
        words_staged = tree_pack_stacked(masks, backend="ref")
        for backend in ("ref", "pallas"):
            up = tree_mask_uplink(probs, None, keys, jnp.ones((K,)),
                                  probs=True, wsum_values=False,
                                  backend=backend)
            np.testing.assert_array_equal(np.asarray(words_staged),
                                          np.asarray(up.words))
            np.testing.assert_array_equal(
                np.asarray(up.counts),
                _flat(masks, K).astype(np.int32).sum(axis=0))


@pytest.mark.parametrize("mode", ["binary", "signed"])
@pytest.mark.parametrize("gated", [True, False])
def test_ste_gradients_bitwise(mode, gated):
    """Fused STE ≡ the psm_mask STE rule, cotangent for cotangent."""
    K, P = 5, 333
    ku, kn, ks, kp = jax.random.split(KEY, 4)
    u = 0.01 * jax.random.normal(ku, (K, P))
    n = 0.01 * jax.random.normal(kn, (K, P))
    r_sm = jax.random.uniform(ks, (K, P))
    r_pm = jax.random.uniform(kp, (K, P)) if gated else None
    prog = 0.6 if gated else None
    cot = jnp.sin(jnp.arange(P, dtype=jnp.float32))

    def f_fused(uu):
        out = mops.mask_uplink_ste(uu, n, r_sm, r_pm, prog, mode=mode)
        return jnp.sum(out.uhat * cot)

    g_fused = jax.grad(f_fused)(u)
    if gated:
        def f_staged(uu):
            uh = _psm_ste_core(uu, n, r_sm, r_pm, jnp.float32(prog),
                               mode, True)
            return jnp.sum(uh * cot)
        g_staged = jax.grad(f_staged)(u)
        np.testing.assert_array_equal(np.asarray(g_fused),
                                      np.asarray(g_staged))
    else:   # progress ≡ 1: pure straight-through, ∂û/∂u = 1
        np.testing.assert_array_equal(
            np.asarray(g_fused),
            np.broadcast_to(np.asarray(cot), (K, P)))


# ---------------------------------------------------------------------------
# the acceptance criterion: the fused program materializes neither the
# mask tree nor the unpacked bit tensor outside the kernel
# ---------------------------------------------------------------------------

def _intermediate_avals(jaxpr, out):
    """All eqn-output avals, recursing into call jaxprs but NOT into the
    pallas_call kernel body (whose VMEM-staged refs are the point)."""
    for eqn in jaxpr.eqns:
        if "pallas" in eqn.primitive.name:
            for v in eqn.outvars:
                out.append(v.aval)
            continue
        for v in eqn.outvars:
            out.append(v.aval)
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", None)
            if inner is not None:
                _intermediate_avals(inner, out)
    return out


def _mask_sized_bit_avals(fn, *args):
    """Avals that look like a materialized mask/bit tensor: a bool/int8
    buffer at least as large as the (K, P) mask stack."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    K, P = args[0].shape
    avals = _intermediate_avals(jaxpr.jaxpr, [])
    return [a for a in avals
            if getattr(a, "dtype", None) in (jnp.bool_, jnp.int8)
            and np.prod(a.shape) >= K * P]


def test_fused_path_materializes_no_mask_or_bit_tensor():
    K, P = 8, 4096
    ku, kn, ks = jax.random.split(KEY, 3)
    u = jax.random.normal(ku, (K, P))
    n = jax.random.normal(kn, (K, P))
    r = jax.random.uniform(ks, (K, P))
    w = jnp.ones((K,))

    def fused(u, n, r, w):
        return mops.mask_uplink_fused(u, n, r, None, None, w,
                                      use_pallas=True)

    def staged(u, n, r, w):
        m = (r < jnp.clip(u / n, 0, 1)).astype(jnp.int8)   # mask tree
        from repro.core.packing import pack_rows, unpack_rows
        words = pack_rows(m, backend="ref")
        bits = unpack_rows(words, P, backend="ref")        # 32× words
        return words, jnp.sum(bits, axis=0, dtype=jnp.int32)

    assert _mask_sized_bit_avals(fused, u, n, r, w) == []
    # positive control: the staged pipeline DOES materialize them
    assert len(_mask_sized_bit_avals(staged, u, n, r, w)) >= 2


# ---------------------------------------------------------------------------
# server side: counts + fused apply
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["binary", "signed"])
def test_counts_and_apply_parity(mode):
    K = 6
    ku, kn, kk, kw = jax.random.split(KEY, 4)
    u = _stack_tree(ku, K)
    n = _stack_tree(kn, K)
    keys = jax.random.split(kk, K)
    masks = tree_sample_mask_stacked(u, n, keys, mode=mode)
    words = tree_pack_stacked(masks, mode=mode, backend="ref")

    with use_backend("ref"):
        c_ref = tree_unpack_counts(words, _template(), mode=mode,
                                   dtype=jnp.int32)
    with use_backend("pallas"):
        c_pal = tree_unpack_counts(words, _template(), mode=mode,
                                   dtype=jnp.int32)
    np.testing.assert_array_equal(_flat(c_ref), _flat(c_pal))

    noise = {k: 0.01 * jax.random.normal(jax.random.fold_in(kn, i), s)
             for i, (k, s) in enumerate(LEAF_SHAPES.items())}
    params = {k: jax.random.normal(jax.random.fold_in(kw, i), s)
              for i, (k, s) in enumerate(LEAF_SHAPES.items())}
    scale = 0.25

    def composed(words):
        with use_backend("ref"):
            counts = tree_unpack_counts(words, _template(), mode=mode,
                                        dtype=jnp.int32)
        agg = jax.tree_util.tree_map(
            lambda nl, cl: nl * (scale * cl.astype(jnp.float32)),
            noise, counts)
        return jax.tree_util.tree_map(mix_add, params, agg)

    def fused(words, backend):
        return tree_unpack_counts_apply(words, noise, params, scale,
                                        mode=mode, backend=backend)

    want = jax.jit(composed)(words)
    for backend in ("ref", "pallas"):
        got = jax.jit(lambda w_, b=backend: fused(w_, b))(words)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-6, atol=1e-12),
            want, got)
    # ref and pallas-interpret agree bitwise under jit
    g_ref = jax.jit(lambda w_: fused(w_, "ref"))(words)
    g_pal = jax.jit(lambda w_: fused(w_, "pallas"))(words)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        g_ref, g_pal)


# ---------------------------------------------------------------------------
# codec level: uplink_stacked ≡ encode_stacked + aggregate
# ---------------------------------------------------------------------------

def _codec(mode="binary", shared=False, count_dtype=None, noise=True,
           normalize=True):
    from repro.core import NoiseConfig
    from repro.fed.codecs import MaskCodec
    return MaskCodec(
        _template(), name="t", mode=mode,
        noise=NoiseConfig(dist="uniform", alpha=1e-2) if noise else None,
        shared_noise=shared, normalize=normalize, count_dtype=count_dtype)


@pytest.mark.parametrize("mode", ["binary", "signed"])
@pytest.mark.parametrize("variant", ["per_client", "shared", "shared_int",
                                     "fedpm"])
def test_codec_uplink_stacked_matches_legacy(mode, variant):
    K = 4
    ku, kk, ks = jax.random.split(KEY, 3)
    u = _stack_tree(ku, K)
    mask_keys = jax.random.split(kk, K)
    if variant == "shared_int":
        codec = _codec(mode, shared=True, count_dtype=jnp.int8)
        weights = jnp.ones((K,))
    elif variant == "shared":
        codec = _codec(mode, shared=True)
        weights = jnp.linspace(0.5, 1.5, K)
    elif variant == "fedpm":
        if mode == "signed":
            pytest.skip("fedpm is binary-only")
        codec = _codec(noise=False, normalize=False)
        weights = jnp.ones((K,))
    else:
        codec = _codec(mode)
        weights = jnp.linspace(0.5, 1.5, K)

    probs = variant == "fedpm"
    if probs:
        scores = jax.tree_util.tree_map(jax.nn.sigmoid, u)
        seed_keys = None
    else:
        scores = u
        one = jax.random.fold_in(ks, 0)
        seed_keys = (jnp.broadcast_to(one, (K,)) if variant != "per_client"
                     else jax.random.split(ks, K))

    def run(backend):
        with use_backend(backend):
            return codec.uplink_stacked(scores, seed_keys, mask_keys,
                                        weights, probs=probs)

    msg_ref, agg_ref = jax.jit(lambda: run("ref"))()
    msg_pal, agg_pal = jax.jit(lambda: run("pallas"))()

    # legacy composition on the ref route
    legacy_msg, legacy_agg = None, None
    with use_backend("ref"):
        if probs:
            masks = tree_bernoulli_stacked(scores, mask_keys)
            legacy_msg = codec.encode_stacked({"mask": masks})
        else:
            from repro.core import gen_noise
            noise = jax.vmap(
                lambda k: gen_noise(k, codec.template, codec.noise)
            )(seed_keys)
            masks = tree_sample_mask_stacked(scores, noise, mask_keys,
                                             mode=mode)
            legacy_msg = codec.encode_stacked(
                {"mask": masks, "seed": seed_keys})
        legacy_agg = codec.aggregate(legacy_msg, weights)

    np.testing.assert_array_equal(
        np.asarray(legacy_msg.buffers["words"]),
        np.asarray(msg_ref.buffers["words"]))
    np.testing.assert_array_equal(
        np.asarray(msg_ref.buffers["words"]),
        np.asarray(msg_pal.buffers["words"]))
    for a, b, exact in ((legacy_agg, agg_ref, True),
                        (agg_ref, agg_pal, False)):
        jax.tree_util.tree_map(
            lambda x, y: (np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y)) if exact else
                np.testing.assert_allclose(
                    np.asarray(x), np.asarray(y), rtol=2e-6, atol=1e-9)),
            a, b)


# ---------------------------------------------------------------------------
# end to end: fedmrn/fedpm trajectories, fused (pallas) vs staged (ref)
# ---------------------------------------------------------------------------

def _tiny_experiment(algorithm, **cfg_kw):
    from repro.data import (make_federated_dataset, make_image_task,
                            make_partition)
    from repro.fed import FLConfig, run_federated
    from repro.models.cnn import mlp_eval_program, mlp_init, mlp_loss
    task = make_image_task(0, n=320, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 6)
    params = mlp_init(KEY, d_in=64, d_hidden=16, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=6, clients_per_round=3,
                   rounds=3, local_steps=3, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7)
    eval_prog = mlp_eval_program(jnp.asarray(task.x), jnp.asarray(task.y))
    return mlp_loss, params, ds, eval_prog, cfg


@pytest.mark.parametrize("algorithm,cfg_kw", [
    ("fedmrn", {}),
    ("fedmrns", {}),
    ("fedmrn", {"shared_noise": True, "int_mask_agg": True}),
    ("fedpm", {}),
])
def test_trajectory_fused_equals_staged(algorithm, cfg_kw):
    """Fixed-seed trajectories through MaskCodec: pallas (fused kernel)
    ≡ ref (the staged legacy composition)."""
    from repro.fed import run_federated
    loss_fn, params, ds, eval_prog, cfg = _tiny_experiment(
        algorithm, **cfg_kw)
    hist = {}
    for backend in ("ref", "pallas"):
        with use_backend(backend):
            hist[backend] = run_federated(
                loss_fn, params, ds, None, cfg, eval_program=eval_prog,
                engine="scan", chunk=3)
    np.testing.assert_allclose(hist["ref"]["acc"], hist["pallas"]["acc"],
                               atol=1e-6)
    np.testing.assert_allclose(hist["ref"]["local_loss"],
                               hist["pallas"]["local_loss"], atol=1e-5)


# ---------------------------------------------------------------------------
# compiled mode (real TPU only — auto-skipped elsewhere via the marker)
# ---------------------------------------------------------------------------

@pytest.mark.tpu
def test_compiled_kernel_matches_oracle():
    K, P = 8, 8192
    ku, kn, ks = jax.random.split(KEY, 3)
    u = 0.01 * jax.random.normal(ku, (K, P))
    n = 0.01 * jax.random.normal(kn, (K, P))
    r = jax.random.uniform(ks, (K, P))
    w = jnp.ones((K,))
    ref = mops.mask_uplink_fused(u, n, r, None, None, w, use_pallas=False)
    pal = mops.mask_uplink_fused(u, n, r, None, None, w, use_pallas=True,
                                 interpret=False)
    np.testing.assert_array_equal(np.asarray(ref.words),
                                  np.asarray(pal.words))
    np.testing.assert_array_equal(np.asarray(ref.counts),
                                  np.asarray(pal.counts))
    np.testing.assert_allclose(np.asarray(ref.wsum), np.asarray(pal.wsum),
                               rtol=2e-6, atol=1e-12)
