"""Batched round engine: parity with the looped reference + backend
dispatch bitwise equivalence (ISSUE 1 acceptance tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NoiseConfig, gen_noise
from repro.core import masking, packing
from repro.data import make_image_task, make_partition, sample_local_batches
from repro.fed import FLConfig, run_federated
from repro.models.cnn import mlp_accuracy, mlp_init, mlp_loss

KEY = jax.random.key(0)


def _setup_fl(algorithm, rounds=5, error_feedback=False):
    task = make_image_task(0, n=1000, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=8, clients_per_round=4,
                   rounds=rounds, local_steps=6, batch_size=32, lr=0.1,
                   noise_alpha=3e-2, error_feedback=error_feedback)

    def batch_fn(rnd, cid):
        return sample_local_batches(rnd * 100 + cid, task.x, task.y,
                                    parts[cid], steps=cfg.local_steps,
                                    batch=cfg.batch_size)

    def eval_fn(p):
        return float(mlp_accuracy(p, jnp.asarray(task.x),
                                  jnp.asarray(task.y)))

    return mlp_loss, params, batch_fn, eval_fn, cfg


# ---------------------------------------------------------------------------
# batched engine ≡ looped reference at fixed seed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["fedmrn", "fedavg", "fedmrns"])
def test_batched_matches_looped_trajectory(algorithm):
    """The single-XLA-program round reproduces the seed's looped engine."""
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl(algorithm)
    hb = run_federated(loss_fn, params, batch_fn, eval_fn, cfg,
                       engine="batched")
    hl = run_federated(loss_fn, params, batch_fn, eval_fn, cfg,
                       engine="looped")
    np.testing.assert_allclose(hb["acc"], hl["acc"], atol=1e-6)
    np.testing.assert_allclose(hb["local_loss"], hl["local_loss"],
                               atol=1e-5)
    assert hb["uplink_bits_per_client"] == hl["uplink_bits_per_client"]


def test_batched_matches_looped_when_steps_differ_from_config():
    """Mask keys derive from the REAL batch step count, so parity holds
    even when client_batch_fn ignores cfg.local_steps (regression)."""
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl("fedmrn", rounds=3)
    task_steps = cfg.local_steps - 2          # 4 steps vs local_steps=6

    def short_batch_fn(rnd, cid):
        full = batch_fn(rnd, cid)
        return jax.tree_util.tree_map(lambda x: x[:task_steps], full)

    hb = run_federated(loss_fn, params, short_batch_fn, eval_fn, cfg,
                       engine="batched")
    hl = run_federated(loss_fn, params, short_batch_fn, eval_fn, cfg,
                       engine="looped")
    np.testing.assert_allclose(hb["acc"], hl["acc"], atol=1e-6)


def test_batched_error_feedback_runs():
    """EF residual state is gathered/scattered per round without breaking."""
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl(
        "fedmrn", error_feedback=True)
    hist = run_federated(loss_fn, params, batch_fn, eval_fn, cfg)
    assert np.isfinite(hist["final_acc"])
    assert hist["final_acc"] > 0.4


def test_round_program_single_dispatch():
    """One jitted program per round: round_fn traces once, losses stay on
    device (no per-client float sync inside a round)."""
    from repro.fed.engine import make_round_engine, stack_client_batches
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl("fedmrn", rounds=2)
    traces = []

    def counting_loss(p, b):
        traces.append(1)
        return loss_fn(p, b)

    round_fn, state = make_round_engine(counting_loss, cfg, params)
    K = cfg.clients_per_round
    batches = stack_client_batches([batch_fn(0, c) for c in range(K)])
    picked = jnp.arange(K, dtype=jnp.int32)
    weights = jnp.ones((K,), jnp.float32)
    for rnd in range(2):
        w, state, losses, wire_bits = round_fn(params, state, batches,
                                               picked, jnp.int32(rnd),
                                               weights)
    # vmap traces the per-client body ONCE per grad pass, not K times —
    # and round 2 reuses the compiled program (no retrace)
    assert len(traces) <= 4, f"loss_fn traced {len(traces)} times"
    assert isinstance(losses, jax.Array)
    assert losses.shape == (K, cfg.local_steps)
    # the 4th output is the round's measured K-client wire cost
    from repro.fed import algorithm_codec
    codec = algorithm_codec(cfg, params)
    assert float(wire_bits) == K * codec.wire_bits(params).uplink_bits


# ---------------------------------------------------------------------------
# backend dispatch: pallas (interpret) ≡ ref, bitwise
# ---------------------------------------------------------------------------

class TestBackendDispatch:
    def setup_method(self):
        self.tree = {"w": jnp.zeros((33, 9)), "b": jnp.zeros((4,)),
                     "deep": {"c": jnp.zeros((200, 30))}}
        self.noise = gen_noise(KEY, self.tree, NoiseConfig())
        self.u = jax.tree_util.tree_map(lambda n: 0.5 * n, self.noise)

    @pytest.mark.parametrize("mode", ["binary", "signed"])
    @pytest.mark.parametrize("progress", [0.0, 0.4, 1.0])
    def test_tree_psm_bitwise(self, mode, progress):
        ref = masking.tree_psm(self.u, self.noise, KEY, progress=progress,
                               mode=mode, backend="ref")
        pal = masking.tree_psm(self.u, self.noise, KEY, progress=progress,
                               mode=mode, backend="pallas")
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), ref, pal)

    def test_tree_psm_gradient_bitwise(self):
        """The pallas path's custom VJP equals the ref autodiff exactly."""

        def grad_of(backend):
            def f(u):
                out = masking.tree_psm(u, self.noise, KEY, progress=0.4,
                                       mode="binary", backend=backend)
                return sum(jnp.sum(l ** 2)
                           for l in jax.tree_util.tree_leaves(out))
            return jax.grad(f)(self.u)

        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)),
            grad_of("ref"), grad_of("pallas"))

    @pytest.mark.parametrize("mode", ["binary", "signed"])
    def test_tree_pack_bitwise(self, mode):
        m = masking.tree_sample_mask(self.u, self.noise, KEY, mode=mode)
        w_ref = packing.tree_pack(m, mode=mode, backend="ref")
        w_pal = packing.tree_pack(m, mode=mode, backend="pallas")
        np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_pal))
        m_ref = packing.tree_unpack(w_ref, self.tree, mode=mode,
                                    backend="ref")
        m_pal = packing.tree_unpack(w_pal, self.tree, mode=mode,
                                    backend="pallas")
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), m_ref, m_pal)

    def test_stacked_pack_matches_per_client(self):
        """tree_pack_stacked row k == tree_pack of client k's mask."""
        m = masking.tree_sample_mask(self.u, self.noise, KEY, mode="binary")
        K = 3
        stacked = jax.tree_util.tree_map(
            lambda l: jnp.stack([l] * K), m)
        for backend in ("ref", "pallas"):
            words = packing.tree_pack_stacked(stacked, backend=backend)
            single = packing.tree_pack(m, backend=backend)
            assert words.shape == (K, single.shape[0])
            for k in range(K):
                np.testing.assert_array_equal(np.asarray(words[k]),
                                              np.asarray(single))
            rec = packing.tree_unpack_stacked(words, self.tree,
                                              backend=backend)
            jax.tree_util.tree_map(
                lambda a, b: np.testing.assert_array_equal(
                    np.asarray(a[0]), np.asarray(b)), rec, m)

    def test_backend_auto_resolution(self):
        from repro.core import backend as be
        assert be.resolve_backend("ref") == "ref"
        assert be.resolve_backend("pallas") == "pallas"
        assert be.resolve_backend(None) in be.BACKENDS
        with be.use_backend("pallas"):
            assert be.resolve_backend(None) == "pallas"
        with pytest.raises(ValueError):
            be.resolve_backend("cuda")


def test_batched_engine_pallas_backend_end_to_end():
    """A full fedmrn round with backend='pallas' (interpret on CPU) matches
    backend='ref' exactly — the kernels really are the hot path."""
    loss_fn, params, batch_fn, eval_fn, cfg = _setup_fl("fedmrn", rounds=2)
    h_ref = run_federated(loss_fn, params, batch_fn, eval_fn,
                          dataclasses.replace(cfg, backend="ref"))
    h_pal = run_federated(loss_fn, params, batch_fn, eval_fn,
                          dataclasses.replace(cfg, backend="pallas"))
    np.testing.assert_allclose(h_ref["acc"], h_pal["acc"], atol=1e-7)
    np.testing.assert_allclose(h_ref["local_loss"], h_pal["local_loss"],
                               atol=1e-6)
