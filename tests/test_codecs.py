"""Typed uplink codecs (ISSUE 5): encode→decode roundtrips, aggregate
semantics (incl. the integer mask-count path), measured wire accounting
vs the legacy estimates, the codec= registration contract, and the
pack→unpack hypothesis property (ref ≡ pallas-interpret bitwise)."""
import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # hypothesis is a pinned requirement (requirements.txt) and the
    # property tests are tier-1 in CI: REPRO_REQUIRE_HYPOTHESIS=1 there
    # makes a missing install a hard failure instead of a skip.
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "") not in ("", "0"):
        raise
    HAVE_HYPOTHESIS = False

from repro.core import (NoiseConfig, client_round_key, fedmrn_record,
                        gen_noise, tree_num_params)
from repro.core.packing import pack_rows, tree_unpack_counts, unpack_rows
from repro.fed import (ALGORITHMS, Algorithm, DenseCodec, MaskCodec,
                       QuantCodec, SignCodec, SparseCodec, WireMsg, FLConfig,
                       algorithm_codec, mask_count_bits, min_count_dtype,
                       register_algorithm, template_of, uplink_bits)

KEY = jax.random.key(0)

TREE = {"w": jnp.zeros((33, 9)), "b": jnp.zeros((5,)),
        "deep": {"c": jnp.zeros((40, 7))}}
P = tree_num_params(TREE)


def _tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def _random_mask(key, mode):
    vals = jax.tree_util.tree_map(
        lambda l: jax.random.bernoulli(key, 0.5, l.shape), TREE)
    if mode == "signed":
        return jax.tree_util.tree_map(
            lambda m: (2 * m.astype(jnp.int8) - 1), vals)
    return jax.tree_util.tree_map(lambda m: m.astype(jnp.int8), vals)


# ---------------------------------------------------------------------------
# encode → decode roundtrips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["binary", "signed"])
def test_mask_codec_roundtrip(mode):
    codec = MaskCodec(template_of(TREE), name="m", mode=mode,
                      noise=NoiseConfig())
    mask = _random_mask(KEY, mode)
    seed = client_round_key(3, 1, 2)
    msg = codec.encode({"mask": mask, "seed": seed})
    assert set(msg.buffers) == {"words", "seed"}
    assert msg.buffers["seed"].size * 32 == 64      # the 64-bit seed
    out = codec.decode(msg)
    _tree_equal(out["mask"], mask)
    np.testing.assert_array_equal(jax.random.key_data(out["seed"]),
                                  jax.random.key_data(seed))


def test_dense_codec_roundtrip_and_bits():
    codec = DenseCodec(template_of(TREE), name="d")
    value = gen_noise(KEY, TREE, NoiseConfig(alpha=1.0))
    msg = codec.encode({"value": value})
    assert msg.bits == 32 * P                        # f32 passthrough
    _tree_equal(codec.decode(msg)["value"], value)


def test_sign_codec_roundtrip():
    """decode(encode(u)) == mean|u| · sign(u) — encode IS signSGD."""
    codec = SignCodec(template_of(TREE), name="s")
    u = gen_noise(KEY, TREE, NoiseConfig(alpha=1.0))
    out = codec.decode(codec.encode({"value": u}))["value"]
    expected = jax.tree_util.tree_map(
        lambda l: jnp.mean(jnp.abs(l)) * jnp.where(l > 0, 1.0, -1.0), u)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), rtol=1e-6),
        out, expected)


def test_sparse_codec_roundtrip_on_sparse_input():
    """A tree with ≤ k nonzeros per leaf decodes back exactly."""
    codec = SparseCodec(template_of(TREE), name="k", frac=0.1)
    dense = gen_noise(KEY, TREE, NoiseConfig(alpha=1.0))

    def keep_topk(l, frac=0.1):
        flat = jnp.abs(l).reshape(-1)
        k = max(1, int(np.ceil(frac * flat.shape[0])))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(l) >= thresh, l, 0.0)

    sparse = jax.tree_util.tree_map(keep_topk, dense)
    out = codec.decode(codec.encode({"value": sparse}))["value"]
    _tree_equal(out, sparse)
    # measured: 32-bit value + 32-bit index per kept element
    ks = [max(1, int(np.ceil(0.1 * (np.prod(l.shape) or 1))))
          for l in jax.tree_util.tree_leaves(TREE)]
    assert codec.encode({"value": sparse}).bits == 64 * sum(ks)


def test_encode_stacked_rows_match_per_client():
    """Stacked encoding (one kernel launch) row k == client k's encode."""
    codec = MaskCodec(template_of(TREE), name="m", noise=NoiseConfig())
    K = 3
    masks = [_random_mask(jax.random.key(i), "binary") for i in range(K)]
    seeds = [client_round_key(0, 0, i) for i in range(K)]
    stacked = codec.encode_stacked({
        "mask": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *masks),
        "seed": jnp.stack(seeds)})
    for k in range(K):
        single = codec.encode({"mask": masks[k], "seed": seeds[k]})
        np.testing.assert_array_equal(
            np.asarray(stacked.buffers["words"][k]),
            np.asarray(single.buffers["words"]))
    assert stacked.bits == K * single.bits


# ---------------------------------------------------------------------------
# aggregate semantics — incl. the ⌈log2(K+1)⌉-bit integer count path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["binary", "signed"])
def test_mask_count_aggregate_matches_f32_path(mode):
    """Integer-dtype count aggregation ≡ the f32 weighted sum (shared
    noise), for binary and signed masks."""
    noise_cfg = NoiseConfig(alpha=0.1)
    K = 8
    masks = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[_random_mask(jax.random.key(i), mode) for i in range(K)])
    seed = client_round_key(0, 0, 0)
    seeds = jnp.stack([seed] * K)
    weights = jnp.ones((K,), jnp.float32)
    mk = lambda dt: MaskCodec(template_of(TREE), name="m", mode=mode,
                              noise=noise_cfg, shared_noise=True,
                              count_dtype=dt)
    msg = mk(None).encode_stacked({"mask": masks, "seed": seeds})
    f32 = mk(None).aggregate(msg, weights)
    i8 = mk(min_count_dtype(K)).aggregate(msg, weights)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-7), f32, i8)


def test_tree_unpack_counts_dtype_and_values():
    K = 5
    bits = jax.random.bernoulli(KEY, 0.5, (K, 70))
    words = pack_rows(bits.astype(jnp.int8))
    like = {"a": jnp.zeros((70,))}
    counts = tree_unpack_counts(words, like, dtype=jnp.int8)
    assert counts["a"].dtype == jnp.int8
    np.testing.assert_array_equal(
        np.asarray(counts["a"]),
        np.asarray(jnp.sum(bits, axis=0)).astype(np.int8))


def test_mask_count_bits_and_min_dtype():
    assert mask_count_bits(1) == 1
    assert mask_count_bits(7) == 3
    assert mask_count_bits(8) == 4          # ⌈log2(9)⌉
    assert mask_count_bits(8, signed=True) == 5
    assert min_count_dtype(8) == jnp.int8
    assert min_count_dtype(127) == jnp.int8
    assert min_count_dtype(128) == jnp.int16
    assert min_count_dtype(40000) == jnp.int32
    with pytest.raises(ValueError):
        mask_count_bits(0)


def test_per_client_noise_aggregate_regenerates_from_wire_seeds():
    """Eq. (5): the server update comes entirely off the wire — masks
    from the packed words, noise regenerated from the shipped seeds."""
    noise_cfg = NoiseConfig(alpha=0.1)
    codec = MaskCodec(template_of(TREE), name="m", noise=noise_cfg)
    K = 4
    masks = [_random_mask(jax.random.key(i), "binary") for i in range(K)]
    seeds = [client_round_key(0, 2, i) for i in range(K)]
    msg = codec.encode_stacked({
        "mask": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *masks),
        "seed": jnp.stack(seeds)})
    weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    agg = codec.aggregate(msg, weights)
    wn = np.asarray(weights) / np.sum(np.asarray(weights))
    expected = jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape), TREE)
    for k in range(K):
        nz = gen_noise(seeds[k], TREE, noise_cfg)
        expected = jax.tree_util.tree_map(
            lambda e, n, m: e + wn[k] * n * m.astype(jnp.float32),
            expected, nz, masks[k])
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6), agg, expected)


# ---------------------------------------------------------------------------
# measured wire accounting vs the legacy estimates (satellite)
# ---------------------------------------------------------------------------

def test_fedmrn_record_matches_mask_codec_measurement():
    """comm.fedmrn_record (one 64-bit per-client seed, word-padded
    masks) == what MaskCodec measures from its encoded buffers."""
    codec = MaskCodec(template_of(TREE), name="fedmrn",
                      noise=NoiseConfig())
    rec = codec.wire_bits(TREE)
    legacy = fedmrn_record(P)
    assert rec.uplink_bits == legacy.uplink_bits == 32 * ((P + 31) // 32) + 64
    assert rec.uplink_bits_paper == legacy.uplink_bits_paper == P
    assert rec.downlink_bits == legacy.downlink_bits == 32 * P
    row = rec.row()
    assert {"uplink_bpp", "uplink_bpp_paper", "downlink_bits"} <= set(row)


def test_fedpm_measured_differs_from_legacy_estimate():
    """uplink_bits is MEASURED (word-padded packed buffer), not the old
    P + 32·L signsgd-style estimate."""
    cfg = FLConfig(algorithm="fedpm")
    bits = uplink_bits(cfg, TREE)
    L = len(jax.tree_util.tree_leaves(TREE))
    assert bits == 32 * ((P + 31) // 32)             # packed words only
    assert bits != P + 32 * L                        # the old estimate


def test_experiment_codec_types():
    for name, cls in [("fedmrn", MaskCodec), ("fedmrns", MaskCodec),
                      ("fedpm", MaskCodec), ("fedavg", DenseCodec),
                      ("signsgd", SignCodec), ("topk", SparseCodec),
                      ("fedsparsify", SparseCodec), ("qsgd", QuantCodec),
                      ("terngrad", QuantCodec)]:
        codec = algorithm_codec(FLConfig(algorithm=name), TREE)
        assert isinstance(codec, cls), name
    # quantizers ship REAL integer wire buffers (no baseline record):
    # measured bits = the tightly bit-packed field words + one f32 scale
    # per leaf; the paper-style figure stays b·P / log2(3)·P
    L = len(jax.tree_util.tree_leaves(TREE))
    qs = algorithm_codec(FLConfig(algorithm="qsgd", qsgd_bits=2), TREE)
    assert qs.record is None and qs.levels == 3       # 2^b - 1
    rec = qs.wire_bits(TREE)
    assert rec.uplink_bits == 32 * ((3 * P + 31) // 32) + 32 * L
    assert rec.uplink_bits_paper == 2 * P
    tg = algorithm_codec(FLConfig(algorithm="terngrad"), TREE)
    assert tg.levels == 1
    rec = tg.wire_bits(TREE)
    assert rec.uplink_bits == 32 * ((2 * P + 31) // 32) + 32 * L
    assert rec.uplink_bits_paper == int(math.log2(3) * P)


# ---------------------------------------------------------------------------
# the codec= registration contract (the derivation shim is GONE)
# ---------------------------------------------------------------------------

def test_algorithm_has_no_deprecated_wire_fields():
    """`uplink_record`/`uplink_kind` were removed with the make_codec
    shim — a plugin passing them must fail loudly at construction, not
    silently lose its cost report."""
    fields = {f.name for f in dataclasses.fields(Algorithm)}
    assert "uplink_record" not in fields and "uplink_kind" not in fields
    with pytest.raises(TypeError):
        Algorithm(name="legacy", make_round_body=lambda *a: None,
                  uplink_record=lambda cfg, p: 1)
    import repro.fed.codecs as codecs_mod
    assert not hasattr(codecs_mod, "make_codec")


def test_custom_record_codec_preserves_cost_report():
    """What the shim used to derive, a plugin now declares directly: a
    DenseCodec with a record override keeps the claimed figure."""
    from repro.core.comm import CommRecord
    bits = 16 * P
    codec = DenseCodec(template_of(TREE), name="legacy_dense",
                       record=CommRecord("legacy_dense", P, bits, bits,
                                         32 * P))
    assert codec.wire_bits(TREE).uplink_bits == bits
    stacked = codec.encode_stacked(
        {"value": jax.tree_util.tree_map(
            lambda l: jnp.zeros((3,) + l.shape), TREE)})
    assert codec.round_bits(stacked) == 3 * bits   # K x record, not f32


def test_register_requires_codec():
    with pytest.raises(ValueError, match="codec"):
        register_algorithm(Algorithm(name="no_wire",
                                     make_round_body=lambda *a: None))
    assert "no_wire" not in ALGORITHMS


def test_int_mask_agg_validation():
    """fedmrn with per-client noise cannot count-aggregate; non-uniform
    weights cannot fold into the single count scale."""
    from repro.fed import get_algorithm
    cfg = FLConfig(algorithm="fedmrn", int_mask_agg=True)
    with pytest.raises(ValueError, match="shared_noise"):
        get_algorithm("fedmrn").validate(cfg)
    get_algorithm("fedmrn").validate(
        dataclasses.replace(cfg, shared_noise=True))


# ---------------------------------------------------------------------------
# hypothesis property: pack→unpack roundtrip (satellite)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(n_bits=st.integers(1, 300).filter(lambda n: n % 32 != 0),
           rows=st.integers(1, 4),
           mode=st.sampled_from(["binary", "signed"]),
           seed=st.integers(0, 2**31 - 1))
    def test_pack_unpack_roundtrip_property(n_bits, rows, mode, seed):
        """pack_rows→unpack_rows is the identity for ANY length not
        divisible by 32, binary and signed, and the ref backend is
        bitwise-identical to pallas-interpret."""
        bits = np.asarray(
            jax.random.bernoulli(jax.random.key(seed), 0.5,
                                 (rows, n_bits))).astype(np.int8)
        ref_words = pack_rows(jnp.asarray(bits), backend="ref")
        pal_words = pack_rows(jnp.asarray(bits), backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref_words),
                                      np.asarray(pal_words))
        for backend in ("ref", "pallas"):
            out = unpack_rows(ref_words, n_bits, backend=backend)
            np.testing.assert_array_equal(np.asarray(out), bits)
            if mode == "signed":
                signed = (2 * out - 1).astype(np.int8)
                np.testing.assert_array_equal(
                    np.asarray(signed), 2 * bits - 1)

else:

    @pytest.mark.skip(reason="hypothesis missing — pinned in "
                             "requirements.txt; install to run "
                             "(REPRO_REQUIRE_HYPOTHESIS=1 raises instead)")
    def test_pack_unpack_roundtrip_property():
        pass
