"""Multi-device engine tier (ISSUE 4): the registry-driven pod round
reproduces the scan engine's trajectories for every algorithm family, and
``sharding="devices"`` sweeps reproduce the vmapped sweep per seed.

Run standalone (``make test-sharded`` / the CI ``test-multidevice`` job)
this file forces 8 fake CPU devices so the client mesh axis and the seed
mesh genuinely partition; inside the full tier-1 suite jax is already
initialised with 1 device and every test adapts (the programs are the
same — only the mesh extents shrink).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
# ^ only effective when this module is the first jax import of the process

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (make_federated_dataset, make_image_task,
                        make_partition)
from repro.fed import (ALGORITHMS, Algorithm, Experiment, ExperimentSpec,
                       FLConfig, make_client_schedule, register_algorithm,
                       sweep_device_count)
from repro.fed.algorithms import get_algorithm
from repro.fed.engine import make_experiment_program
from repro.fed.sharded import (PodRoundSpec, client_axis_of, make_pod_round,
                               pod_batch_specs)
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

KEY = jax.random.key(0)
NDEV = jax.device_count()


def _pod_mesh():
    """A (data, model) mesh over everything available: (4, 2) on the 8
    fake CI devices, (1, 1) degenerate inside the single-device suite."""
    if NDEV >= 8:
        return jax.make_mesh((4, 2), ("data", "model"))
    if NDEV >= 2:
        return jax.make_mesh((NDEV, 1), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def _setup(algorithm, rounds=3, **cfg_kw):
    task = make_image_task(0, n=400, hw=8, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, 8)
    params = mlp_init(KEY, d_in=64, d_hidden=32, n_classes=4)
    cfg = FLConfig(algorithm=algorithm, num_clients=8, clients_per_round=8,
                   rounds=rounds, local_steps=2, batch_size=16, lr=0.1,
                   noise_alpha=3e-2, **cfg_kw)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:128], y_test=task.y[:128])
    return mlp_loss, params, ds, cfg


def _specs_of(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), tree)


def _pod_program(cfg, loss_fn, params, ds, rounds_fused=1,
                 client_weights=None, int_mask_agg=None):
    """(jitted pod step, batch gather fn, initial state) on _pod_mesh."""
    mesh = _pod_mesh()
    gather = jax.jit(lambda r, p: ds.gather_batches(
        r, p, steps=cfg.local_steps, batch=cfg.batch_size))
    b0 = gather(jnp.int32(0), jnp.arange(cfg.clients_per_round,
                                         dtype=jnp.int32))
    step, arg_specs, in_sh = make_pod_round(
        cfg.algorithm, mesh, PodRoundSpec(config=cfg, rounds=rounds_fused),
        loss_fn=loss_fn, p_specs=_specs_of(params),
        batch_specs=_specs_of(b0), client_weights=client_weights,
        int_mask_agg=int_mask_agg)
    algo = get_algorithm(cfg.algorithm)
    return (jax.jit(step, in_shardings=in_sh), gather,
            algo.init_state(cfg, params))


def _assert_trees_close(a, b, atol):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol)


# ---------------------------------------------------------------------------
# the acceptance criterion: pod round body ≡ scan engine, every family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm, overrides", [
    ("fedmrn", {}),
    ("fedmrn", {"error_feedback": True}),
    ("fedmrn", {"shared_noise": True}),   # the pod default for mask families
    ("fedavg", {}),
    ("fedpm", {}),
])
def test_pod_round_matches_scan_engine(algorithm, overrides):
    """R host-driven pod rounds (registry body under the client×data
    mesh, per-round gathered batches + schedule) reproduce the scan
    engine's fused experiment program to 1e-6 — same body, same keys."""
    loss_fn, params, ds, cfg = _setup(algorithm, **overrides)
    schedule = jnp.asarray(make_client_schedule(cfg), jnp.int32)

    run_chunk, state0, metrics0 = make_experiment_program(
        loss_fn, cfg, params, ds)
    w_ref, _, metrics = run_chunk(params, state0, metrics0, jnp.int32(0),
                                  schedule, n_rounds=cfg.rounds)

    pod_step, gather, state = _pod_program(cfg, loss_fn, params, ds)
    w = params
    pod_losses = []
    for r in range(cfg.rounds):
        batches = gather(jnp.int32(r), schedule[r])
        w, state, losses = pod_step(w, state, batches, schedule[r],
                                    jnp.int32(r))
        assert losses.shape == (cfg.clients_per_round, cfg.local_steps)
        pod_losses.append(float(jnp.mean(losses[:, -1])))

    _assert_trees_close(w_ref, w, atol=1e-6)
    np.testing.assert_allclose(np.asarray(metrics["loss"]), pod_losses,
                               atol=1e-5)


def test_pod_client_weights_match_scan_engine():
    """Non-uniform client weights gather as weights_all[picked] on the pod
    path exactly as in the scan engine's chunk body."""
    loss_fn, params, ds, cfg = _setup("fedmrn")
    cw = tuple(float(i + 1) for i in range(cfg.num_clients))
    schedule = jnp.asarray(make_client_schedule(cfg), jnp.int32)

    run_chunk, state0, metrics0 = make_experiment_program(
        loss_fn, cfg, params, ds, client_weights=cw)
    w_ref, _, _ = run_chunk(params, state0, metrics0, jnp.int32(0),
                            schedule, n_rounds=cfg.rounds)

    pod_step, gather, state = _pod_program(cfg, loss_fn, params, ds,
                                           client_weights=cw)
    w = params
    for r in range(cfg.rounds):
        w, state, _ = pod_step(w, state, gather(jnp.int32(r), schedule[r]),
                               schedule[r], jnp.int32(r))
    _assert_trees_close(w_ref, w, atol=1e-6)

    with pytest.raises(ValueError, match="client_weights"):
        _pod_program(cfg, loss_fn, params, ds, client_weights=(1.0, 2.0))


def test_pod_algorithm_instance_resolution():
    """An Algorithm instance auto-registers; a name collision with a
    different plugin raises instead of silently running the builtin."""
    loss_fn, params, ds, cfg = _setup("fedmrn", rounds=1)
    mesh = _pod_mesh()
    b_specs = _specs_of(ds.gather_batches(
        jnp.int32(0), jnp.arange(cfg.clients_per_round, dtype=jnp.int32),
        steps=cfg.local_steps, batch=cfg.batch_size))
    imposter = dataclasses.replace(get_algorithm("fedavg"), name="fedmrn")
    with pytest.raises(ValueError, match="different plugin"):
        make_pod_round(imposter, mesh, PodRoundSpec(config=cfg),
                       loss_fn=loss_fn, p_specs=_specs_of(params),
                       batch_specs=b_specs)
    fresh = dataclasses.replace(get_algorithm("fedavg"), name="pod_inline")
    try:
        make_pod_round(fresh, mesh, PodRoundSpec(config=cfg),
                       loss_fn=loss_fn, p_specs=_specs_of(params),
                       batch_specs=b_specs)
        assert "pod_inline" in ALGORITHMS
    finally:
        ALGORITHMS.pop("pod_inline", None)


def test_pod_multiround_scan_matches_host_loop():
    """PodRoundSpec(rounds=R) — the fused in-program scan — equals R
    single-round pod dispatches fed the same batch stream (the probe's
    reuse semantics), cross-round state included."""
    loss_fn, params, ds, cfg = _setup("fedmrn", rounds=3,
                                      error_feedback=True)
    picked = jnp.arange(cfg.clients_per_round, dtype=jnp.int32)

    fused_step, gather, state_f = _pod_program(cfg, loss_fn, params, ds,
                                               rounds_fused=cfg.rounds)
    batches = gather(jnp.int32(0), picked)
    w_f, state_f, losses_f = fused_step(params, state_f, batches, picked,
                                        jnp.int32(0))
    assert losses_f.shape == (cfg.rounds, cfg.clients_per_round,
                              cfg.local_steps)

    single_step, _, state = _pod_program(cfg, loss_fn, params, ds)
    w = params
    for r in range(cfg.rounds):
        w, state, losses = single_step(w, state, batches, picked,
                                       jnp.int32(r))
        np.testing.assert_allclose(np.asarray(losses_f[r]),
                                   np.asarray(losses), atol=1e-6)
    _assert_trees_close(w_f, w, atol=1e-6)
    _assert_trees_close(state_f, state, atol=1e-6)


def test_pod_runs_custom_plugin():
    """ANY registered Algorithm lowers on the pod path — no engine fork."""

    def make_body(loss_fn, cfg, params):
        def round_fn(seed, w, state, batches, picked, round_idx, weights):
            def per_client(b, cid):
                from repro.core import sgd_local_update
                return sgd_local_update(loss_fn, w, b, lr=cfg.lr)

            updates, losses = jax.vmap(per_client)(batches, picked)
            wn = weights / jnp.sum(weights)
            agg = jax.tree_util.tree_map(
                lambda x: jnp.tensordot(wn, x, axes=1), updates)
            new_w = jax.tree_util.tree_map(lambda p, a: p + 0.5 * a, w, agg)
            return new_w, state, losses

        return round_fn

    from repro.core.comm import CommRecord
    from repro.fed import DenseCodec, template_of
    register_algorithm(Algorithm(
        name="toy_pod", make_round_body=make_body,
        codec=lambda cfg, p: DenseCodec(
            template_of(p), name="toy_pod",
            record=CommRecord("toy_pod", 0, 1, 1, 1))))
    try:
        loss_fn, params, ds, cfg = _setup("toy_pod", rounds=1)
        pod_step, gather, state = _pod_program(cfg, loss_fn, params, ds)
        picked = jnp.arange(cfg.clients_per_round, dtype=jnp.int32)
        batches = gather(jnp.int32(0), picked)
        w, state, losses = pod_step(params, state, batches, picked,
                                    jnp.int32(0))
        assert np.isfinite(np.asarray(losses)).all()
        changed = any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(w)))
        assert changed
    finally:
        ALGORITHMS.pop("toy_pod", None)


# ---------------------------------------------------------------------------
# the codec wire format on the pod path (ISSUE 5 acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm, overrides", [
    ("fedmrn", {"shared_noise": True}),
    ("fedpm", {}),
])
def test_pod_int_mask_agg_matches_f32_reference(algorithm, overrides):
    """The ⌈log2(K+1)⌉-bit integer mask-count aggregate (the pod default
    for count-aggregatable MaskCodec families) reproduces the f32
    reference aggregation — same trajectories over R rounds."""
    loss_fn, params, ds, cfg = _setup(algorithm, **overrides)
    schedule = jnp.asarray(make_client_schedule(cfg), jnp.int32)
    int_step, gather, state_i = _pod_program(cfg, loss_fn, params, ds)
    f32_step, _, state_f = _pod_program(cfg, loss_fn, params, ds,
                                        int_mask_agg=False)
    w_i = w_f = params
    for r in range(cfg.rounds):
        batches = gather(jnp.int32(r), schedule[r])
        w_i, state_i, _ = int_step(w_i, state_i, batches, schedule[r],
                                   jnp.int32(r))
        w_f, state_f, _ = f32_step(w_f, state_f, batches, schedule[r],
                                   jnp.int32(r))
    _assert_trees_close(w_i, w_f, atol=1e-6)


def test_pod_mask_allreduce_lowers_to_integer_dtype():
    """Acceptance probe: with int_mask_agg (the pod default for fedmrn +
    shared noise) the cross-client collective in the compiled HLO is an
    INTEGER all-reduce, and no model-sized f32 all-reduce remains."""
    import re

    mesh = _pod_mesh()
    D = mesh.shape[client_axis_of(mesh)]
    if D == 1:
        pytest.skip("degenerate 1-device client axis emits no collective")
    loss_fn, params, ds, cfg = _setup("fedmrn", rounds=1,
                                      shared_noise=True)
    from repro.fed.codecs import min_count_dtype
    import numpy as _np
    want = _np.dtype(min_count_dtype(cfg.clients_per_round))
    hlo_dtype = {"int8": "s8", "int16": "s16", "int32": "s32"}[want.name]

    gather = jax.jit(lambda r, p: ds.gather_batches(
        r, p, steps=cfg.local_steps, batch=cfg.batch_size))
    b0 = gather(jnp.int32(0), jnp.arange(cfg.clients_per_round,
                                         dtype=jnp.int32))
    step, arg_specs, in_sh = make_pod_round(
        cfg.algorithm, mesh, PodRoundSpec(config=cfg),
        loss_fn=loss_fn, p_specs=_specs_of(params),
        batch_specs=_specs_of(b0))
    hlo = jax.jit(step, in_shardings=in_sh).lower(
        *arg_specs).compile().as_text()
    ars = re.findall(r"= (\w+)\[([0-9,]*)\][^=\n]*all-reduce", hlo)
    assert any(dt == hlo_dtype for dt, _ in ars), (
        f"no {hlo_dtype} all-reduce in HLO: {ars}")
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))

    def elems(dims):
        out = 1
        for d in dims.split(","):
            out *= int(d) if d else 1
        return out

    big_f32 = [(dt, dims) for dt, dims in ars
               if dt == "f32" and elems(dims) >= n_params]
    assert not big_f32, f"model-sized f32 all-reduce survived: {big_f32}"


def test_pod_int_mask_agg_rejects_nonuniform_weights():
    loss_fn, params, ds, cfg = _setup("fedmrn", shared_noise=True)
    cw = tuple(float(i + 1) for i in range(cfg.num_clients))
    with pytest.raises(ValueError, match="uniform"):
        _pod_program(cfg, loss_fn, params, ds, client_weights=cw,
                     int_mask_agg=True)


def test_pod_rejects_indivisible_client_axis():
    mesh = _pod_mesh()
    D = mesh.shape[client_axis_of(mesh)]
    if D == 1:
        pytest.skip("degenerate 1-device mesh divides everything")
    loss_fn, params, ds, cfg = _setup("fedmrn")
    cfg = dataclasses.replace(cfg, clients_per_round=D + 1)
    with pytest.raises(ValueError, match="divisible"):
        make_pod_round(cfg.algorithm, mesh, PodRoundSpec(config=cfg),
                       loss_fn=loss_fn, p_specs=_specs_of(params),
                       batch_specs=_specs_of(ds.gather_batches(
                           jnp.int32(0),
                           jnp.arange(D + 1, dtype=jnp.int32),
                           steps=cfg.local_steps, batch=cfg.batch_size)))


def test_pod_batch_specs_split():
    specs = pod_batch_specs(
        {"x": jax.ShapeDtypeStruct((256, 7), jnp.float32)}, 16, 2)
    assert specs["x"].shape == (16, 2, 8, 7)
    tiny = pod_batch_specs(
        {"x": jax.ShapeDtypeStruct((4,), jnp.float32)}, 16, 2)
    assert tiny["x"].shape == (16, 2, 1)      # floor clamps at 1


# ---------------------------------------------------------------------------
# sharding="devices": the seed axis over a device mesh via shard_map
# ---------------------------------------------------------------------------

def _experiment(algorithm="fedmrn", rounds=3, **cfg_kw):
    loss_fn, params, ds, cfg = _setup(algorithm, rounds, **cfg_kw)
    cfg = dataclasses.replace(cfg, clients_per_round=4)
    return Experiment(ExperimentSpec(
        loss_fn=loss_fn, params=params, data=ds, config=cfg,
        eval_apply=mlp_apply))


def test_sharded_sweep_matches_vmapped_per_seed():
    """The shard_map'd sweep is trajectory-identical to the vmapped sweep
    (and hence to S independent runs) for every seed — EF state too."""
    exp = _experiment(rounds=3, error_feedback=True)
    n_seeds = 8
    vm = exp.sweep(seeds=n_seeds)
    sh = exp.sweep(seeds=n_seeds, sharding="devices")
    assert sh.vmapped and sh.devices == sweep_device_count(n_seeds)
    if NDEV >= 8:
        assert sh.devices == 8                 # genuinely spread in CI
    for a, b in zip(vm.runs, sh.runs):
        np.testing.assert_allclose(a.acc, b.acc, atol=1e-6)
        np.testing.assert_allclose(a.local_loss, b.local_loss, atol=1e-5)
        np.testing.assert_array_equal(a.schedule, b.schedule)
    solo = exp.run(seed=sh.seeds[1])
    np.testing.assert_allclose(sh.runs[1].acc, solo.acc, atol=1e-6)


def test_sharded_sweep_chunked_and_algorithms():
    """Chunked dispatch + a second family through the same sharded path."""
    exp = _experiment("fedpm", rounds=4)
    sh = exp.sweep(seeds=4, sharding="devices", chunk=3)   # 3 + 1 trailing
    vm = exp.sweep(seeds=4, chunk=3)
    assert all(r.num_dispatches == 2 for r in sh.runs)
    for a, b in zip(vm.runs, sh.runs):
        np.testing.assert_allclose(a.acc, b.acc, atol=1e-6)
        np.testing.assert_allclose(a.local_loss, b.local_loss, atol=1e-5)


def test_sweep_device_count_picks_largest_divisor():
    assert sweep_device_count(8, max_devices=8) == 8
    assert sweep_device_count(8, max_devices=4) == 4
    assert sweep_device_count(6, max_devices=4) == 3
    assert sweep_device_count(7, max_devices=4) == 1
    assert sweep_device_count(3, max_devices=8) == 3
    with pytest.raises(ValueError, match="seed"):
        sweep_device_count(0)


def test_sharded_sweep_argument_validation():
    exp = _experiment(rounds=2)
    with pytest.raises(ValueError, match="divide"):
        exp.sweep(seeds=3, sharding="devices", devices=2)
    with pytest.raises(ValueError, match="vmapped"):
        exp.sweep(seeds=2, sharding="devices", vmapped=False)
    with pytest.raises(ValueError, match="sharding"):
        exp.sweep(seeds=2, sharding="pods")
    with pytest.raises(ValueError, match="devices"):
        exp.sweep(seeds=2, devices=2)         # devices without sharding=
