"""Cohort-streaming scale benchmark: larger-than-HBM client populations.

Drives the cohort engine (``fed/engine.py::CohortRunner``) over synthetic
uniform populations of C ∈ {1e3, 1e5, 1e6} clients — the population's
examples and index matrices stay HOST-resident, only one cohort block is
device-resident at a time — and reports:

  scale/C<β>/clients_per_sec   round-selected clients processed per
                               wall-second (R·K / wall), prefetch on
  scale/C<β>/rounds_per_sec    the same run, per-round view
  scale/C<β>/prefetch_ratio    prefetch-off wall over prefetch-on wall —
                               the double-buffering win.  The overlap
                               needs a host core free beside the compute
                               stream (or a real accelerator whose H2D
                               DMA runs beside it); on a single-core CPU
                               runner stage and compute share the core
                               and the ratio degenerates to ~1.0, so the
                               row must be read against ``n_cpus`` in
                               BENCH_scale.json.
  scale/C<β>/block_MB          device watermark: ONE staged cohort block
                               (x/y + index matrix, padded to the
                               population maxima) — what the engine keeps
                               resident instead of the whole population
  scale/C<β>/population_MB     host bytes of the full population (the
                               device cost a non-streaming engine pays)
  scale/peak_rss_MB            host max-RSS after the sweep (sanity: the
                               host copy, not a device blowup)

``write_bench_json`` emits machine-readable ``BENCH_scale.json`` at the
repo root (same commit/config/results shape as BENCH_engine.json).
"""
from __future__ import annotations

import json
import os
import resource
import subprocess
import time
from typing import Dict, List

import jax
import numpy as np

from repro.data import make_cohorted_dataset
from repro.fed import Experiment, ExperimentSpec, FLConfig
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

K = 64              # clients per round
ROUNDS = 3
STEPS = 2           # local steps
BATCH = 4
PER_CLIENT = 2      # examples per client (uniform 2-D parts fast path)
D = 16              # feature dim

# population size → cohort size (clients staged per block)
SIZES = {1_000: 256, 100_000: 8_192, 1_000_000: 16_384}
SIZES_QUICK = {1_000: 256, 10_000: 2_048}

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_scale.json")


def _population(C: int, cohort_size: int):
    rng = np.random.RandomState(0)
    x = rng.randn(C * PER_CLIENT, D).astype(np.float32)
    y = rng.randint(0, 4, C * PER_CLIENT).astype(np.int32)
    # uniform clients: the 2-D parts fast path (no per-client lists)
    parts = np.arange(C * PER_CLIENT, dtype=np.int32).reshape(C, PER_CLIENT)
    return make_cohorted_dataset(x, y, parts, cohort_size=cohort_size,
                                 x_test=x[:256], y_test=y[:256],
                                 batch_seed=7)


def _block_mb(ds) -> float:
    """Analytic bytes of ONE staged cohort block (the device watermark)."""
    ex = ds.pad_examples
    return (ex * D * 4 + ex * 4                       # x + y
            + ds.pad_clients * ds.pad_len * 4         # client_idx
            + ds.pad_clients * 4) / 1e6               # client_len


def scale_rows(quick: bool = False) -> List[Dict]:
    sizes = SIZES_QUICK if quick else SIZES
    rounds = 2 if quick else ROUNDS
    rows = []
    for C, cohort_size in sizes.items():
        ds = _population(C, cohort_size)
        params = mlp_init(jax.random.key(0), d_in=D, d_hidden=32,
                          n_classes=4)
        cfg = FLConfig(algorithm="fedmrn", num_clients=C,
                       clients_per_round=K, rounds=rounds,
                       local_steps=STEPS, batch_size=BATCH, lr=0.1,
                       noise_alpha=3e-2)
        exp = Experiment(ExperimentSpec(
            loss_fn=mlp_loss, params=params, data=ds, config=cfg,
            eval_apply=mlp_apply, eval_every=rounds))
        walls = {}
        for prefetch in (True, False):
            exp.run(engine="cohort", prefetch=prefetch)   # compile/warmup
            best = float("inf")
            for _ in range(2 if quick else 3):
                t0 = time.time()
                exp.run(engine="cohort", prefetch=prefetch)
                best = min(best, time.time() - t0)
            walls[prefetch] = best
        wall = walls[True]
        tag = f"scale/C{C:.0e}".replace("e+0", "e")
        rows += [
            dict(name=f"{tag}/clients_per_sec",
                 us_per_call=wall / rounds * 1e6,
                 derived=round(rounds * K / wall, 1)),
            dict(name=f"{tag}/rounds_per_sec", us_per_call=0.0,
                 derived=round(rounds / wall, 2)),
            dict(name=f"{tag}/prefetch_ratio", us_per_call=0.0,
                 derived=round(walls[False] / walls[True], 2)),
            dict(name=f"{tag}/block_MB", us_per_call=0.0,
                 derived=round(_block_mb(ds), 2)),
            dict(name=f"{tag}/population_MB", us_per_call=0.0,
                 derived=round((C * PER_CLIENT * (D * 4 + 4)
                                + C * (PER_CLIENT + 1) * 4) / 1e6, 2)),
        ]
        del ds, exp
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    rows.append(dict(name="scale/peak_rss_MB", us_per_call=0.0,
                     derived=round(rss, 1)))
    return rows


def write_bench_json(rows: List[Dict], path: str = BENCH_JSON,
                     quick: bool = False) -> str:
    try:
        commit = subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True).strip()
    except Exception:  # noqa: BLE001 — no git in CI tarballs
        commit = "unknown"
    results: Dict[str, Dict] = {}
    for r in rows:
        parts = r["name"].split("/")
        if parts[0] != "scale":
            continue
        if len(parts) == 2:
            results[parts[1]] = r["derived"]
        else:
            results.setdefault(parts[1], {})[parts[2]] = r["derived"]
    doc = {
        "bench": "scale",
        "commit": commit,
        "config": {"clients_per_round": K,
                   "rounds": 2 if quick else ROUNDS,
                   "local_steps": STEPS, "batch_size": BATCH,
                   "examples_per_client": PER_CLIENT, "features": D,
                   "cohort_sizes": {f"{c:.0e}".replace("e+0", "e"): s
                                    for c, s in (SIZES_QUICK if quick
                                                 else SIZES).items()},
                   "model": f"mlp({D},32,4)",
                   "n_devices": jax.local_device_count(),
                   "n_cpus": os.cpu_count(),
                   "unit": "clients_per_sec (prefetch on; prefetch_ratio "
                           "is off-wall over on-wall and needs a spare "
                           "host core or real H2D DMA to exceed 1 — see "
                           "n_cpus; *_MB rows are memory watermarks)"},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    all_rows = scale_rows()
    for row in all_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    print(f"# wrote {write_bench_json(all_rows)}")
