"""Round-engine benchmark: looped vs batched vs scan rounds/sec.

Three execution models of the same algorithm family on the synthetic CNN
workload — cost-comparable workloads (same model, K, S, B); exact
bit-equality of trajectories is asserted by the parity tests
(``tests/test_scan_engine.py``), not by this bench (the looped/batched
rows reuse one prebuilt batch set, the driver/scan rows draw per-round
batches from the device-resident dataset):

  looped    the seed's per-client loop — one jitted local update per
            client + blocking host sync + eager server aggregation;
  batched   one jitted XLA program per round (PR 1) — the host still
            gathers/stacks batches and dispatches every round;
  scan      one jitted program per CHUNK of rounds (PR 2) — client
            selection, batch gathering, and metrics live in-program,
            the host dispatches ⌈R/chunk⌉ times.

Rows (derived = rounds/sec, except ratio rows):
  engine/<algo>/looped, engine/<algo>/batched   program-level round cost
  engine/<algo>/speedup                         batched vs looped ratio
  engine/<algo>/batched_driver                  driver-level: host batch
                                                stacking + dispatch/round
  engine/<algo>/scan                            driver-level: chunked scan
  engine/<algo>/scan_vs_batched                 scan vs batched_driver —
                                                the PR-2 acceptance ratio

Multi-seed sweep rows (derived = seeds/sec, except the ratios):
  engine/sweep/vmapped            Experiment.sweep: S seeds as ONE vmapped
                                  scan program (one dispatch per chunk)
  engine/sweep/host_loop          the fallback: S sequential dispatches of
                                  one seed-polymorphic compiled program
  engine/sweep/vmapped_vs_loop    the PR-3 acceptance ratio (>= 2x)
  engine/sweep/sharded            sharding="devices": the seed axis
                                  shard_map'd over the local device mesh
                                  (S/D seeds vmapped per device; equals
                                  the vmapped program when D=1)
  engine/sweep/sharded_devices    D actually used (context for the row)
  engine/sweep/sharded_vs_vmapped sharded over vmapped seeds/sec ratio

Wire-format rows (the codec pod aggregation, derived = rounds/sec unless
noted):
  engine/wire/pod_int_mask        fedmrn shared-noise pod round with the
                                  ⌈log2(K+1)⌉-bit integer mask-count
                                  all-reduce (int_mask_agg)
  engine/wire/pod_f32_mask        the same round forced to the f32
                                  reference aggregation
  engine/wire/int_vs_f32          f32-over-int wall-time ratio
  engine/wire/{int,f32}_payload_B cross-client collective payload bytes
                                  per round for each format

``write_bench_json`` emits the machine-readable ``BENCH_engine.json``
(rounds/sec per engine + config + commit) next to the repo root.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (make_federated_dataset, make_image_task,
                        make_partition, sample_local_batches)
from repro.fed import FLConfig
from repro.fed.engine import (make_experiment_program, make_round_engine,
                              stack_client_batches)
from repro.core import (client_local_update, server_aggregate,
                        server_aggregate_updates, sgd_local_update)
from repro.models.cnn import cnn_init, cnn_loss

K = 8               # clients per round
STEPS = 1           # local steps (FedSGD-style rounds: the regime where
                    # engine overhead, not local compute, is the cost)
BATCH = 4
NUM_CLIENTS = 16
# The workload is deliberately SMALL (1 local step, batch 4, cnn(4,4)):
# this bench measures ENGINE overhead — per-round host work + dispatch —
# which a big local-compute term would drown.  On the TPU target a round
# of this model is far cheaper than on CPU, so small CPU compute is the
# representative regime for the overhead ratios.

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_engine.json")


def _setup():
    task = make_image_task(0, n=2000, hw=8, n_classes=8, noise=0.5)
    parts = make_partition("iid", 0, task.y, num_clients=NUM_CLIENTS)
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(4, 4), hw=8)
    batches = [
        sample_local_batches(131 + cid, task.x, task.y, parts[cid],
                             steps=STEPS, batch=BATCH)
        for cid in range(K)]
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=131)
    return params, batches, ds


def _time_rounds(round_once, n: int, repeats: int = 3) -> float:
    """Best-of-``repeats`` wall-seconds per call after a compile/warmup
    call (min over passes rejects scheduler noise on shared CPUs — without
    it the ordering of the engines is not even stable run-to-run)."""
    jax.block_until_ready(round_once())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        out = None
        for _ in range(n):
            out = round_once()
        jax.block_until_ready(out)
        best = min(best, (time.time() - t0) / n)
    return best


def _cfg(algo: str) -> FLConfig:
    return FLConfig(algorithm=algo, num_clients=NUM_CLIENTS,
                    clients_per_round=K, rounds=1, local_steps=STEPS,
                    batch_size=BATCH, lr=0.1, noise_alpha=0.05)


def engine_rows(n_rounds: int = 30) -> List[Dict]:
    params, batches, ds = _setup()
    picked = np.arange(K)
    weights = [1.0] * K
    rows = []

    for algo in ("fedmrn", "fedavg"):
        cfg = _cfg(algo)
        mrn = cfg.fedmrn_config()

        # ---- seed execution model: per-client jitted calls + host syncs ----
        if algo == "fedmrn":
            local = jax.jit(partial(client_local_update, cnn_loss, cfg=mrn,
                                    base_seed=cfg.seed))

            def looped_round():
                results, losses = [], []
                for cid in picked:
                    res = local(params, batches[cid], round_idx=0,
                                client_id=int(cid),
                                train_key=jax.random.fold_in(
                                    jax.random.key(cfg.seed + 1), int(cid)))
                    results.append(res)
                    losses.append(float(res.losses[-1]))   # seed's host sync
                return server_aggregate(params, results, weights, cfg=mrn)
        else:
            local = jax.jit(partial(sgd_local_update, cnn_loss, lr=cfg.lr))

            def looped_round():
                updates, losses = [], []
                for cid in picked:
                    u, ls = local(params, batches[cid])
                    updates.append(u)
                    losses.append(float(ls[-1]))           # seed's host sync
                return server_aggregate_updates(params, updates, weights)

        # ---- batched: one jitted XLA program per round --------------------
        round_fn, state0 = make_round_engine(cnn_loss, cfg, params)
        stacked = stack_client_batches(batches)
        picked_dev = jnp.asarray(picked, jnp.int32)
        weights_dev = jnp.asarray(weights, jnp.float32)

        def batched_round():
            w, _, losses, _ = round_fn(params, state0, stacked, picked_dev,
                                       jnp.int32(0), weights_dev)
            return w, losses          # losses stay device-resident

        # ---- batched DRIVER: what run_federated(engine="batched") pays
        # per round — gather + stack the picked clients' batches on the
        # host (round index VARIES per call, as in the real driver loop —
        # pinning it would let argument caching flatter the host path),
        # dispatch the round program, and dispatch the per-round loss
        # reduction the driver keeps in its device loss buffer
        batch_fn = ds.batch_fn(steps=STEPS, batch=BATCH)

        def batched_driver_rounds():
            loss_buf = []
            for rnd in range(n_rounds):
                bs = stack_client_batches(
                    [batch_fn(rnd, int(cid)) for cid in picked])
                w, _, losses, _ = round_fn(params, state0, bs, picked_dev,
                                           jnp.int32(rnd), weights_dev)
                loss_buf.append(jnp.mean(losses[:, -1]))
            return w, loss_buf

        # ---- scan: n_rounds fused into one dispatch -----------------------
        scan_cfg = dataclasses.replace(cfg, rounds=n_rounds)
        run_chunk, sstate0, metrics0 = make_experiment_program(
            cnn_loss, scan_cfg, params, ds)
        schedule = jnp.tile(picked_dev, (n_rounds, 1))

        def scan_chunk():
            return run_chunk(params, sstate0, metrics0, jnp.int32(0),
                             schedule, n_rounds=n_rounds)

        t_loop = _time_rounds(looped_round, n_rounds)
        t_batch = _time_rounds(batched_round, n_rounds)
        # driver/scan cover n_rounds rounds per call: best full pass
        t_bdrv = _time_rounds(batched_driver_rounds, 1) / n_rounds
        t_scan = _time_rounds(scan_chunk, 1) / n_rounds
        rows += [
            dict(name=f"engine/{algo}/looped", us_per_call=t_loop * 1e6,
                 derived=round(1.0 / t_loop, 2)),
            dict(name=f"engine/{algo}/batched", us_per_call=t_batch * 1e6,
                 derived=round(1.0 / t_batch, 2)),
            dict(name=f"engine/{algo}/speedup", us_per_call=0.0,
                 derived=round(t_loop / t_batch, 2)),
            dict(name=f"engine/{algo}/batched_driver",
                 us_per_call=t_bdrv * 1e6, derived=round(1.0 / t_bdrv, 2)),
            dict(name=f"engine/{algo}/scan", us_per_call=t_scan * 1e6,
                 derived=round(1.0 / t_scan, 2)),
            dict(name=f"engine/{algo}/scan_vs_batched", us_per_call=0.0,
                 derived=round(t_bdrv / t_scan, 2)),
        ]
    return rows


def sweep_rows(n_rounds: int = 10, n_seeds: int = 32) -> List[Dict]:
    """Vmapped vs host-looped multi-seed sweep seeds/sec (same scan body).

    Both paths run the SAME per-seed computation (n_rounds scan rounds of
    the fedmrn body, per-seed client schedules) through cached compiled
    programs; the vmapped path fuses the S seeds into one program with a
    leading seed axis, the host loop dispatches one seed-polymorphic
    program S times.  Trajectory equality is asserted by
    tests/test_experiment_api.py, not here.
    """
    from repro.fed import Experiment, ExperimentSpec
    from repro.models.cnn import cnn_apply

    task = make_image_task(0, n=2000, hw=8, n_classes=8, noise=0.5)
    parts = make_partition("iid", 0, task.y, num_clients=NUM_CLIENTS)
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(4, 4), hw=8)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=131,
                                x_test=task.x[:256], y_test=task.y[:256])
    cfg = dataclasses.replace(_cfg("fedmrn"), rounds=n_rounds)
    exp = Experiment(ExperimentSpec(
        loss_fn=cnn_loss, params=params, data=ds, config=cfg,
        eval_apply=cnn_apply, eval_every=n_rounds))

    def timed(fn, repeats=3):
        fn()                    # compile/warmup (programs cached on exp)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            fn()
            best = min(best, time.time() - t0)
        return best

    t_vm = timed(lambda: exp.sweep(seeds=n_seeds))
    t_host = timed(lambda: exp.sweep(seeds=n_seeds, vmapped=False))
    # sharding="devices": identical per-seed programs shard_map'd over the
    # local device mesh.  On the single-device CI runner D=1 and the row
    # degenerates to the vmapped program (ratio ≈ 1); spread it with e.g.
    # XLA_FLAGS=--xla_force_host_platform_device_count=8.  Report the
    # device count the timed sweep ACTUALLY used, not a recomputation.
    n_dev = exp.sweep(seeds=n_seeds, sharding="devices").devices
    t_sh = timed(lambda: exp.sweep(seeds=n_seeds, sharding="devices"))
    return [
        dict(name="engine/sweep/vmapped", us_per_call=t_vm * 1e6,
             derived=round(n_seeds / t_vm, 2)),
        dict(name="engine/sweep/host_loop", us_per_call=t_host * 1e6,
             derived=round(n_seeds / t_host, 2)),
        dict(name="engine/sweep/vmapped_vs_loop", us_per_call=0.0,
             derived=round(t_host / t_vm, 2)),
        dict(name="engine/sweep/sharded", us_per_call=t_sh * 1e6,
             derived=round(n_seeds / t_sh, 2)),
        dict(name="engine/sweep/sharded_devices", us_per_call=0.0,
             derived=n_dev),
        dict(name="engine/sweep/sharded_vs_vmapped", us_per_call=0.0,
             derived=round(t_vm / t_sh, 2)),
    ]


def wire_rows(n_rounds: int = 20) -> List[Dict]:
    """Pod mask-aggregation wire formats: integer vs f32 all-reduce.

    Lowers the SAME fedmrn shared-noise pod round twice — once with the
    ``⌈log2(K+1)⌉``-bit integer mask-count aggregate (``int_mask_agg``,
    the pod default for count-aggregatable mask codecs) and once forced
    to the f32 reference path — and reports rounds/sec plus the
    cross-client collective payload bytes each format moves (P elements
    × the aggregate dtype).  On a single-device runner the mesh is
    degenerate (no collective), but the rows still track the program
    cost of both formats.
    """
    import dataclasses as _dc

    from repro.fed.codecs import min_count_dtype
    from repro.fed.sharded import PodRoundSpec, make_pod_round

    params, _, ds = _setup()
    ndev = jax.local_device_count()
    client_dev = next(d for d in range(min(K, ndev), 0, -1) if K % d == 0)
    mesh = jax.make_mesh((client_dev, 1), ("data", "model"))
    cfg = _dc.replace(_cfg("fedmrn"), shared_noise=True)

    def specs_of(tree):
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), tree)

    picked = jnp.arange(K, dtype=jnp.int32)
    b0 = jax.jit(lambda: ds.gather_batches(
        jnp.int32(0), picked, steps=STEPS, batch=BATCH))()
    P = sum(int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(params))
    times, payload = {}, {}
    for kind, imask in (("int", True), ("f32", False)):
        step, _, in_sh = make_pod_round(
            "fedmrn", mesh, PodRoundSpec(config=cfg),
            loss_fn=cnn_loss, p_specs=specs_of(params),
            batch_specs=specs_of(b0), int_mask_agg=imask)
        jitted = jax.jit(step, in_shardings=in_sh)

        def round_once():
            return jitted(params, {}, b0, picked, jnp.int32(0))

        times[kind] = _time_rounds(round_once, n_rounds)
        dtype = min_count_dtype(K) if imask else jnp.float32
        payload[kind] = P * np.dtype(dtype).itemsize
    return [
        dict(name="engine/wire/pod_int_mask",
             us_per_call=times["int"] * 1e6,
             derived=round(1.0 / times["int"], 2)),
        dict(name="engine/wire/pod_f32_mask",
             us_per_call=times["f32"] * 1e6,
             derived=round(1.0 / times["f32"], 2)),
        dict(name="engine/wire/int_vs_f32", us_per_call=0.0,
             derived=round(times["f32"] / times["int"], 2)),
        dict(name="engine/wire/int_payload_B", us_per_call=0.0,
             derived=payload["int"]),
        dict(name="engine/wire/f32_payload_B", us_per_call=0.0,
             derived=payload["f32"]),
    ]


def write_bench_json(rows: List[Dict], path: str = BENCH_JSON,
                     n_rounds: int = 30, n_sweep_seeds: int = 32) -> str:
    """Emit machine-readable engine results (satellite: bench trajectory).

    ``n_rounds`` is recorded in the config so a --quick (10-round) run is
    distinguishable from a full 30-round run in the tracked trajectory.
    """
    try:
        commit = subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True).strip()
    except Exception:  # noqa: BLE001 — no git in CI tarballs
        commit = "unknown"
    results = {}
    for r in rows:
        if r["name"].startswith("engine/"):
            _, algo, kind = r["name"].split("/")
            results.setdefault(algo, {})[kind] = r["derived"]
    doc = {
        "bench": "engine",
        "commit": commit,
        "config": {"clients_per_round": K, "num_clients": NUM_CLIENTS,
                   "local_steps": STEPS, "batch_size": BATCH,
                   "n_rounds": n_rounds, "n_sweep_seeds": n_sweep_seeds,
                   "n_devices": jax.local_device_count(),
                   "model": "cnn(4,4)/hw8", "unit": "rounds_per_sec "
                   "(sweep rows are seeds_per_sec; speedup/"
                   "scan_vs_batched/vmapped_vs_loop/sharded_vs_vmapped "
                   "rows are ratios; sharded_devices is a device count)"},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    all_rows = engine_rows() + sweep_rows() + wire_rows()
    for row in all_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    print(f"# wrote {write_bench_json(all_rows, n_rounds=30)}")
