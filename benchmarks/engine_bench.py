"""Looped-vs-batched round-engine benchmark (the tentpole's receipts).

Measures steady-state rounds/sec of the seed's per-client loop (one jitted
local update per client + blocking host sync + eager server aggregation —
``fed/looped.py``'s execution model) against the batched round engine (one
jitted XLA program per round, ``fed/engine.py``) on the synthetic CNN
workload.  Both paths compute the same algorithm with the same keys; only
the execution model differs, so the ratio is pure engine overhead.

Rows:  engine/<algo>/looped, engine/<algo>/batched   (derived = rounds/sec)
       engine/<algo>/speedup                         (derived = ratio)
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_image_task, make_partition, sample_local_batches
from repro.fed import FLConfig
from repro.fed.engine import make_round_engine, stack_client_batches
from repro.core import (client_local_update, server_aggregate,
                        server_aggregate_updates, sgd_local_update)
from repro.models.cnn import cnn_init, cnn_loss

K = 8               # clients per round
STEPS = 5           # local steps
BATCH = 16


def _setup():
    task = make_image_task(0, n=2000, hw=8, n_classes=8, noise=0.5)
    parts = make_partition("iid", 0, task.y, num_clients=16)
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(4, 8), hw=8)
    batches = [
        sample_local_batches(131 + cid, task.x, task.y, parts[cid],
                             steps=STEPS, batch=BATCH)
        for cid in range(K)]
    return params, batches


def _time_rounds(round_once, n: int) -> float:
    """Wall-seconds per round after a compile/warmup call."""
    jax.block_until_ready(round_once())
    t0 = time.time()
    out = None
    for _ in range(n):
        out = round_once()
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def engine_rows(n_rounds: int = 30) -> List[Dict]:
    params, batches = _setup()
    picked = np.arange(K)
    weights = [1.0] * K
    rows = []

    for algo in ("fedmrn", "fedavg"):
        cfg = FLConfig(algorithm=algo, num_clients=16, clients_per_round=K,
                       rounds=1, local_steps=STEPS, batch_size=BATCH,
                       lr=0.1, noise_alpha=0.05)
        mrn = cfg.fedmrn_config()

        # ---- seed execution model: per-client jitted calls + host syncs ----
        if algo == "fedmrn":
            local = jax.jit(partial(client_local_update, cnn_loss, cfg=mrn,
                                    base_seed=cfg.seed))

            def looped_round():
                results, losses = [], []
                for cid in picked:
                    res = local(params, batches[cid], round_idx=0,
                                client_id=int(cid),
                                train_key=jax.random.fold_in(
                                    jax.random.key(cfg.seed + 1), int(cid)))
                    results.append(res)
                    losses.append(float(res.losses[-1]))   # seed's host sync
                return server_aggregate(params, results, weights, cfg=mrn)
        else:
            local = jax.jit(partial(sgd_local_update, cnn_loss, lr=cfg.lr))

            def looped_round():
                updates, losses = [], []
                for cid in picked:
                    u, ls = local(params, batches[cid])
                    updates.append(u)
                    losses.append(float(ls[-1]))           # seed's host sync
                return server_aggregate_updates(params, updates, weights)

        # ---- batched: one jitted XLA program per round --------------------
        round_fn, state0 = make_round_engine(cnn_loss, cfg, params)
        stacked = stack_client_batches(batches)
        picked_dev = jnp.asarray(picked, jnp.int32)
        weights_dev = jnp.asarray(weights, jnp.float32)

        def batched_round():
            w, _, losses = round_fn(params, state0, stacked, picked_dev,
                                    jnp.int32(0), weights_dev)
            return w, losses          # losses stay device-resident

        t_loop = _time_rounds(looped_round, n_rounds)
        t_batch = _time_rounds(batched_round, n_rounds)
        rows.append(dict(name=f"engine/{algo}/looped",
                         us_per_call=t_loop * 1e6,
                         derived=round(1.0 / t_loop, 2)))
        rows.append(dict(name=f"engine/{algo}/batched",
                         us_per_call=t_batch * 1e6,
                         derived=round(1.0 / t_batch, 2)))
        rows.append(dict(name=f"engine/{algo}/speedup", us_per_call=0.0,
                         derived=round(t_loop / t_batch, 2)))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in engine_rows():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
