"""Measured ε / accuracy / bits trade-off of the DP mask-count release.

The paper-style curve the privacy subsystem exists to produce: sweep the
noise multiplier z = σ/Δ₂ over the SAME federation (identical task,
partition, model init, schedule) and record, per point,

  privacy/curve/z<z>/final_acc     measured final accuracy (scan engine)
  privacy/curve/z<z>/epsilon       the accountant's cumulative ε after
                                   the run's R rounds at the TRUE
                                   recorded participation (δ fixed)
  privacy/curve/z<z>/uplink_bits_round   measured wire bits per round —
                                   the DP release rides the SAME 1-bit
                                   mask wire, so this column is constant
                                   across z (privacy is free on the wire)
  privacy/baseline/final_acc       the z→∞-accuracy anchor: the same
                                   federation with privacy=None (ε = ∞)
  privacy/binomial/...             one symmetric-binomial point at z=1 —
                                   the mechanism choice is a knob, not a
                                   fork of the pipeline
  privacy/entry_adjacency/...      one adjacency="entry" point at z=1 —
                                   per-ENTRY protection (Δ₂ = Δ, weaker
                                   unit) keeps utility where the default
                                   whole-mask client adjacency
                                   (Δ₂ = Δ·√d) pays √d more noise

The curve points run at the DEFAULT client adjacency: ε there is the
whole-mask spend, and the accuracy column shows the honest utility cost
of σ = z·Δ·√d per entry at this cohort size.  Every number is MEASURED
from a real engine run (the accountant reads the participation the
engine recorded), not an analytic projection.
``write_bench_json`` emits ``BENCH_privacy.json``; the CI smoke job
asserts the ε column is finite and strictly decreasing in z.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Dict, List

import dataclasses

import jax

from repro.fed import Experiment, FLConfig
from repro.fed.privacy import PrivacyConfig
from repro.fed.scenarios import make_synthetic_spec

ALGO = "fedmrn"
CLIENTS = 16
K = 4
ROUNDS = 8
STEPS = 2
BATCH = 16
DELTA = 1e-5
NOISE_MULTIPLIERS = (0.5, 1.0, 2.0)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_privacy.json")


def _base_cfg(rounds: int) -> FLConfig:
    return FLConfig(algorithm=ALGO, num_clients=CLIENTS,
                    clients_per_round=K, rounds=rounds, local_steps=STEPS,
                    batch_size=BATCH, shared_noise=True)


def _run_point(cfg: FLConfig) -> Dict:
    spec = make_synthetic_spec(cfg, n=1024, hw=8, n_classes=4,
                               d_hidden=24)
    res = Experiment(spec).run(engine="scan")
    return {
        "final_acc": float(res.final_acc),
        "epsilon": float(res.dp_epsilon[-1]),
        "delta": float(res.dp_delta),
        "uplink_bits_round": float(res.uplink_bits_round[0]),
    }


def privacy_rows(quick: bool = False) -> List[Dict]:
    rounds = 4 if quick else ROUNDS
    base = _run_point(_base_cfg(rounds))
    rows = [
        dict(name="privacy/baseline/final_acc", us_per_call=0.0,
             derived=base["final_acc"]),
        dict(name="privacy/baseline/uplink_bits_round", us_per_call=0.0,
             derived=base["uplink_bits_round"]),
    ]
    for z in NOISE_MULTIPLIERS:
        cfg = dataclasses.replace(
            _base_cfg(rounds),
            privacy=PrivacyConfig(mechanism="discrete_gaussian",
                                  noise_multiplier=z, delta=DELTA))
        pt = _run_point(cfg)
        assert pt["uplink_bits_round"] == base["uplink_bits_round"], (
            "the DP release changed the wire format: "
            f"{pt['uplink_bits_round']} != {base['uplink_bits_round']} "
            "bits at z=" + str(z))
        tag = f"privacy/curve/z{z:g}"
        rows += [
            dict(name=f"{tag}/final_acc", us_per_call=0.0,
                 derived=pt["final_acc"]),
            dict(name=f"{tag}/epsilon", us_per_call=0.0,
                 derived=round(pt["epsilon"], 4)),
            dict(name=f"{tag}/uplink_bits_round", us_per_call=0.0,
                 derived=pt["uplink_bits_round"]),
        ]
    binom = _run_point(dataclasses.replace(
        _base_cfg(rounds),
        privacy=PrivacyConfig(mechanism="binomial", noise_multiplier=1.0,
                              delta=DELTA)))
    rows += [
        dict(name="privacy/binomial/final_acc", us_per_call=0.0,
             derived=binom["final_acc"]),
        dict(name="privacy/binomial/epsilon", us_per_call=0.0,
             derived=round(binom["epsilon"], 4)),
    ]
    entry = _run_point(dataclasses.replace(
        _base_cfg(rounds),
        privacy=PrivacyConfig(mechanism="discrete_gaussian",
                              noise_multiplier=1.0, delta=DELTA,
                              adjacency="entry")))
    rows += [
        dict(name="privacy/entry_adjacency/final_acc", us_per_call=0.0,
             derived=entry["final_acc"]),
        dict(name="privacy/entry_adjacency/epsilon", us_per_call=0.0,
             derived=round(entry["epsilon"], 4)),
    ]
    return rows


def write_bench_json(rows: List[Dict], path: str = BENCH_JSON,
                     quick: bool = False) -> str:
    try:
        commit = subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True).strip()
    except Exception:  # noqa: BLE001 — no git in CI tarballs
        commit = "unknown"
    results: Dict[str, Dict] = {}
    for r in rows:
        parts = r["name"].split("/")
        if parts[0] != "privacy":
            continue
        node = results
        for p in parts[1:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = r["derived"]
    doc = {
        "bench": "privacy",
        "commit": commit,
        "config": {"algorithm": ALGO, "num_clients": CLIENTS,
                   "clients_per_round": K,
                   "rounds": 4 if quick else ROUNDS,
                   "local_steps": STEPS, "batch_size": BATCH,
                   "delta": DELTA,
                   "noise_multipliers": list(NOISE_MULTIPLIERS),
                   "adjacency": "client (curve; +1 entry point)",
                   "mechanism": "discrete_gaussian (+1 binomial point)",
                   "n_devices": jax.local_device_count(),
                   "n_cpus": os.cpu_count(),
                   "unit": "measured final accuracy and cumulative "
                           "(ε, δ) per noise multiplier on the scan "
                           "engine; uplink_bits_round is the measured "
                           "wire — constant across z by construction"},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    all_rows = privacy_rows()
    for row in all_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    print(f"# wrote {write_bench_json(all_rows)}")
