"""Wire-true service benchmark: what the HTTP boundary costs.

Runs the SAME federation through the in-process scan engine and the
loopback coordinator (``fed/service``) and reports:

  service/sync/rounds_per_sec       loopback-HTTP rounds per wall-second
                                    (K worker threads, real sockets)
  service/sync/scan_rounds_per_sec  the scan engine on the identical
                                    federation — the zero-transport bound
  service/sync/overhead_x           scan wall over service wall: what the
                                    process boundary + serde + threading
                                    costs relative to fused in-process
                                    rounds (< 1.0 means service is that
                                    fraction of scan speed)
  service/wire/measured_uplink_B    bytes of WireMsg payload that crossed
                                    the socket over the whole run
  service/wire/claimed_uplink_B     Σ WireMsg.bits/8 — the codec's claim;
                                    measured MUST equal claimed (the
                                    wire-true acceptance criterion)
  service/wire/framing_B            frame bytes beyond the payload
  service/async/rounds_per_sec      async mode with an injected straggler
                                    (one worker slot defers every POST by
                                    one round, beta = 0.5)
  service/async/latency_ratio       async wall over sync wall at the same
                                    straggler fraction — the round-close
                                    rule's win: sync waits for the
                                    straggler, async closes at min_fresh
  service/degraded/rounds_per_sec   sync mode under a FaultPlan (one
                                    dropped + one corrupt uplink) with
                                    quorum = K-1: rounds still close at
                                    the survivor threshold
  service/degraded/bad_frames       coordinator-rejected frames in that
                                    run (the corrupt POST, answered 400)
  service/degraded/participation    Σ aggregated uplinks across rounds —
                                    must equal the report's n_uplinks
                                    (exact accounting, never silent loss)

``write_bench_json`` emits machine-readable ``BENCH_service.json`` at
the repo root (same commit/config/results shape as BENCH_scale.json).
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Dict, List

import jax
import numpy as np

from repro.data import make_federated_dataset, make_image_task, make_partition
from repro.fed import (Experiment, ExperimentSpec, FLConfig, FaultPlan,
                       ServiceConfig, algorithm_codec)
from repro.models.cnn import mlp_apply, mlp_init, mlp_loss

ALGO = "fedmrn"
CLIENTS = 16
K = 4               # clients per round (worker threads on the service)
ROUNDS = 6
STEPS = 2           # local steps
BATCH = 16
D_IN, HW = 64, 8

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_service.json")


def _experiment(rounds: int) -> Experiment:
    task = make_image_task(0, n=800, hw=HW, n_classes=4, noise=0.5)
    parts = make_partition("iid", 0, task.y, CLIENTS)
    params = mlp_init(jax.random.key(0), d_in=D_IN, d_hidden=32,
                      n_classes=4)
    cfg = FLConfig(algorithm=ALGO, num_clients=CLIENTS,
                   clients_per_round=K, rounds=rounds, local_steps=STEPS,
                   batch_size=BATCH, lr=0.1, noise_alpha=3e-2)
    ds = make_federated_dataset(task.x, task.y, parts, batch_seed=7,
                                x_test=task.x[:256], y_test=task.y[:256])
    return Experiment(ExperimentSpec(loss_fn=mlp_loss, params=params,
                                     data=ds, config=cfg,
                                     eval_apply=mlp_apply,
                                     eval_every=rounds))


def _best_wall(fn, reps: int) -> float:
    fn()                                    # compile / warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def service_rows(quick: bool = False) -> List[Dict]:
    rounds = 3 if quick else ROUNDS
    reps = 2 if quick else 3
    exp = _experiment(rounds)
    async_cfg = ServiceConfig(mode="async", staleness_beta=0.5,
                              straggler_slots=(K - 1,))

    wall_scan = _best_wall(lambda: exp.run(engine="scan"), reps)
    wall_sync = _best_wall(lambda: exp.run(engine="service"), reps)
    rep = exp.service_report            # the last sync run's accounting
    claimed = rep.n_uplinks * algorithm_codec(
        exp.cfg, exp.spec.params).measured_bits(exp.spec.params)
    wall_async = _best_wall(
        lambda: exp.run(engine="service", service=async_cfg), reps)

    # one dropped + one corrupt uplink; quorum = K-1 lets the dropped
    # round close on survivors instead of hanging the barrier
    degraded_cfg = ServiceConfig(
        mode="sync", quorum=K - 1, run_timeout_s=120.0,
        faults=FaultPlan(drop_uplinks=((0, 0),),
                         corrupt_uplinks=((1, 1),)))
    wall_deg = _best_wall(
        lambda: exp.run(engine="service", service=degraded_cfg), reps)
    rep_deg = exp.service_report
    assert rep_deg.n_uplinks == sum(rep_deg.participation), (
        "degraded-run accounting drifted: aggregated uplinks "
        f"{rep_deg.n_uplinks} != Σ participation "
        f"{sum(rep_deg.participation)}")

    return [
        dict(name="service/sync/rounds_per_sec",
             us_per_call=wall_sync / rounds * 1e6,
             derived=round(rounds / wall_sync, 2)),
        dict(name="service/sync/scan_rounds_per_sec",
             us_per_call=wall_scan / rounds * 1e6,
             derived=round(rounds / wall_scan, 2)),
        dict(name="service/sync/overhead_x", us_per_call=0.0,
             derived=round(wall_scan / wall_sync, 3)),
        dict(name="service/wire/measured_uplink_B", us_per_call=0.0,
             derived=rep.uplink_payload_bits // 8),
        dict(name="service/wire/claimed_uplink_B", us_per_call=0.0,
             derived=claimed // 8),
        dict(name="service/wire/framing_B", us_per_call=0.0,
             derived=rep.uplink_framing_bits // 8),
        dict(name="service/async/rounds_per_sec",
             us_per_call=wall_async / rounds * 1e6,
             derived=round(rounds / wall_async, 2)),
        dict(name="service/async/latency_ratio", us_per_call=0.0,
             derived=round(wall_async / wall_sync, 3)),
        dict(name="service/degraded/rounds_per_sec",
             us_per_call=wall_deg / rounds * 1e6,
             derived=round(rounds / wall_deg, 2)),
        dict(name="service/degraded/bad_frames", us_per_call=0.0,
             derived=int(rep_deg.rejected.get("bad_frame", 0))),
        dict(name="service/degraded/participation", us_per_call=0.0,
             derived=int(sum(rep_deg.participation))),
    ]


def write_bench_json(rows: List[Dict], path: str = BENCH_JSON,
                     quick: bool = False) -> str:
    try:
        commit = subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True).strip()
    except Exception:  # noqa: BLE001 — no git in CI tarballs
        commit = "unknown"
    results: Dict[str, Dict] = {}
    for r in rows:
        parts = r["name"].split("/")
        if parts[0] != "service":
            continue
        if len(parts) == 2:
            results[parts[1]] = r["derived"]
        else:
            results.setdefault(parts[1], {})[parts[2]] = r["derived"]
    doc = {
        "bench": "service",
        "commit": commit,
        "config": {"algorithm": ALGO, "num_clients": CLIENTS,
                   "clients_per_round": K,
                   "rounds": 3 if quick else ROUNDS,
                   "local_steps": STEPS, "batch_size": BATCH,
                   "straggler_slots": [K - 1], "staleness_beta": 0.5,
                   "degraded": {"quorum": K - 1,
                                "drop_uplinks": [[0, 0]],
                                "corrupt_uplinks": [[1, 1]]},
                   "model": f"mlp({D_IN},32,4)",
                   "n_devices": jax.local_device_count(),
                   "n_cpus": os.cpu_count(),
                   "unit": "rounds_per_sec over loopback HTTP with K "
                           "client threads; wire rows are whole-run "
                           "bytes (measured MUST equal claimed); "
                           "latency_ratio is async-wall over sync-wall "
                           "at one injected straggler"},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    print("name,us_per_call,derived")
    all_rows = service_rows()
    for row in all_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    print(f"# wrote {write_bench_json(all_rows)}")
